#!/usr/bin/env python
"""HPO over TPU mesh slices — the paper's Resource Manager adapted to pods.

Part 1 (virtual): a 16x16 "pod" is tiled into 4x4 slices (16 concurrent
trials); jobs simulate training and the elastic wrapper injects a slice
failure + a scale-out mid-experiment — the EC2-autoscaling story of paper
Fig. 3, on pod topology.

Part 2 (real devices): the container's CPU device forms a 1x1 slice; each
trial jit-compiles and trains a tiny LM on its slice's Mesh — proving the
trial path is a genuine pjit program on the slice.

    PYTHONPATH=src python examples/mesh_hpo.py
"""
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.experiment import Experiment  # noqa: E402
from repro.core.resource.elastic import ElasticResourceManager  # noqa: E402
from repro.core.resource.mesh_pool import MeshPoolResourceManager, tile_pod  # noqa: E402

SPACE = [
    {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-1], "scale": "log"},
    {"name": "warmup_frac", "type": "float", "range": [0.05, 0.5]},
]


def part1_virtual_pod():
    print("=== part 1: 16x16 virtual pod, 4x4 slices, failure + scale-out ===")
    rm = ElasticResourceManager(
        MeshPoolResourceManager(pod_shape=(16, 16), slice_shape=(4, 4), virtual=True)
    )
    print(f"pool: {rm.n_total()} slices of 16 chips")

    def trial(cfg, mesh_slice):
        time.sleep(0.02)
        import math
        return -(math.log10(cfg["learning_rate"]) + 2.5) ** 2 - cfg["warmup_frac"]

    exp = Experiment(
        {"proposer": "tpe", "parameter_config": SPACE, "n_samples": 32,
         "n_parallel": 16, "target": "max", "random_seed": 0, "max_retries": 3},
        trial, resource_manager=rm,
    )

    def chaos():
        time.sleep(0.1)
        victim = next(iter(rm.base.slices))
        print(f"  !! failing slice {victim} (its job is retried elsewhere)")
        rm.fail_resource(victim)
        time.sleep(0.1)
        extra = tile_pod((4, 4), (4, 4), virtual=True)[0]
        rm.base.slices["spare[0:4,0:4]"] = extra
        rm.scale_out(["spare[0:4,0:4]"])
        print("  ++ scaled out with a spare slice")

    threading.Thread(target=chaos, daemon=True).start()
    best = exp.run()
    done = sum(1 for j in exp.job_log if j.status.value == "finished")
    print(f"finished {done} trials despite failure; best lr="
          f"{best['config']['learning_rate']:.2e} score={best['score']:.3f}\n")


def part2_real_device():
    print("=== part 2: real-device slice trials (pjit'd tiny LM train) ===")
    import jax

    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.data.pipeline import SyntheticLM
    from repro.train.train_step import init_train_state, make_train_step

    rm = MeshPoolResourceManager(pod_shape=(1, 1), slice_shape=(1, 1),
                                 devices=jax.devices())

    def trial(cfg, mesh_slice):
        mesh = mesh_slice.mesh(("data", "model"))
        model = get_smoke_config("starcoder2-3b")
        tc = TrainConfig(model=model, parallel=ParallelConfig(),
                         learning_rate=float(cfg["learning_rate"]),
                         warmup_steps=2, total_steps=12)
        data = SyntheticLM(model.vocab_size, 32, 4, seed=0)
        with mesh:
            state = init_train_state(jax.random.PRNGKey(0), tc)
            step = jax.jit(make_train_step(tc))
            loss = None
            for s in range(12):
                state, m = step(state, data.make_batch(s))
                loss = float(m["loss"])
        return -loss

    exp = Experiment(
        {"proposer": "random", "parameter_config": SPACE, "n_samples": 3,
         "n_parallel": 1, "target": "max", "random_seed": 0},
        trial, resource_manager=rm,
    )
    best = exp.run()
    print(f"best final loss {-best['score']:.3f} at lr={best['config']['learning_rate']:.2e}")


if __name__ == "__main__":
    part1_virtual_pod()
    part2_real_device()
