#!/usr/bin/env python
"""Paper §IV — tune the 2conv+2fc CNN's five hyperparameters, then compare
proposers on equal budgets (the experiment behind Figs. 4/5).

Each job genuinely trains the CNN (synthetic MNIST stand-in; ~1-2 s/epoch on
CPU) and reports test accuracy.  Hyperband/BOHB allocate ``n_iterations``
adaptively.

    PYTHONPATH=src python examples/cnn_hpo.py --proposers random,tpe --n-samples 6
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.experiment import Experiment  # noqa: E402
from repro.train.cnn import train_cnn  # noqa: E402

SPACE = [
    {"name": "conv1", "type": "int", "range": [4, 24]},
    {"name": "conv2", "type": "int", "range": [8, 32]},
    {"name": "fc1", "type": "int", "range": [16, 96]},
    {"name": "dropout", "type": "float", "range": [0.0, 0.5]},
    {"name": "learning_rate", "type": "float", "range": [3e-4, 3e-2], "scale": "log"},
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--proposers", default="random,tpe")
    ap.add_argument("--n-samples", type=int, default=6)
    ap.add_argument("--n-parallel", type=int, default=2)
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--db", default="", help="sqlite tracking db path")
    args = ap.parse_args()

    def job(config):
        return train_cnn(config, n_train=args.n_train, n_test=256, batch=64)

    for proposer in args.proposers.split(","):
        exp_cfg = {
            "proposer": proposer,
            "parameter_config": SPACE,
            "n_samples": args.n_samples,
            "n_parallel": args.n_parallel,
            "target": "max",
            "random_seed": 0,
            "max_iter": 4, "eta": 2,          # hyperband/bohb budget geometry
        }
        if args.db:
            exp_cfg["db_path"] = args.db
        t0 = time.time()
        best = Experiment(exp_cfg, job).run()
        cfg = {k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in best["config"].items() if k in
               ("conv1", "conv2", "fc1", "dropout", "learning_rate")}
        print(f"{proposer:10s} best test-acc {best['score']:.3f} in "
              f"{time.time()-t0:5.1f}s  config={cfg}")


if __name__ == "__main__":
    main()
