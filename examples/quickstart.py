#!/usr/bin/env python
"""Quickstart — the paper's end-to-end workflow in one file.

Optimizes the Rosenbrock function (paper Code 2's example) and shows the
three pieces a user touches:

1. the experiment configuration (paper Code 2),
2. the job — here an in-process callable; ``--script`` switches to the
   paper-faithful subprocess mode (BasicConfig argv[1] in, print_result out),
3. running it, switching proposers with a single word.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --proposer gp --script
"""
import argparse
import os
import sys
import tempfile
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.experiment import Experiment  # noqa: E402

# --- paper Code 2: the experiment configuration --------------------------------
EXPERIMENT = {
    "proposer": "random",          # <- switching algorithms = changing this word
    "n_samples": 25,
    "n_parallel": 4,
    "target": "max",
    "random_seed": 0,
    "parameter_config": [
        {"name": "x", "type": "float", "range": [-5.0, 10.0]},
        {"name": "y", "type": "float", "range": [-5.0, 10.0]},
    ],
}


# --- the user's code (in-process form) ------------------------------------------
def rosenbrock(config):
    x, y = config["x"], config["y"]
    return -((1 - x) ** 2 + 100 * (y - x * x) ** 2)  # maximize => negate


# --- the user's code (paper Code 3 script form) ---------------------------------
SCRIPT = textwrap.dedent(f"""\
    #!/usr/bin/env python
    import sys
    sys.path.insert(0, {os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")!r})
    from repro.core.basic_config import BasicConfig, print_result

    config = BasicConfig(x=0.0, y=0.0)                 # defaults: standalone-runnable
    config.load(sys.argv[1] if len(sys.argv) > 1 else None)
    score = -((1 - config.x) ** 2 + 100 * (config.y - config.x ** 2) ** 2)
    print_result(score)                                 # report back
""")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--proposer", default="random",
                    help="random | grid | gp | tpe | hyperband | bohb | asha | pbt")
    ap.add_argument("--script", action="store_true",
                    help="run jobs as subprocess scripts (paper Code 3 protocol)")
    args = ap.parse_args()

    cfg = dict(EXPERIMENT, proposer=args.proposer)
    if args.script:
        tmp = tempfile.mkdtemp()
        path = os.path.join(tmp, "rosenbrock_job.py")
        with open(path, "w") as f:
            f.write(SCRIPT)
        os.chmod(path, 0o755)
        cfg.update(resource="subprocess", workdir=tmp)
        exp = Experiment(cfg, path)
    else:
        exp = Experiment(cfg, rosenbrock)

    best = exp.run()
    print(f"\nproposer={args.proposer} mode={'script' if args.script else 'callable'}")
    print(f"best score {best['score']:.4f} at "
          f"x={best['config']['x']:.3f} y={best['config']['y']:.3f} "
          f"(optimum: 0.0 at x=y=1)")


if __name__ == "__main__":
    main()
