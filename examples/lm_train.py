#!/usr/bin/env python
"""End-to-end LM training driver demo — the production train path on CPU.

Trains a reduced config of any assigned architecture on the deterministic
synthetic LM stream with sharded train steps, checkpointing and auto-resume,
then proves fault tolerance by crashing mid-run and resuming.

    PYTHONPATH=src python examples/lm_train.py --arch gemma2-9b --steps 60
    PYTHONPATH=src python examples/lm_train.py --demo-crash   # kill + resume demo
"""
import argparse
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
sys.path.insert(0, SRC)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--demo-crash", action="store_true",
                    help="inject a failure mid-run, then auto-resume")
    args = ap.parse_args()

    env = dict(os.environ, PYTHONPATH=SRC)
    ckpt = tempfile.mkdtemp(prefix="lm_train_ckpt_")
    base = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps), "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", ckpt, "--ckpt-every", str(max(args.steps // 4, 1)),
    ]
    if args.demo_crash:
        fail_at = args.steps * 3 // 4
        print(f"=== run 1: will crash at step {fail_at} ===")
        r = subprocess.run(base + ["--fail-at", str(fail_at)], env=env)
        assert r.returncode == 17, "expected the injected failure"
        print("\n=== run 2: same command resumes from the checkpoint ===")
        r = subprocess.run(base, env=env)
        sys.exit(r.returncode)
    else:
        r = subprocess.run(base, env=env)
        sys.exit(r.returncode)


if __name__ == "__main__":
    main()
