#!/usr/bin/env python
"""Paper §V — EAS-style NAS as a Proposer.

The RL meta-controller (REINFORCE over widen/deepen morphisms) runs as a
Proposer; each child architecture trains as an ordinary job with a net2net
warm start from the incumbent (the ``arch_parent`` aux key — the paper's
"auxiliary values can be customized ... to save and retrieve models").
Architecture evolution happens entirely through the standard
get_param()/update() interface: the framework neither knows nor cares that
the "hyperparameter" is a network topology.

    PYTHONPATH=src python examples/nas_eas.py --episodes 2 --children 3
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.experiment import Experiment  # noqa: E402
from repro.train.cnn import train_cnn  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=2)
    ap.add_argument("--children", type=int, default=3)
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--epochs", type=int, default=1)
    args = ap.parse_args()

    def job(config):
        # each child is a CNN defined by its arch string; warm-started when
        # arch_parent is present (function-preserving morphism)
        return train_cnn(dict(config, n_iterations=args.epochs),
                         n_train=args.n_train, n_test=256, batch=64)

    exp = Experiment(
        {"proposer": "eas", "parameter_config": [], "target": "max",
         "random_seed": 0, "n_parallel": args.children,
         "n_episodes": args.episodes, "children_per_episode": args.children},
        job,
    )
    t0 = time.time()
    best = exp.run()
    arch = json.loads(best["config"]["arch"])
    print(f"\nfound architecture in {time.time()-t0:.1f}s: "
          f"conv={arch['conv']} fc={arch['fc']}  test-acc={best['score']:.3f}")
    print(f"jobs run: {len(exp.job_log)} "
          f"({sum(1 for j in exp.job_log if j.status.value == 'finished')} finished)")


if __name__ == "__main__":
    main()
