"""Validate that the user-facing docs actually match the code.

Checks, over README.md and docs/*.md:

* every fenced ``python`` block compiles, and every ``import repro...`` /
  ``from repro... import ...`` statement in one resolves against the real
  package (module importable, attributes present);
* every ``--flag`` mentioned (inline code or fenced shell blocks) exists in
  ``repro.launch.hpo``'s argparse --help;
* every ``make <target>`` reference names a real Makefile target;
* every repo-relative path in backticks or local markdown links exists
  (paths are also tried relative to ``src/repro`` so docs can say
  ``core/experiment.py``).

Run via ``make docs-check``.  Exits non-zero with a list of findings.
"""
from __future__ import annotations

import glob
import importlib
import io
import os
import re
import sys
from contextlib import redirect_stdout

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md"] + sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))

FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.S)
INLINE_CODE_RE = re.compile(r"`([^`\n]+)`")
LINK_RE = re.compile(r"\]\(([^)\s]+)\)")
FLAG_RE = re.compile(r"(--[a-z][a-z0-9-]*)\b")
MAKE_RE = re.compile(r"\bmake\s+([a-z][a-z0-9_-]*)")
PATH_PREFIXES = ("src/", "docs/", "benchmarks/", "tests/", "examples/", "tools/")


def _read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


def _hpo_help() -> str:
    from repro.launch import hpo

    buf = io.StringIO()
    try:
        with redirect_stdout(buf):
            hpo.main(["--help"])
    except SystemExit:
        pass
    return buf.getvalue()


def _check_python_block(code: str, where: str, errors: list) -> None:
    try:
        compile(code, where, "exec")
    except SyntaxError as e:
        errors.append(f"{where}: python block does not compile: {e}")
        return
    for line in code.splitlines():
        line = line.strip()
        m = re.match(r"from\s+(repro[\w.]*)\s+import\s+(.+)", line)
        if m:
            mod, names = m.group(1), m.group(2)
            try:
                module = importlib.import_module(mod)
            except Exception as e:
                errors.append(f"{where}: cannot import {mod}: {e}")
                continue
            for name in re.split(r"\s*,\s*", names.split("#")[0].strip()):
                name = name.split(" as ")[0].strip()
                if name and name != "*" and not hasattr(module, name):
                    errors.append(f"{where}: {mod} has no attribute {name!r}")
        elif re.match(r"import\s+repro[\w.]*", line):
            mod = line.split()[1]
            try:
                importlib.import_module(mod)
            except Exception as e:
                errors.append(f"{where}: cannot import {mod}: {e}")


def _check_paths(doc: str, text: str, errors: list) -> None:
    doc_dir = os.path.join(ROOT, os.path.dirname(doc))
    candidates = set()
    for m in INLINE_CODE_RE.finditer(text):
        tok = m.group(1).strip().rstrip(":,")
        if "/" in tok and re.fullmatch(r"[A-Za-z0-9_./-]+", tok):
            candidates.add(tok)
    for m in LINK_RE.finditer(text):
        tok = m.group(1).split("#")[0]
        if tok and not tok.startswith(("http://", "https://", "mailto:")):
            candidates.add(tok)
    for tok in sorted(candidates):
        if tok.startswith(PATH_PREFIXES) or tok in ("Makefile",) or tok.endswith(".md"):
            # markdown links resolve relative to the doc itself first
            roots = [doc_dir, ROOT]
        elif tok.endswith((".py", "/")):
            roots = [ROOT, os.path.join(ROOT, "src", "repro")]
        else:
            continue
        if not any(os.path.exists(os.path.join(r, tok)) for r in roots):
            errors.append(f"{doc}: referenced path {tok!r} does not exist")


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    errors: list = []
    makefile = _read(os.path.join(ROOT, "Makefile"))
    make_targets = set(re.findall(r"^([a-zA-Z][\w-]*)\s*:", makefile, re.M))
    help_text = _hpo_help()

    for path in DOC_FILES:
        doc = os.path.relpath(path, ROOT)
        text = _read(path)

        flags, make_refs = set(), set()
        for lang, body in FENCE_RE.findall(text):
            where = f"{doc} ({lang or 'text'} block)"
            if lang == "python":
                _check_python_block(body, where, errors)
            if lang in ("bash", "sh", "shell", "console", ""):
                for line in body.splitlines():
                    make_refs.update(MAKE_RE.findall(line))
                    if "repro.launch.hpo" in line or line.strip().startswith("--"):
                        flags.update(FLAG_RE.findall(line))
        for m in INLINE_CODE_RE.finditer(text):
            tok = m.group(1).strip()
            flags.update(FLAG_RE.findall(tok))
            make_refs.update(MAKE_RE.findall(tok))

        for flag in sorted(flags):
            if flag not in help_text:
                errors.append(f"{doc}: flag {flag} not in `repro.launch.hpo --help`")
        for target in sorted(make_refs):
            if target not in make_targets:
                errors.append(f"{doc}: `make {target}` is not a Makefile target")
        _check_paths(doc, text, errors)

    if errors:
        print(f"docs-check: {len(errors)} problem(s)")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"docs-check: OK ({len(DOC_FILES)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
