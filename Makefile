# One-command entry points for the tier-1 suite and smoke benchmarks.
#
#   make test    — full tier-1 pytest run (hypothesis-based files skip
#                  cleanly when hypothesis isn't installed).  Every test runs
#                  under a timeout guard (pytest-timeout when installed, a
#                  faulthandler watchdog otherwise — see tests/conftest.py)
#                  so a deadlocked streaming-flush thread fails instead of
#                  hanging CI; tune with PYTEST_TIMEOUT=<seconds>
#   make bench   — smoke benchmarks: HPO trial-engine throughput (emits
#                  BENCH_hpo_throughput.json) + extensibility LOC count
#   make bench-all — every registered benchmark (slow: full roofline sweep)
#   make docs-check — README/docs snippets compile, imports resolve, CLI
#                  flags and make targets referenced in docs exist

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-all docs-check

test:
	$(PYTHON) -m pytest -x -q

bench:
	$(PYTHON) -m benchmarks.run --only hpo_throughput,extensibility

bench-all:
	$(PYTHON) -m benchmarks.run

docs-check:
	$(PYTHON) tools/docs_check.py
