"""Benchmark runner: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only fig3,roofline
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = ("table1", "fig3", "fig4", "fig5", "extensibility", "hpo_throughput", "roofline")
OUT_DIR = "artifacts/bench"


def _run_one(name: str):
    if name == "table1":
        from . import table1_switching as m
        return m.run()
    if name == "fig3":
        from . import fig3_scalability as m
        return m.run()
    if name == "fig4":
        from . import fig4_distributions as m
        return m.run()
    if name == "fig5":
        from . import fig5_hpo_curves as m
        return m.run()
    if name == "extensibility":
        from . import extensibility_loc as m
        return m.run()
    if name == "hpo_throughput":
        from . import hpo_throughput as m
        return m.run()
    if name == "roofline":
        from . import roofline as m
        single = m.run("pod_16x16")
        multi = m.run("multipod_2x16x16")
        return {"single_pod": single, "multi_pod": multi,
                "pass": single["pass"] and multi["pass"]}
    raise KeyError(name)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default="", help="comma-separated subset of " + ",".join(BENCHES))
    args = p.parse_args(argv)
    names = [n for n in args.only.split(",") if n] or list(BENCHES)

    os.makedirs(OUT_DIR, exist_ok=True)
    all_ok = True
    for name in names:
        t0 = time.time()
        try:
            result = _run_one(name)
            status = "PASS" if result.get("pass", True) else "CHECK"
        except Exception as e:  # noqa: BLE001 - surface but keep running others
            import traceback
            result = {"error": traceback.format_exc()}
            status = "FAIL"
        dt = time.time() - t0
        all_ok &= status != "FAIL"
        with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
            json.dump(result, f, indent=1, default=str)
        claim = result.get("paper_claim", "")
        print(f"[{status}] {name:14s} {dt:7.1f}s  {claim}", flush=True)
        if name == "roofline" and "single_pod" in result:
            sp = result["single_pod"]
            print(f"         cells={sp['n_cells']} ok={sp['n_ok']} "
                  f"skipped={sp['n_skipped']} failed={sp['n_failed']} "
                  f"bottlenecks={sp['bottleneck_histogram']}")
    print(f"\nartifacts in {OUT_DIR}/")
    return 0 if all_ok else 1


if __name__ == "__main__":
    sys.exit(main())
