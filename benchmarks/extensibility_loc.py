"""Paper §III-A extensibility claim — "integrating BOHB took 138 new lines
against 4305 reused".

We measure the same quantity for this codebase: lines of code in each
proposer's integration file vs the shared machinery it reuses (base Proposer
+ search space + experiment loop + resource managers + tracking).  The claim
reproduced is structural: each new algorithm costs ~100 lines because the
interface is two functions.
"""
from __future__ import annotations

import os
from typing import Dict

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _loc(path: str) -> int:
    with open(path) as f:
        return sum(
            1 for ln in f
            if ln.strip() and not ln.strip().startswith("#")
        )


def run() -> Dict:
    prop_dir = os.path.join(SRC, "core", "proposer")
    per_proposer = {}
    for name in sorted(os.listdir(prop_dir)):
        if name.endswith(".py") and name != "__init__.py":
            per_proposer[name[:-3]] = _loc(os.path.join(prop_dir, name))

    shared_files = [
        os.path.join(SRC, "core", "proposer", "__init__.py"),
        os.path.join(SRC, "core", "search_space.py"),
        os.path.join(SRC, "core", "experiment.py"),
        os.path.join(SRC, "core", "job.py"),
        os.path.join(SRC, "core", "basic_config.py"),
        os.path.join(SRC, "core", "tracking", "database.py"),
    ]
    for sub in ("resource",):
        d = os.path.join(SRC, "core", sub)
        shared_files += [os.path.join(d, f) for f in os.listdir(d) if f.endswith(".py")]
    shared = sum(_loc(f) for f in shared_files)

    # BOHB is the paper's example: it subclasses Hyperband + reuses TPE's model
    bohb_new = per_proposer.get("bohb", 0)
    return {
        "per_proposer_loc": per_proposer,
        "shared_framework_loc": shared,
        "bohb_new_loc": bohb_new,
        "bohb_reuse_ratio": round(shared / max(bohb_new, 1), 1),
        "paper_claim": "BOHB = 138 new lines vs 4305 reused",
        "pass": bohb_new < 200 and shared > 1000,
    }
