"""Roofline report — collates the dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/<mesh>/<arch>__<shape>.json (produced by
``python -m repro.launch.dryrun --all``) and emits, per (arch x shape x mesh):
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
and the roofline fraction.  Also writes a markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

ART = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def _fmt(x):
    return f"{x:.3e}"


def run(mesh: str = "pod_16x16") -> Dict:
    rows = []
    md = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | useful | frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        r = json.load(open(path))
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"], "skip": r["reason"]})
            md.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP: {r['reason'][:45]} | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"], "error": True})
            md.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "kind": r["kind"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "bottleneck": rl["bottleneck"],
            "useful_ratio": rl["useful_ratio"],
            "roofline_fraction": rl["roofline_fraction"],
            "compile_s": r.get("compile_s"),
        })
        md.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(rl['compute_s'])} | {_fmt(rl['memory_s'])} "
            f"| {_fmt(rl['collective_s'])} | {rl['bottleneck']} "
            f"| {rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.3f} |"
        )
    ok = [r for r in rows if "compute_s" in r]
    return {
        "mesh": mesh,
        "n_cells": len(rows),
        "n_ok": len(ok),
        "n_skipped": sum(1 for r in rows if "skip" in r),
        "n_failed": sum(1 for r in rows if r.get("error")),
        "bottleneck_histogram": {
            b: sum(1 for r in ok if r["bottleneck"] == b)
            for b in ("compute", "memory", "collective")
        },
        "rows": rows,
        "markdown": "\n".join(md),
        "pass": rows != [] and not any(r.get("error") for r in rows),
    }
