"""Paper Table I — flexibility / usability / extensibility, measured.

* flexibility: number of proposers registered behind the single interface
  (paper claims 9 for Auptimizer) and proof that switching between them is a
  one-word config change: the SAME target callable runs under every proposer
  with zero code changes.
* usability: the job-side protocol is a script (BasicConfig + print_result),
  demonstrated by running one subprocess job.
* extensibility: integration LOC per proposer (see extensibility_loc).
"""
from __future__ import annotations

import sys
import tempfile
import textwrap
from typing import Dict

import numpy as np

from repro.core.experiment import Experiment
from repro.core.proposer import available_proposers
from repro.core.resource import available_resource_managers

SPACE = [
    {"name": "x", "type": "float", "range": [-2.0, 2.0]},
    {"name": "y", "type": "float", "range": [-1.0, 3.0]},
]


def rosenbrock(cfg):
    x, y = float(cfg["x"]), float(cfg["y"])
    return -((1 - x) ** 2 + 100 * (y - x * x) ** 2)


def run(budget: int = 12) -> Dict:
    proposers = available_proposers()
    scores = {}
    for name in ("random", "grid", "gp", "tpe", "hyperband", "bohb", "asha", "pbt"):
        exp_cfg = {"proposer": name, "parameter_config": SPACE, "n_samples": budget,
                   "n_parallel": 4, "target": "max", "random_seed": 0}
        best = Experiment(exp_cfg, rosenbrock).run()   # same target, 1 word changed
        scores[name] = best["score"]

    # usability: script-format job via the subprocess RM
    with tempfile.TemporaryDirectory() as tmp:
        script = f"{tmp}/job.py"
        with open(script, "w") as f:
            f.write(textwrap.dedent(f"""\
                import sys
                sys.path.insert(0, {repr(sys.path[0] + '/src')})
                from repro.core.basic_config import BasicConfig, print_result
                c = BasicConfig(x=0.0, y=0.0).load(sys.argv[1] if len(sys.argv) > 1 else None)
                print_result(-((1 - c.x) ** 2 + 100 * (c.y - c.x ** 2) ** 2))
            """))
        exp = Experiment(
            {"proposer": "random", "parameter_config": SPACE, "n_samples": 2,
             "n_parallel": 1, "target": "max", "random_seed": 0,
             "resource": "subprocess", "workdir": tmp},
            script,
        )
        script_best = exp.run()

    return {
        "criteria": {
            "open_source": True,
            "flexibility_n_proposers": len(proposers),
            "proposers": proposers,
            "usability_format": "script (BasicConfig argv[1] JSON in, print_result out)",
            "scalability_resource_managers": available_resource_managers(),
            "extensibility": "Proposer ABC: get_param()/update()/finished()",
        },
        "switching_is_config_only": {k: round(v, 3) for k, v in scores.items()},
        "script_job_score": script_best["score"],
        "paper_claim": "Auptimizer: 9 HPO algorithms, script-format code, scalable, extensible",
        "pass": len(proposers) >= 9 and all(np.isfinite(v) for v in scores.values()),
    }
