"""Benchmarks — one per paper table/figure + the roofline report.

    python -m benchmarks.run            # all, CPU-sized budgets
    python -m benchmarks.run --only fig3

Artifacts land in artifacts/bench/<name>.json.
"""
