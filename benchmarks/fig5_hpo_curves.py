"""Paper Fig. 5 — best-so-far curves per HPO algorithm, n_parallel=8.

The paper's §IV experiment: the 2conv+2fc model, five hyperparameters,
roughly equal total epoch budgets per algorithm (random/GP/TPE: n configs x
10 epochs; grid: 3^4 x 2 lattice; Hyperband/BOHB allocate adaptively).  Here
each job really trains the CNN (synthetic MNIST stand-in) on CPU; budgets are
scaled down so the whole figure runs in minutes.

Outputs best-so-far accuracy vs cumulative epochs per proposer — the shape
the paper uses to argue budget-efficiency of HB/BOHB and the quality of
model-based searchers.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from repro.core.experiment import Experiment
from repro.train.cnn import train_cnn

# n_grid=2 caps grid search at 2^5 = 32 lattice points (the paper capped its
# grid at 162 for rough budget parity with 100-config searches)
SPACE = [
    {"name": "conv1", "type": "int", "range": [4, 24], "n_grid": 2},
    {"name": "conv2", "type": "int", "range": [8, 32], "n_grid": 2},
    {"name": "fc1", "type": "int", "range": [16, 96], "n_grid": 2},
    {"name": "dropout", "type": "float", "range": [0.0, 0.5], "n_grid": 2},
    {"name": "learning_rate", "type": "float", "range": [3e-4, 3e-2], "scale": "log", "n_grid": 2},
]


def run(n_samples: int = 8, epochs_unit: int = 3, n_train: int = 1024) -> Dict:
    curves: Dict[str, List] = {}
    for name in ("random", "grid", "gp", "tpe", "hyperband", "bohb"):
        log = []
        lock = threading.Lock()

        def target(cfg):
            ep = max(1, int(cfg.get("n_iterations", 1) * epochs_unit))
            acc = train_cnn(dict(cfg, n_iterations=ep), n_train=n_train, n_test=512,
                            batch=64)
            with lock:
                log.append({"epochs": ep, "acc": acc})
            return acc

        Experiment(
            {"proposer": name, "parameter_config": SPACE, "n_samples": n_samples,
             "n_parallel": 8, "target": "max", "random_seed": 0,
             "max_iter": 4, "eta": 2},
            target,
        ).run()

        cum, best, curve = 0, 0.0, []
        for row in log:
            cum += row["epochs"]
            best = max(best, row["acc"])
            curve.append({"cum_epochs": cum, "best_acc": round(best, 4)})
        curves[name] = curve

    finals = {k: (v[-1]["best_acc"] if v else 0.0) for k, v in curves.items()}
    budgets = {k: (v[-1]["cum_epochs"] if v else 0) for k, v in curves.items()}
    return {
        "curves": curves,
        "final_best_acc": finals,
        "total_epochs": budgets,
        "paper_claim": "HB/BOHB are budget-efficient; model-based finds good configs",
        "pass": all(a > 0.35 for a in finals.values()),
    }
