"""Paper Fig. 3 — scalability: experiment wall-time vs sum(job time)/n_parallel.

The paper ran 128 configurations on up to 64 EC2 instances and showed the
controller overhead is marginal: wall-time tracks sum(job)/n until the
last-job straggler effect flattens it.  We reproduce the experiment shape on
the mesh-slice pool (virtual slices, so a 16x16 "pod" exists on this 1-CPU
container) with jobs that sleep their simulated training duration — exactly
the controller-overhead question Fig. 3 asks, measured for real.

Fixed random seed => every n_parallel runs the SAME 128 job durations
(paper: "we fixed the random seed, such that all experiments explored the
same configurations").
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core.experiment import Experiment
from repro.core.resource.mesh_pool import MeshPoolResourceManager

SPACE = [{"name": "complexity", "type": "float", "range": [0.5, 1.5]}]


def run(n_jobs: int = 128, base_s: float = 0.02, parallels=(1, 2, 4, 8, 16, 32, 64)) -> Dict:
    rows = []
    for n_par in parallels:
        # 16x16 virtual pod tiled into n_par slices (paper: n EC2 instances)
        rm = MeshPoolResourceManager(pod_shape=(64, 1), slice_shape=(64 // min(n_par, 64), 1),
                                     virtual=True)
        durations = []

        def target(cfg, _slice):
            d = base_s * float(cfg["complexity"])  # "training time varies with complexity"
            durations.append(d)
            time.sleep(d)
            return -abs(float(cfg["complexity"]) - 1.0)

        exp = Experiment(
            {"proposer": "random", "parameter_config": SPACE, "n_samples": n_jobs,
             "n_parallel": n_par, "target": "max", "random_seed": 7},
            target, resource_manager=rm,
        )
        t0 = time.time()
        exp.run()
        wall = time.time() - t0
        ideal = sum(durations) / n_par
        rows.append({
            "n_parallel": n_par,
            "wall_s": round(wall, 3),
            "sum_jobs_over_n": round(ideal, 3),
            "overhead_s": round(wall - ideal, 3),
            "overhead_frac": round((wall - ideal) / max(ideal, 1e-9), 3),
        })
    # paper claim: overhead marginal vs training time at low n; last-job effect at high n
    return {
        "rows": rows,
        "paper_claim": "wall-time tracks sum(jobs)/n; HPO overhead marginal",
        "pass": rows[0]["overhead_frac"] < 0.5,
    }
