"""HPO trial-engine throughput: serial-recompile vs compile-once vs vmapped.

The pre-refactor Experiment loop baked each proposal's hyperparameters into
the ``TrainConfig`` closure, so every trial paid a full XLA compile and the
device ran one small model at a time.  This benchmark quantifies the two
fixes on the CPU smoke config:

* **serial_recompile** — the legacy path: fresh ``jax.jit(make_train_step)``
  per trial (compiles grow O(n_trials));
* **compile_once**     — hyperparameters as a traced ``HParams`` argument via
  ``get_compiled_train_step``: one compile serves every trial;
* **vmapped**          — ``repro.train.population``: K trials advance in one
  jitted ``vmap`` program (one compile per (arch, K), amortized dispatch).

Emits ``BENCH_hpo_throughput.json`` (repo root) and returns the result dict
for ``benchmarks/run.py``.  Pass criteria: vmapped >= 3x serial trials/sec,
compile-once and vmapped each compile exactly once, and vmapped scores match
the compile-once scores within tolerance.
"""
from __future__ import annotations

import json
import time

import numpy as np

OUT_PATH = "BENCH_hpo_throughput.json"
SPEEDUP_FLOOR = 3.0
SCORE_TOL = 1e-3


def run(arch: str = "starcoder2-3b", n_trials: int = 8, population: int = 8,
        steps: int = 6, batch: int = 4, seq: int = 32, seed: int = 0):
    import jax

    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.core.search_space import SearchSpace
    from repro.data.pipeline import SyntheticLM
    from repro.launch.hpo import SPACE, PopulationTrial
    from repro.train import population as pop
    from repro.train import train_step as ts

    space = SearchSpace.from_json(SPACE)
    rng = np.random.default_rng(seed)
    cfgs = [space.sample(rng) for _ in range(n_trials)]

    results = {}

    # -- serial_recompile: the legacy closure-over-hparams path ----------------
    ts.clear_step_cache()
    model_cfg = get_smoke_config(arch)
    data = SyntheticLM(model_cfg.vocab_size, seq, batch, seed=seed)
    t0 = time.time()
    compiles = 0
    serial_scores = []
    for cfg in cfgs:
        tc = TrainConfig(
            model=model_cfg, parallel=ParallelConfig(remat="none"),
            learning_rate=float(cfg["learning_rate"]),
            warmup_steps=max(1, int(cfg.get("warmup_frac", 0.1) * steps)),
            total_steps=steps,
            weight_decay=float(cfg.get("weight_decay", 0.1)),
            b2=float(cfg.get("b2", 0.95)),
            grad_clip=float(cfg.get("grad_clip", 1.0)),
            seed=seed,
        )
        state = ts.init_train_state(jax.random.PRNGKey(seed), tc)
        step_fn = jax.jit(ts.make_train_step(tc))
        score = -1e9
        for s in range(steps):
            state, metrics = step_fn(state, data.make_batch(s))
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                break
            score = -loss
        serial_scores.append(score)
        compiles += step_fn._cache_size()
    dt = time.time() - t0
    results["serial_recompile"] = {
        "seconds": dt, "trials_per_sec": n_trials / dt, "compiles": compiles,
    }

    # -- compile_once: HParams as a traced argument ----------------------------
    ts.clear_step_cache()
    trial = PopulationTrial(arch, steps, batch, seq, seed)
    t0 = time.time()
    once_scores = [trial(cfg) for cfg in cfgs]
    dt = time.time() - t0
    tc_static, _ = trial._setup()
    results["compile_once"] = {
        "seconds": dt, "trials_per_sec": n_trials / dt,
        "compiles": ts.get_compiled_train_step(tc_static)._cache_size(),
    }

    # -- vmapped: K trials in one device program -------------------------------
    pop.clear_population_cache()
    vtrial = PopulationTrial(arch, steps, batch, seq, seed, population=population)
    t0 = time.time()
    vmap_scores = []
    for i in range(0, n_trials, population):
        vmap_scores.extend(vtrial.run_population(cfgs[i:i + population]))
    dt = time.time() - t0
    tc_static, _ = vtrial._setup()
    results["vmapped"] = {
        "seconds": dt, "trials_per_sec": n_trials / dt, "population": population,
        "compiles": pop.get_compiled_population_step(tc_static, population)._cache_size(),
    }

    equiv = float(max(abs(a - b) for a, b in zip(once_scores, vmap_scores)))
    speedup_vmap = results["vmapped"]["trials_per_sec"] / results["serial_recompile"]["trials_per_sec"]
    speedup_once = results["compile_once"]["trials_per_sec"] / results["serial_recompile"]["trials_per_sec"]
    ok = (
        speedup_vmap >= SPEEDUP_FLOOR
        and results["compile_once"]["compiles"] == 1
        and results["vmapped"]["compiles"] == 1
        and equiv <= SCORE_TOL
    )
    out = {
        "arch": arch, "n_trials": n_trials, "steps": steps,
        "batch": batch, "seq": seq,
        "modes": results,
        "speedup_vmapped_vs_serial": speedup_vmap,
        "speedup_compile_once_vs_serial": speedup_once,
        "equivalence_max_abs_diff": equiv,
        "pass": bool(ok),
        "paper_claim": (
            f"vmapped population engine: {speedup_vmap:.1f}x trials/sec over "
            f"serial recompile (floor {SPEEDUP_FLOOR}x); compiles "
            f"{results['serial_recompile']['compiles']} -> 1"
        ),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
