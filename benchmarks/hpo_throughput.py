"""HPO trial-engine throughput: serial-recompile vs compile-once vs vmapped
vs mesh-sharded.

The pre-refactor Experiment loop baked each proposal's hyperparameters into
the ``TrainConfig`` closure, so every trial paid a full XLA compile and the
device ran one small model at a time.  This benchmark quantifies the fixes on
the CPU smoke config:

* **serial_recompile** — the legacy path: fresh ``jax.jit(make_train_step)``
  per trial (compiles grow O(n_trials));
* **compile_once**     — hyperparameters as a traced ``HParams`` argument via
  ``get_compiled_train_step``: one compile serves every trial;
* **vmapped**          — ``repro.train.population``: K trials advance in one
  jitted ``vmap`` program (one compile per (arch, K), amortized dispatch);
* **sharded**          — the K-trial population axis split over an
  8-virtual-device CPU mesh with ``shard_map`` (K/N trials per device, still
  one compiled program).  Runs in a subprocess because the device count must
  be forced before jax initializes; the same subprocess re-times the vmapped
  engine so the sharded-vs-vmapped ratio is apples-to-apples;
* **inflight_stop**    — an ASHA-ladder workload (mixed per-trial budgets) in
  batch-synchronous flights on the mesh, with the rung rule truncating losing
  lanes mid-flight (``--inflight-stop``): freed lanes still idle until each
  flight drains;
* **refill**           — the same ladder workload as ONE continuous streaming
  flight (``--lane-refill``): a retired lane is reset in place inside the
  compiled program and immediately leases the next trial, so the inter-flight
  bubble disappears.  Wall-clock must be <= the inflight_stop row, and each
  trial's score must match the serial driver replayed at the trial's
  *effective* budget (truncations included);
* **chunked**          — **fused multi-step dispatch** (``--chunk-steps``):
  up to CHUNK_STEPS population steps run as ONE ``lax.scan`` program whose
  batches are synthesized *on device* (``repro.data.pipeline.synth_batch`` is
  bit-identical under NumPy and XLA), so the host re-enters only at event
  steps.  Measured per-step-vs-chunked across all four engines — ``vmapped``
  and ``sharded`` batch flights, the ``refill`` streaming ladder, and
  ``pbt_stream`` — at the PBT row's dispatch-bound geometry and a longer
  ladder budget unit (``CHUNK_UNIT``: chunk sizes are bounded by the gap
  between scheduler events, so trials must train long enough between
  retirements for chunks to form).  Gate (on the refill ladder, the hot-path
  engine):
  wall-clock must beat the per-step loop by ``CHUNKED_FLOOR``, scores must
  match within ``CHUNKED_SCORE_TOL`` (the engines are bit-equal by
  construction), and the host-dispatch ratio (device calls per trained step)
  must drop below 1 — the T-fold dispatch collapse this engine exists for;
* **data_ring**        — **device-resident prefetch ring** (``--data-ring``):
  host-supplied data on the fused-scan engine.  The baseline is the per-step
  host-feed loop (chunk 1: the host builds every batch and dispatches one
  step at a time — the only way host data could ride the engines before the
  ring); the ring flight runs the same trials as ``RING_CHUNK``-step fused
  scans indexing a ring of pre-staged per-lane token slabs, the host filler
  running ahead *behind* device compute.  The workload is a uniform
  one-trial-per-lane streaming flight on the sharded engine at
  ``RING_BATCH x RING_SEQ`` (more dispatch-bound than the PBT geometry): no
  lane splices mid-flight, so the lane table never changes and the row
  isolates the feed path itself.  Gate: best-of-``RING_REPS`` wall-clock
  must beat the per-step host-feed loop by ``DATA_RING_FLOOR``,
  ``overlap_frac`` (the fraction of host fill time hidden behind device
  compute) must reach ``RING_OVERLAP_FLOOR``, the ring actually filled,
  dispatches per trained step must drop below 1, and scores must match the
  per-step loop within ``CHUNKED_SCORE_TOL`` (the synth adapter is the
  in-scan synthesis bit-for-bit, so host-fed chunks change nothing about
  the math);
* **device_rules**     — **device-side decision rules** (``--device-rules``):
  the rung rule runs *inside* the fused scan (scan-carried per-lane budgets +
  per-rung loss histories), so chunk boundaries no longer clamp to rung /
  retirement event steps and a whole multi-rung ASHA ladder drains as ONE
  device dispatch, the host harvesting retirements from the scan's emitted
  event log afterwards.  Measured host-rule vs device-rule on a ladder sized
  to exactly the population (one trial per lane: with queued refills the
  device path's batched retirement harvest can reorder rung arrivals — a
  legitimate but *different* SHA schedule — so the trial-identical workload
  is what makes bit-equality a fair gate), on both the vmapped and sharded
  engines.  Gate: the device-rule flight's whole ladder costs exactly ONE
  dispatch (vmapped and sharded), scores and effective budgets match the
  host-rule path within ``CHUNKED_SCORE_TOL``, and the rule actually cut
  lanes (a ladder with nothing to truncate would gate nothing);
* **elastic_regrid**   — **elastic two-level regrid** (``--elastic-regrid``):
  at every rung boundary the survivors' full train state is re-laid-out from
  K lanes x W devices-per-lane to K' x W' (``make_lane_regrid`` +
  ``plan_regrid``), so later rungs train fewer trials wider and faster
  instead of idling freed devices.  Measured fixed-width sharded flight vs
  the elastic flight leasing an ``ElasticLanePool``, on a shrink-heavy
  ladder (one trial per lane, most lanes retiring at the first rung) at a
  heavier per-lane geometry (``ELASTIC_BATCH`` x ``ELASTIC_SEQ``) where the
  per-lane FLOP reduction dominates dispatch overhead.  Gate: at least one
  regrid fired, the pod stays fully leased after every cut (rows x width
  tiles the device count), wall-clock beats the fixed-width flight by
  ``ELASTIC_FLOOR``, scores match within ``CHUNKED_SCORE_TOL`` (resharding
  changes layout, never math) and the rung rule truncated the same trials;
* **tp_width**         — **tensor-parallel population step**
  (``--model-parallel``): ``TP_LANES`` survivors hold the whole 8-device pod
  at widths 1 / 2 / 4 on a compute-bound geometry (``TP_D_MODEL`` /
  ``TP_FF``, well above the smoke config).  Width 1 pads to one lane per
  device, so most devices burn full-model compute on frozen padding lanes;
  width W pads to 8/W rows with each live lane's heads and ff dims split W
  ways behind psum seams.  The virtual devices share the container's single
  core, so per-step wall-clock tracks TOTAL device compute — the width-2
  ratio is a direct witness that the model axis *partitions* compute (the
  pre-TP replicating regrid would time ~1.0x).  Gate: width-2 per-step
  wall-clock beats width-1 by ``TP_FLOOR``, the lowered width-2 step carries
  model-axis all-reduces while width-1 carries exactly zero, and the
  survivors' scores match across widths within ``TP_SCORE_TOL`` (width is
  layout, never math);
* **pbt_stream**       — Population-Based Training on the streaming engine
  (``--pbt-streaming``): members live in lanes, exploit is a compiled donor
  clone (``make_lane_clone``) and weights never visit the host — measured
  against the generation-barriered *serial* PBT driver (``run_pbt_serial``:
  one member at a time, host checkpoint restore + save every round) at equal
  total train steps and shared decision RNG.  Scores must match per
  (member, round); wall-clock must beat the serial driver by
  ``PBT_STREAM_FLOOR`` on the 8-virtual-device mesh; the streaming side must
  report ZERO host checkpoint round-trips;
* **pbt_async_quality** — ``--pbt-async`` drops the round gate, so by
  construction it has no serial equivalence baseline; this row quantifies
  what that costs on a longer workload: gated vs async best score, the
  clone/keep decision mix, and a *decision-lag histogram* (how many rounds
  stale each window entry behind an exploit/explore decision was — all zeros
  when gated, spread when async).  Informational — no pass criterion;
* **sha_rule_compare** — the cohort rung rule (batch-synchronous
  ``--inflight-stop`` flights) vs the staggered history rule (the refill
  engine's ``observe``) on a longer-horizon ASHA ladder: both are valid SHA
  variants that can cut *different* lanes; this row quantifies how far their
  cut counts and scores drift (informational — no pass criterion);
* **recovery**         — the crash-safety story end to end.  (a) *snapshot
  overhead*: the refill ladder with ``--snapshot-every 1`` (every live lane
  harvested to a disk-backed ``LaneSnapshotStore`` at every event boundary)
  vs snapshots off — the harvest must cost <= ``SNAPSHOT_OVERHEAD_CEIL``
  extra wall-clock AND <= ``SNAPSHOT_COST_CEIL_S`` per harvested snapshot
  (the absolute bound is the regression-proof one: a faster baseline flight
  inflates the ratio without any snapshot getting more expensive); (b)
  *quarantine*: a deterministic repeat-crash fault
  (``raise@step=...,times=...``) drives the supervised flight through its
  restart budget and the poison lane must be quarantined; (c)
  *kill/resume equivalence*: a CLI run SIGKILLed at an event boundary
  (``kill@event=K``) and resumed with ``--resume`` must report
  lanes restored from a snapshot step > 0 and end with per-trial scores
  within ``RECOVERY_SCORE_TOL`` of an uninterrupted run's.

All engines fold a per-trial ``stream`` id into the batch PRNG (independent
per-trial data streams), so scores must agree trial-for-trial across engines.

Emits ``BENCH_hpo_throughput.json`` (repo root) and returns the result dict
for ``benchmarks/run.py``.  Pass criteria: vmapped >= 3x serial trials/sec,
sharded >= 1x the vmapped trials/sec on the same mesh, compile-once /
vmapped / sharded each compile exactly once, vmapped + sharded scores match
the compile-once scores within tolerance, refill wall-clock no worse than the
inflight_stop flights (ratio floor ``REFILL_FLOOR`` absorbs shared-runner
timer noise), and refill scores match the serial replay within tolerance.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

OUT_PATH = "BENCH_hpo_throughput.json"
SPEEDUP_FLOOR = 3.0
SHARDED_FLOOR = 1.0  # sharded engine must not be slower than vmapped
# refill must beat (or at worst tie, within shared-runner timer noise) the
# batch-synchronous inflight-stop flights on the same ladder; the committed
# run shows ~1.4-1.5x
REFILL_FLOOR = 0.95
SCORE_TOL = 1e-3
MESH_DEVICES = 8
# fused multi-step dispatch: chunk length for the chunked row, its wall-clock
# floor against the per-step refill row on the same ladder (the committed run
# shows ~2-3.5x; host batch-building and per-step dispatch dominate at smoke
# scale), and its score tolerance (the scan engine is bit-equal to the
# per-step loop by construction, so this is the acceptance tolerance, not an
# engine-noise tolerance)
CHUNK_STEPS = 8
CHUNKED_FLOOR = 1.5
CHUNKED_SCORE_TOL = 1e-6
# budget unit (steps) for the chunked row's ladder: chunk sizes are bounded
# by the gap between scheduler events (retirements, rung boundaries), so the
# trials must train long enough between events for T-step chunks to form at
# all — the REFILL_UNIT=2 ladder retires a lane nearly every step and no
# dispatch scheme could fuse across that.  Same ASHA shape, longer unit.
CHUNK_UNIT = 8
# device-resident prefetch ring: the ring-fed fused flight vs the per-step
# host-feed loop on a uniform one-trial-per-lane streaming flight (no lane
# splices, so the row isolates the feed path; splice invalidation is covered
# by the crash/refill tests).  The ring removes BOTH the per-step dispatch
# and the synchronous host batch build from the hot loop; the overlap floor
# is the acceptance bar for the ring actually hiding host fill behind device
# compute rather than serializing it at chunk boundaries.  RING_BATCH x
# RING_SEQ is even more dispatch-bound than the PBT geometry — the regime
# the ring exists for — and wall-clock is best-of-RING_REPS because the
# shared-CPU container's scheduler noise swamps single-shot timings.
DATA_RING_FLOOR = 2.0
RING_OVERLAP_FLOOR = 0.5
RING_WINDOWS = 4
RING_CHUNK = 32
RING_UNITS = 16
RING_BATCH = 1
RING_SEQ = 8
RING_REPS = 5
# async-PBT quality probe: longer horizon than the equivalence row so the
# gated and staggered rules have room to diverge
PBT_QUALITY_ROUNDS = 5
# ASHA-ladder workload for the inflight-stop vs lane-refill comparison:
# many cheap rung-0 trials, a few expensive promotions (units of REFILL_UNIT
# steps).  Batch-synchronous flights pad every flight to its max surviving
# budget; the refill engine packs retired lanes instead.
REFILL_UNIT = 2            # train steps per budget unit
REFILL_LADDER = [1] * 8 + [2] * 4 + [4] * 2 + [8] * 2
# the rung boundary sits at 8 steps: on this synthetic LM the per-step batch
# loss only orders by lr reliably from ~8 steps on (earlier it is transient
# noise and the rule would cut at random)
REFILL_MIN_ITER_UNITS = 4

# device-rule row: a multi-rung ladder sized to exactly the population (one
# trial per lane — no refill contention, so host-rule and device-rule flights
# lease identical trials and must score bit-equal; see the docstring bullet),
# in units of CHUNK_UNIT steps.  eta=2 with min_iter=CHUNK_UNIT puts rung
# boundaries at 8 and 16 steps inside the 32-step max budget, and the chunk
# covers the whole ladder so the device path drains in ONE dispatch while the
# host-rule path still re-enters at every event step.
DEVRULES_LADDER = [1, 1, 2, 2, 2, 4, 4, 4]
DEVRULES_CHUNK = 32

# elastic-regrid row: a shrink-heavy ladder (one trial per lane, most lanes
# retiring at the first rung) at a heavier per-lane batch geometry than the
# other rows — the row measures the *compute* the regrid removes from later
# rungs (fewer, wider lanes), which at the smoke batch sizes is drowned by
# per-op dispatch overheads that do not scale with lane count.  Rung-0 lanes
# get a deliberately dead lr so the promotions reliably survive the cut and
# the flight actually regrids.  The fixed-width baseline runs the same ladder
# sharded over the same mesh; the two flights do identical work up to the
# first cut, so the whole-flight ratio is attributable to the later rungs.
ELASTIC_UNITS = [1, 1, 1, 1, 2, 2, 8, 8]
ELASTIC_LR = {1: 1e-5, 2: 1e-3, 8: 2e-3}
ELASTIC_BATCH = 8
ELASTIC_SEQ = 64
# The row's model swaps the smoke GQA geometry (4 heads, kv 2 — TP-degenerate:
# kv%width blocks attention sharding past width 2) for MHA 8x8 heads, so every
# pool width the planner picks (2/4/8) shards attention AND the MLP.  Later
# rungs then run width-local compute on the survivors' rows — what the regrid
# actually removes — instead of rows of mostly-replicated math.
ELASTIC_OVERRIDES = {"n_heads": 8, "n_kv_heads": 8, "head_dim": 8}
# committed 8-virtual-device run shows ~2.3x; the floor absorbs CI timer noise
ELASTIC_FLOOR = 1.1

# tensor-parallel width row: TP_LANES survivors holding the full 8-device pod
# at widths 1 / 2 / 4.  Width 1 pads to one lane per device (6 padding lanes
# burning full-model compute); width W pads to 8/W rows with each live lane's
# heads/ff split W ways, so total device compute — which IS wall-clock on the
# single-core container — drops roughly with the padded lane count times the
# width-local shard fraction.  The floor gates that the model axis carries
# compute (pure replication would time ~1.0x); scores must not move (width is
# layout, never math).  Geometry is compute-bound: d_model/ff well above the
# smoke config so matmuls dominate dispatch.
TP_LANES = 2
TP_STEPS = 4
TP_REPS = 3
TP_D_MODEL = 256
TP_FF = 1024
TP_BATCH = 4
TP_SEQ = 32
TP_FLOOR = 1.3
TP_SCORE_TOL = 1e-5

# streaming PBT vs the generation-barriered serial driver: equal total steps,
# shared RNG.  The serial baseline runs K*ROUNDS rounds one member at a time
# with 2 host checkpoint round-trips each; streaming runs ROUNDS*STEPS pop
# steps with exploit as a device clone.  The committed 8-virtual-device run
# shows well above the floor.
PBT_STREAM_FLOOR = 1.2
PBT_ROUNDS = 3
PBT_ROUND_STEPS = 4
# the PBT row times the dispatch/checkpoint overheads the streaming engine
# eliminates, so it uses a smaller batch geometry than the throughput rows
# (per-step compute on the 2-core CPU container would otherwise drown them);
# the vmapped engine runs the flight — on virtual devices the sharded twin
# adds only cross-device dispatch overhead at this scale and is covered by
# the equivalence tests instead
PBT_BATCH = 2
PBT_SEQ = 16
# streaming PBT reproduces the generation-barriered serial driver bit-for-bit
# on this workload (shared decision RNG, shared per-member streams/init keys,
# donor copies at round boundaries) — gate at the acceptance tolerance, well
# below the engine-equivalence SCORE_TOL
PBT_SCORE_TOL = 1e-6
# lr capped below the divergence zone so the comparison is not hostage to a
# borderline NaN flipping between engines
PBT_SPACE = [
    {"name": "learning_rate", "type": "float", "range": [1e-4, 5e-3], "scale": "log"},
    {"name": "weight_decay", "type": "float", "range": [0.0, 0.2]},
    {"name": "b2", "type": "float", "range": [0.9, 0.99]},
]

# longer-horizon ladder for the cohort-vs-staggered rung-rule comparison
# (units of REFILL_UNIT steps; boundaries at 2/6/18 steps with eta=3)
LONG_LADDER = [1] * 6 + [3] * 3 + [9] * 2 + [27] * 1
LONG_MIN_ITER_UNITS = 1

# crash-safety row: per-event lane harvests must stay cheap relative to the
# ladder (the snapshot is one lane's smoke-model state; device_get + npz),
# and the kill/resume round trip must reproduce the uninterrupted scores.
# The ratio ceiling is wider than it once was for an honest reason: the
# prefetch-ahead host feed shortened the snapshot-free per-step flight, so
# the same fixed ~10ms/harvest now reads as a larger *fraction* of this
# sub-second probe — the absolute per-snapshot cost is therefore gated too
# (the quantity a cost regression would actually move).
SNAPSHOT_OVERHEAD_CEIL = 1.40
SNAPSHOT_COST_CEIL_S = 0.030  # wall-clock per harvested snapshot
RECOVERY_SCORE_TOL = 1e-6
RECOVERY_KILL_EVENT = 3


def _sample_configs(n_trials: int, seed: int):
    from repro.core.search_space import SearchSpace
    from repro.launch.hpo import SPACE

    space = SearchSpace.from_json(SPACE)
    rng = np.random.default_rng(seed)
    # explicit per-trial stream ids: every engine (serial / vmapped / sharded)
    # then trains trial i on the same independent data sequence
    return [dict(space.sample(rng), stream=i) for i in range(n_trials)]


# ASHA promotes its *best* trials, so big-budget jobs usually carry good
# configs: lr improves with budget (by step 8 on this synthetic LM, higher lr
# means lower loss) so promotions stay on top at the rung the way a real ASHA
# run's do.  One of the two top promotions is deliberately *bad* — the rung
# rule must have something real to cut mid-flight in both engines.  Its lr
# sits well below the rung-0 lrs: at the 8-step boundary the counter-based
# stream's batch-to-batch noise is ~the gap between adjacent ladder lrs, so
# only a wide gap orders reliably against the rung history.
_LADDER_LR = {1: 2e-4, 2: 5e-4, 4: 1e-3, 8: 2e-3}
_LADDER_BAD_LR = 1e-5


def _ladder_workload(seed: int):
    """Deterministic mixed-budget configs (shared by probe and main process)."""
    cfgs = _sample_configs(len(REFILL_LADDER), seed + 1)
    order = np.random.default_rng(seed + 1).permutation(len(REFILL_LADDER))
    units = np.asarray(REFILL_LADDER)[order]
    bad_promotion = int(np.flatnonzero(units == max(REFILL_LADDER))[-1])
    for i, (c, u) in enumerate(zip(cfgs, units)):
        c["n_iterations"] = int(u)
        c["learning_rate"] = _LADDER_LR[int(u)] * (1.0 + 0.05 * (i % 3))
        # short warmup for every budget: a promotion's longer schedule must
        # not leave it crawling at rung boundaries it already passed once
        c["warmup_frac"] = 0.05
    cfgs[bad_promotion]["learning_rate"] = _LADDER_BAD_LR
    return cfgs


def _refill_hook():
    from repro.core.proposer.early_stop import InFlightSuccessiveHalving

    return InFlightSuccessiveHalving(
        eta=2.0, min_iter=REFILL_MIN_ITER_UNITS * REFILL_UNIT,
        max_iter=max(REFILL_LADDER) * REFILL_UNIT)


def _devrules_workload(seed: int, population: int):
    """One trial per lane, budgets cycled from DEVRULES_LADDER, with one
    deliberately bad max-budget promotion for the rung rule to cut."""
    units = [DEVRULES_LADDER[i % len(DEVRULES_LADDER)]
             for i in range(population)]
    cfgs = _sample_configs(population, seed + 5)
    bad_promotion = int(np.flatnonzero(np.asarray(units) == max(units))[-1])
    for i, (c, u) in enumerate(zip(cfgs, units)):
        c["n_iterations"] = int(u)
        c["learning_rate"] = _LADDER_LR[int(u)] * (1.0 + 0.05 * (i % 3))
        c["warmup_frac"] = 0.05
    cfgs[bad_promotion]["learning_rate"] = _LADDER_BAD_LR
    return cfgs


_LONG_LR = {1: 2e-4, 3: 5e-4, 9: 1e-3, 27: 2e-3}


def _elastic_workload(seed: int, population: int):
    """One trial per lane, budgets from ELASTIC_UNITS: rung-0 lanes carry a
    dead lr, promotions a live one, so the first boundary reliably leaves a
    strict subset of lanes alive and every later rung runs post-regrid."""
    cfgs = _sample_configs(population, seed + 7)
    for i, (c, u) in enumerate(zip(cfgs, ELASTIC_UNITS)):
        c["n_iterations"] = int(u)
        c["learning_rate"] = ELASTIC_LR[int(u)] * (1.0 + 0.05 * (i % 3))
        c["warmup_frac"] = 0.05
    return cfgs


def _long_ladder_workload(seed: int):
    """Longer-horizon mixed-budget configs for the rung-rule comparison."""
    cfgs = _sample_configs(len(LONG_LADDER), seed + 2)
    order = np.random.default_rng(seed + 2).permutation(len(LONG_LADDER))
    units = np.asarray(LONG_LADDER)[order]
    bad_promotion = int(np.flatnonzero(units == max(LONG_LADDER))[-1])
    for i, (c, u) in enumerate(zip(cfgs, units)):
        c["n_iterations"] = int(u)
        c["learning_rate"] = _LONG_LR[int(u)] * (1.0 + 0.05 * (i % 3))
        c["warmup_frac"] = 0.05
    cfgs[bad_promotion]["learning_rate"] = _LONG_LR[1]
    return cfgs


def _long_hook():
    from repro.core.proposer.early_stop import InFlightSuccessiveHalving

    return InFlightSuccessiveHalving(
        eta=3.0, min_iter=LONG_MIN_ITER_UNITS * REFILL_UNIT,
        max_iter=max(LONG_LADDER) * REFILL_UNIT)


def _dispatch_row(seconds: float, trial) -> dict:
    """One chunked-row engine entry — single source of the field shape
    ``run()`` consumes for every mode (per_step AND fused, all four engines)."""
    return {
        "seconds": seconds,
        "dispatches": trial.n_dispatches,
        "trained_steps": trial.n_train_steps,
        "dispatches_per_step": trial.n_dispatches / max(1, trial.n_train_steps),
    }


def _feed_scheduler(cfgs):
    """The shared streaming-feed adapter (fixed queue, ends when drained)."""
    from repro.core.resource.vectorized import QueueFeedScheduler

    return QueueFeedScheduler(cfgs)


def _probe_sharded(arch: str, n_trials: int, population: int, steps: int,
                   batch: int, seq: int, seed: int) -> dict:
    """Time vmapped + sharded inside a fresh process with a forced
    MESH_DEVICES-wide virtual CPU mesh (must happen before jax init)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + f" --xla_force_host_platform_device_count={MESH_DEVICES}"
    ).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.hpo_throughput", "--probe-sharded",
           arch, str(n_trials), str(population), str(steps), str(batch),
           str(seq), str(seed)]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"sharded probe failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.splitlines()[-1])


def _probe_main(argv) -> None:
    arch, n_trials, population, steps, batch, seq, seed = (
        argv[0], *(int(x) for x in argv[1:]))
    import jax

    from repro.distributed.sharding import population_mesh
    from repro.launch.hpo import PopulationTrial
    from repro.train import population as pop

    cfgs = _sample_configs(n_trials, seed)
    trial = PopulationTrial(arch, steps, batch, seq, seed, population=population)
    tc, _ = trial._setup()
    mesh = population_mesh()
    res = {"n_devices": jax.device_count()}
    for name, kw in (("vmapped", {}), ("sharded", {"mesh": mesh})):
        pop.clear_population_cache()
        t0 = time.time()
        scores = []
        for i in range(0, n_trials, population):
            scores.extend(trial.run_population(cfgs[i:i + population], **kw))
        dt = time.time() - t0
        if name == "sharded":
            compiles = pop.get_compiled_sharded_population_step(
                tc, population, mesh=mesh, per_trial_batch=True)._cache_size()
        else:
            compiles = pop.get_compiled_population_step(
                tc, population, per_trial_batch=True)._cache_size()
        res[name] = {"seconds": dt, "trials_per_sec": n_trials / dt,
                     "population": population, "compiles": compiles,
                     "scores": scores}

    # -- inflight-stop flights vs one continuous refill flight (same mesh) -----
    lcfgs = _ladder_workload(seed)
    # warm the step + lane-op compiles so both rows time pre-compiled programs
    # (the streaming engine uses the masked init for multi-lane rounds and the
    # single-lane splice for one-at-a-time refills — warm both)
    warm = PopulationTrial(arch, REFILL_UNIT, batch, seq, seed,
                           population=population, refill_idle_grace_s=0.0)
    warm.run_population([], mesh=mesh, scheduler=_feed_scheduler(
        _sample_configs(2, seed)))
    wkeys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(0),
        jax.numpy.arange(population, dtype=jax.numpy.uint32))
    wst = pop.shard_population_state(
        pop.init_population_state_from_keys(wkeys, tc), mesh)
    pop.get_compiled_lane_op(tc, population, "splice", mesh=mesh)(
        wst, jax.numpy.asarray(0, jax.numpy.int32), jax.random.PRNGKey(1))

    itrial = PopulationTrial(arch, REFILL_UNIT, batch, seq, seed,
                             population=population, early_stop=_refill_hook())
    t0 = time.time()
    for i in range(0, len(lcfgs), population):
        itrial.run_population(lcfgs[i:i + population], mesh=mesh)
    dt = time.time() - t0
    # scores are not shipped: truncation makes them budget-dependent, and only
    # the refill row's scores are checked (against the serial replay)
    res["inflight_stop"] = {
        "seconds": dt, "trials_per_sec": len(lcfgs) / dt,
        "trials": len(lcfgs), "population": population,
        "truncated": itrial.early_stop.n_truncated,
        "reclaimed": itrial.early_stop.n_reclaimed,
    }

    rtrial = PopulationTrial(arch, REFILL_UNIT, batch, seq, seed,
                             population=population, early_stop=_refill_hook(),
                             refill_idle_grace_s=0.0)
    feed = _feed_scheduler(lcfgs)
    t0 = time.time()
    rtrial.run_population([], mesh=mesh, scheduler=feed)
    dt = time.time() - t0
    res["refill"] = {
        "seconds": dt, "trials_per_sec": len(lcfgs) / dt,
        "trials": len(lcfgs), "population": population,
        "truncated": rtrial.early_stop.n_truncated,
        "refills": rtrial.n_refills,
        "flight_steps": rtrial.last_flight_steps,
        "dispatches": rtrial.n_dispatches,
        "trained_steps": rtrial.n_train_steps,
        "scores": feed.ordered_scores(len(lcfgs)),
        "eff_steps": [int(feed.extras[i]["steps"]) for i in range(len(lcfgs))],
        "diverged": [bool(feed.extras[i]["diverged"]) for i in range(len(lcfgs))],
    }

    # -- fused chunked dispatch: per-step vs chunked across all four engines ---
    # Dispatch-bound geometry (the PBT row's) and a longer budget unit
    # (CHUNK_UNIT): the row measures the per-step dispatch + host-batch-
    # synthesis overheads that chunking eliminates, on a ladder whose trials
    # train long enough between scheduler events for chunks to form.  Each
    # (mode, chunk) pair runs once to warm every power-of-two scan compile,
    # then times a fresh trial on the same ladder.
    from repro.core.proposer.early_stop import InFlightSuccessiveHalving

    def _chunk_hook():
        return InFlightSuccessiveHalving(
            eta=2.0, min_iter=REFILL_MIN_ITER_UNITS * CHUNK_UNIT,
            max_iter=max(REFILL_LADDER) * CHUNK_UNIT)

    def _chunk_trial(chunk):
        return PopulationTrial(
            arch, CHUNK_UNIT, PBT_BATCH, PBT_SEQ, seed,
            population=population, chunk_steps=chunk,
            early_stop=_chunk_hook(), refill_idle_grace_s=0.0)

    def _timed_pair(measure, equiv=None):
        """The ONE pairing protocol every engine mode goes through:
        ``measure(chunk) -> (seconds, scores, trial)`` is timed at chunk 1
        (per_step) and CHUNK_STEPS (fused), rows share ``_dispatch_row``'s
        shape, and ``equiv`` compares the two score sets (default: listwise
        max abs diff)."""
        out = {}
        scores = {}
        for name, chunk in (("per_step", 1), ("fused", CHUNK_STEPS)):
            seconds, scores[name], trial = measure(chunk)
            out[name] = _dispatch_row(seconds, trial)
        out["speedup"] = out["per_step"]["seconds"] / out["fused"]["seconds"]
        eq = equiv or (lambda a, b: float(max(abs(x - y)
                                              for x, y in zip(a, b))))
        out["equivalence_max_abs_diff"] = eq(scores["per_step"],
                                             scores["fused"])
        return out

    def _ladder_measure(run_of):
        """Warm a fresh trial (compiles + tracing), then time another;
        ``run_of(trial)`` drives the ladder and returns ordered scores."""
        def measure(chunk):
            run_of(_chunk_trial(chunk))
            trial = _chunk_trial(chunk)
            t0 = time.time()
            scores = run_of(trial)
            return time.time() - t0, scores, trial
        return measure

    def _batch_flights(mkw):
        def run(trial):
            scores = []
            for i in range(0, len(lcfgs), population):
                scores.extend(
                    trial.run_population(lcfgs[i:i + population], **mkw))
            return scores
        return run

    def _refill_flight(trial):
        feedc = _feed_scheduler(lcfgs)
        trial.run_population([], mesh=mesh, scheduler=feedc)
        return feedc.ordered_scores(len(lcfgs))

    res["chunked"] = {
        "chunk_steps": CHUNK_STEPS, "trials": len(lcfgs),
        "budget_unit": CHUNK_UNIT,
        "population": population, "batch": PBT_BATCH, "seq": PBT_SEQ,
        "vmapped": _timed_pair(_ladder_measure(_batch_flights({}))),
        "sharded": _timed_pair(_ladder_measure(_batch_flights({"mesh": mesh}))),
        "refill": _timed_pair(_ladder_measure(_refill_flight)),
    }

    # -- device-resident prefetch ring: host-fed data on the fused scan --------
    # Per-step host-feed baseline (chunk 1, no ring: the host builds every
    # batch and dispatches one step at a time) vs the ring-fed fused flight
    # (chunk RING_CHUNK, --data-ring: the scan indexes pre-staged device
    # slabs the host filler keeps ahead of consumption).  Uniform budgets,
    # one trial per lane on the sharded streaming engine: no lane splices
    # mid-flight, so the ring's lane table never changes and the row isolates
    # the feed path itself (splice-heavy invalidation is covered by the
    # crash/refill tests, not this row).  RING_BATCH x RING_SEQ is even more
    # dispatch-bound than the PBT geometry — the regime the ring exists for.
    # The synth adapter is the in-scan synthesis bit-for-bit, so scores must
    # not move.  Best-of-RING_REPS wall-clock: on a shared-CPU container the
    # scheduler noise on single-shot timings exceeds the effect under test.
    rcfgs = _sample_configs(population, seed + 9)
    for cfg in rcfgs:
        cfg["n_iterations"] = RING_UNITS
        cfg["warmup_frac"] = 0.05

    def _ring_trial(chunk, ring):
        return PopulationTrial(
            arch, CHUNK_UNIT, RING_BATCH, RING_SEQ, seed,
            population=population, chunk_steps=chunk,
            refill_idle_grace_s=0.0,
            data_ring=ring, ring_windows=RING_WINDOWS)

    def _ring_flight(trial):
        feedr = _feed_scheduler([dict(c) for c in rcfgs])
        trial.run_population([], mesh=mesh, scheduler=feedr)
        return feedr.ordered_scores(len(rcfgs))

    def _ring_measure(chunk, ring):
        _ring_flight(_ring_trial(chunk, ring))  # warm compiles + ring path
        best = scores = trial = None
        for _ in range(RING_REPS):
            cand = _ring_trial(chunk, ring)
            t0 = time.time()
            s = _ring_flight(cand)
            dt = time.time() - t0
            if best is None or dt < best:
                best, scores, trial = dt, s, cand
        return best, scores, trial

    ring_ps_s, ring_ps_scores, ring_ps_trial = _ring_measure(1, False)
    ring_s, ring_scores, ring_trial = _ring_measure(RING_CHUNK, True)
    res["data_ring"] = {
        "chunk_steps": RING_CHUNK, "ring_windows": RING_WINDOWS,
        "trials": len(rcfgs), "population": population,
        "budget_unit": CHUNK_UNIT, "units_per_trial": RING_UNITS,
        "batch": RING_BATCH, "seq": RING_SEQ, "reps": RING_REPS,
        "per_step": _dispatch_row(ring_ps_s, ring_ps_trial),
        "ring": dict(
            _dispatch_row(ring_s, ring_trial),
            ring_fills=ring_trial.n_ring_fills,
            overlap_frac=ring_trial.ring_overlap_frac,
            fill_wait_s=ring_trial.ring_fill_wait_s,
        ),
        "speedup": ring_ps_s / ring_s,
        "equivalence_max_abs_diff": float(max(
            abs(a - b) for a, b in zip(ring_ps_scores, ring_scores))),
    }

    # -- streaming PBT vs generation-barriered serial PBT ----------------------
    from repro.core.experiment import Experiment
    from repro.core.proposer import make_proposer
    from repro.core.search_space import SearchSpace
    from repro.launch.hpo import run_pbt_serial

    pbt_space = SearchSpace.from_json(PBT_SPACE)

    def _pbt_proposer():
        return make_proposer(
            "pbt", pbt_space, maximize=True, seed=seed + 3,
            population=population, n_generations=PBT_ROUNDS, streaming=True,
            quantile=0.25)

    def _pbt_stream(n_generations, chunk=1):
        trial = PopulationTrial(arch, PBT_ROUND_STEPS, PBT_BATCH, PBT_SEQ,
                                seed, population=population,
                                per_trial_init=True, chunk_steps=chunk)
        exp = Experiment({
            "proposer": "pbt", "parameter_config": PBT_SPACE,
            "n_samples": population * n_generations, "n_parallel": population,
            "target": "max", "seed": seed + 3, "population": population,
            "n_generations": n_generations, "streaming": True,
            "quantile": 0.25, "resource": "vectorized", "lane_refill": True},
            trial)
        scores = {}
        exp.add_result_callback(lambda job: scores.__setitem__(
            (job.config.get("pbt_member"), job.config.get("pbt_round")),
            job.result.score if job.result else None))
        t0 = time.time()
        exp.run()
        return time.time() - t0, scores, trial, exp

    # warm every compiled program both drivers touch so the row times steady
    # state: a one-round streaming experiment (pop step + splice + clone at
    # the PBT batch geometry) and one serial hparam-step call
    _pbt_stream(1)
    wtrial = PopulationTrial(arch, PBT_ROUND_STEPS, PBT_BATCH, PBT_SEQ, seed,
                             per_trial_init=True)
    wtrial.serial_score_at({"learning_rate": 1e-3, "stream": -7}, 1)
    wstate = pop.init_population_state_from_keys(
        jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            jax.random.PRNGKey(0),
            jax.numpy.arange(population, dtype=jax.numpy.uint32)), tc)
    pop.get_compiled_lane_op(tc, population, "clone")(
        wstate, jax.numpy.zeros(population, bool),
        jax.numpy.arange(population, dtype=jax.numpy.int32))

    ptrial_serial = PopulationTrial(arch, PBT_ROUND_STEPS, PBT_BATCH, PBT_SEQ,
                                    seed, per_trial_init=True)
    t0 = time.time()
    serial_pbt = run_pbt_serial(ptrial_serial, _pbt_proposer())
    dt_serial = time.time() - t0

    dt_stream, stream_pbt, ptrial, exp = _pbt_stream(PBT_ROUNDS)
    pbt_equiv = max(
        abs(stream_pbt[k2] - serial_pbt[k2]) for k2 in serial_pbt
    ) if set(stream_pbt) == set(serial_pbt) else float("inf")
    res["pbt_stream"] = {
        "serial_seconds": dt_serial, "stream_seconds": dt_stream,
        "speedup": dt_serial / dt_stream,
        "members": population, "rounds": PBT_ROUNDS,
        "round_steps": PBT_ROUND_STEPS,
        "batch": PBT_BATCH, "seq": PBT_SEQ,
        "clones": ptrial.n_clones, "splices": ptrial.n_splices,
        "keeps": exp.proposer.lifecycle_hook().n_keeps,
        "donor_waits": ptrial.n_donor_waits
                       + exp.proposer.lifecycle_hook().n_donor_waits,
        "serial_host_ckpt_roundtrips": ptrial_serial.n_host_ckpt_roundtrips,
        "stream_host_ckpt_roundtrips": ptrial.n_host_ckpt_roundtrips,
        "equivalence_max_abs_diff": pbt_equiv,
    }

    # chunked PBT: same streaming engine, rounds dispatched as fused chunks
    # (round ends are host-known events, so decisions are unchanged) — same
    # pairing protocol as the other three engines, dict-keyed scores
    _pbt_stream(1, chunk=CHUNK_STEPS)  # warm the PBT-geometry scan compiles

    def _pbt_measure(chunk):
        dtc, sc, ptrialc, _ = _pbt_stream(PBT_ROUNDS, chunk=chunk)
        return dtc, sc, ptrialc

    res["chunked"]["pbt_stream"] = _timed_pair(
        _pbt_measure,
        equiv=lambda a, b: float(max(abs(a[k2] - b[k2]) for k2 in a))
        if set(a) == set(b) else float("inf"))

    # -- device-side decision rules: the whole ladder as ONE dispatch ----------
    # Host-rule vs device-rule on a trial-per-lane ladder (no refill
    # contention — with queued trials the device path's batched retirement
    # harvest could reorder rung arrivals into a different, equally valid SHA
    # schedule), chunk covering the max budget: the host path still stops at
    # every rung boundary / budget end, the device path runs start-to-drain
    # as one scan and only harvests the emitted event log.
    devcfgs = _devrules_workload(seed, population)

    def _devrules_hook():
        return InFlightSuccessiveHalving(
            eta=2.0, min_iter=CHUNK_UNIT,
            max_iter=max(DEVRULES_LADDER) * CHUNK_UNIT)

    def _devrules_trial(device):
        return PopulationTrial(
            arch, CHUNK_UNIT, PBT_BATCH, PBT_SEQ, seed,
            population=population, chunk_steps=DEVRULES_CHUNK,
            early_stop=_devrules_hook(), refill_idle_grace_s=0.0,
            device_rules=device)

    def _devrules_cell(device, mkw):
        def flight():
            trial = _devrules_trial(device)
            feedd = _feed_scheduler(devcfgs)
            t0 = time.time()
            trial.run_population([], scheduler=feedd, **mkw)
            return time.time() - t0, feedd, trial
        flight()  # warm the scan / rule-state compiles
        dt, feedd, trial = flight()
        row = _dispatch_row(dt, trial)
        row["ladder_device_dispatches"] = trial.ladder_dispatches
        row["truncated"] = trial.early_stop.n_truncated
        row["reclaimed"] = trial.early_stop.n_reclaimed
        row["scores"] = feedd.ordered_scores(len(devcfgs))
        row["eff_steps"] = [int(feedd.extras[i]["steps"])
                            for i in range(len(devcfgs))]
        return row

    def _devrules_pair(host, dev):
        return {
            "host": host, "device": dev,
            "speedup": host["seconds"] / dev["seconds"],
            "equivalence_max_abs_diff": float(max(
                abs(a - b) for a, b in zip(host["scores"], dev["scores"]))),
            "eff_steps_equal": host["eff_steps"] == dev["eff_steps"],
            "truncated_equal": host["truncated"] == dev["truncated"],
        }

    res["device_rules"] = {
        "trials": len(devcfgs), "population": population,
        "ladder_units": DEVRULES_LADDER, "budget_unit": CHUNK_UNIT,
        "chunk_steps": DEVRULES_CHUNK,
        "vmapped": _devrules_pair(_devrules_cell(False, {}),
                                  _devrules_cell(True, {})),
        "sharded": _devrules_pair(_devrules_cell(False, {"mesh": mesh}),
                                  _devrules_cell(True, {"mesh": mesh})),
    }

    # -- elastic two-level regrid: survivors absorb freed devices --------------
    # Fixed-width sharded baseline vs the elastic engine with a leased
    # ElasticLanePool on the same shrink-heavy ladder: identical work up to
    # the first cut, then the elastic flight trains fewer, wider lanes.
    from repro.core.resource.sharded import ElasticLanePool

    ecfgs = _elastic_workload(seed, population)

    def _elastic_hook():
        return InFlightSuccessiveHalving(
            eta=2.0, min_iter=CHUNK_UNIT,
            max_iter=max(ELASTIC_UNITS) * CHUNK_UNIT)

    def _elastic_trial(elastic):
        return PopulationTrial(
            arch, CHUNK_UNIT, ELASTIC_BATCH, ELASTIC_SEQ, seed,
            population=population, chunk_steps=CHUNK_STEPS,
            early_stop=_elastic_hook(), refill_idle_grace_s=0.0,
            elastic_regrid=elastic, model_overrides=ELASTIC_OVERRIDES)

    def _fixed_flight():
        trial = _elastic_trial(False)
        t0 = time.time()
        scores = trial.run_population(list(ecfgs), mesh=mesh)
        return time.time() - t0, scores, trial

    def _elastic_flight():
        trial = _elastic_trial(True)
        pool = ElasticLanePool()
        t0 = time.time()
        scores = trial.run_population(list(ecfgs), elastic=pool)
        return time.time() - t0, scores, trial, pool

    _fixed_flight()    # warm the sharded step/scan compiles at this geometry
    _elastic_flight()  # warm the per-K elastic programs + regrid gathers
    fixed_s, fixed_scores, ftrial = _fixed_flight()
    elastic_s, elastic_scores, etrial, pool = _elastic_flight()
    n_dev = jax.device_count()
    res["elastic_regrid"] = {
        "trials": len(ecfgs), "population": population,
        "ladder_units": ELASTIC_UNITS, "budget_unit": CHUNK_UNIT,
        "batch": ELASTIC_BATCH, "seq": ELASTIC_SEQ,
        "chunk_steps": CHUNK_STEPS, "n_devices": n_dev,
        "model_overrides": ELASTIC_OVERRIDES,
        "per_rung_step_time_s": etrial.per_rung_step_time_s,
        "fixed_seconds": fixed_s, "elastic_seconds": elastic_s,
        "later_rung_speedup": fixed_s / elastic_s,
        "regrids": etrial.n_regrids,
        "lane_width_history": etrial.lane_width_history,
        "pool_width_history": pool.width_history,
        # rows = n/width device rows, each carrying lanes/rows trials: the
        # pod is fully re-leased after every cut, no partial rows
        "full_occupancy": all(
            n_dev % w == 0 and l % (n_dev // w) == 0
            for l, w in etrial.lane_width_history),
        "equivalence_max_abs_diff": float(max(
            abs(a - b) for a, b in zip(fixed_scores, elastic_scores))),
        "truncated_equal": (ftrial.early_stop.n_truncated
                            == etrial.early_stop.n_truncated),
    }

    # -- tensor-parallel width: per-step wall-clock for survivors on a full pod
    # TP_LANES survivors hold the whole 8-device pod.  At width 1 the flight
    # pads to one lane per device (rows == devices), so 6 of 8 devices burn
    # full-model compute on frozen padding lanes; at width W the pod regrids
    # to 8/W rows — fewer padding lanes, each live lane computing on
    # width-local shards (heads/W, ff/W) with psum seams.  On this container
    # the virtual devices share one core, so wall-clock tracks TOTAL device
    # compute: the per-step ratio is therefore a direct witness that the
    # model axis partitions compute — a replicating model axis (the pre-TP
    # regrid) would keep every device at full-model cost and time ~1.0x.
    # The geometry is deliberately compute-bound (bigger d_model/ff than the
    # smoke config) so matmul work, not dispatch, dominates the step.
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.data.pipeline import SyntheticLM
    from repro.optim.hparams import hparams_from_config, stack_hparams

    jnp = jax.numpy
    tp_model = dataclasses.replace(
        get_smoke_config(arch), name=f"{arch}-tpbench",
        d_model=TP_D_MODEL, head_dim=TP_D_MODEL // 4, d_ff=TP_FF)
    tp_tc = TrainConfig(model=tp_model, parallel=ParallelConfig(remat="none"),
                        learning_rate=1e-3, warmup_steps=1,
                        total_steps=TP_STEPS, seed=seed)
    tp_data = SyntheticLM(tp_model.vocab_size, TP_SEQ, TP_BATCH, seed=seed)
    tp_batches = [tp_data.make_batch(s, stream=0) for s in range(TP_STEPS)]

    def _tp_cell(width, count_psums=True):
        m = population_mesh(width=None if width == 1 else width)
        k = pop.pad_population(TP_LANES, m)
        # live lanes carry distinct lrs (a trivial equivalence would not
        # notice a lane permutation); padding lanes freeze at budget 0
        php = stack_hparams([
            hparams_from_config(dataclasses.replace(
                tp_tc, learning_rate=1e-3 * (1.0 + 0.1 * i),
                total_steps=TP_STEPS if i < TP_LANES else 0))
            for i in range(k)])
        step = pop.get_compiled_sharded_population_step(tp_tc, k, mesh=m)

        def _flight():
            keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
                jax.random.PRNGKey(seed),
                jnp.arange(k, dtype=jnp.uint32))
            st = pop.shard_population_state(
                pop.init_population_state_from_keys(keys, tp_tc), m,
                tc=tp_tc)
            jax.block_until_ready(st)
            t0 = time.perf_counter()
            for b in tp_batches:
                st, _ = step(st, b, php)
            jax.block_until_ready(st["last_loss"])
            return (time.perf_counter() - t0) / TP_STEPS, st

        _flight()  # warm the compile + placement
        per_step, st = _flight()
        for _ in range(TP_REPS - 1):
            per_step = min(per_step, _flight()[0])
        return {
            "width": width, "lanes": k,
            "padding_lanes": k - TP_LANES,
            "per_step_seconds": per_step,
            "collectives": (pop.count_model_axis_collectives(
                tp_tc, k, m, tp_data) if count_psums else None),
            "scores": [float(x) for x in np.asarray(
                pop.population_scores(st))[:TP_LANES]],
        }

    tp_w1 = _tp_cell(1)
    tp_w2 = _tp_cell(2)
    tp_w4 = _tp_cell(4, count_psums=False)  # informational: kv=2 drops attn
    res["tp_width"] = {
        "trials": TP_LANES, "steps": TP_STEPS, "reps": TP_REPS,
        "d_model": TP_D_MODEL, "d_ff": TP_FF,
        "batch": TP_BATCH, "seq": TP_SEQ, "n_devices": jax.device_count(),
        "w1": tp_w1, "w2": tp_w2, "w4": tp_w4,
        "w2_vs_w1_per_step_speedup": (tp_w1["per_step_seconds"]
                                      / tp_w2["per_step_seconds"]),
        "w4_vs_w1_per_step_speedup": (tp_w1["per_step_seconds"]
                                      / tp_w4["per_step_seconds"]),
        "equivalence_max_abs_diff": float(max(
            abs(a - b)
            for ws in (tp_w2["scores"], tp_w4["scores"])
            for a, b in zip(tp_w1["scores"], ws))),
    }

    # -- async vs gated PBT: search quality on a longer horizon ----------------
    def _pbt_quality(sync: bool) -> dict:
        trial = PopulationTrial(arch, PBT_ROUND_STEPS, PBT_BATCH, PBT_SEQ,
                                seed, population=population,
                                per_trial_init=True)
        exp = Experiment({
            "proposer": "pbt", "parameter_config": PBT_SPACE,
            "n_samples": population * PBT_QUALITY_ROUNDS,
            "n_parallel": population, "target": "max", "seed": seed + 4,
            "population": population, "n_generations": PBT_QUALITY_ROUNDS,
            "streaming": True, "sync_rounds": sync, "quantile": 0.25,
            "resource": "vectorized", "lane_refill": True}, trial)
        scores: dict = {}
        exp.add_result_callback(lambda job: scores.__setitem__(
            (job.config.get("pbt_member"), job.config.get("pbt_round")),
            job.result.score if job.result else None))
        t0 = time.time()
        exp.run()
        dt = time.time() - t0
        hook = exp.proposer.lifecycle_hook()
        lags = [int(x) for x in hook.decision_lags]
        finals = [s for (m, r), s in scores.items()
                  if r == PBT_QUALITY_ROUNDS - 1 and s is not None]
        return {
            "seconds": dt,
            "best_score": max(s for s in scores.values() if s is not None),
            "best_final_round_score": max(finals) if finals else None,
            "clones": trial.n_clones, "keeps": hook.n_keeps,
            "splices": trial.n_splices,
            "donor_waits": trial.n_donor_waits + hook.n_donor_waits,
            "decision_lag_hist": np.bincount(lags).tolist() if lags else [],
            "decision_lag_mean": float(np.mean(lags)) if lags else 0.0,
            "decision_lag_max": int(max(lags)) if lags else 0,
        }

    res["pbt_async_quality"] = {
        "members": population, "rounds": PBT_QUALITY_ROUNDS,
        "round_steps": PBT_ROUND_STEPS,
        "gated": _pbt_quality(True),
        "async": _pbt_quality(False),
    }

    # -- cohort vs staggered rung rule on the longer-horizon ladder ------------
    long_cfgs = _long_ladder_workload(seed)
    chook = _long_hook()
    ctrial = PopulationTrial(arch, REFILL_UNIT, batch, seq, seed,
                             population=population, early_stop=chook)
    t0 = time.time()
    cohort_scores = []
    for i in range(0, len(long_cfgs), population):
        cohort_scores.extend(
            ctrial.run_population(long_cfgs[i:i + population], mesh=mesh))
    dt_cohort = time.time() - t0
    shook = _long_hook()
    strial2 = PopulationTrial(arch, REFILL_UNIT, batch, seq, seed,
                              population=population, early_stop=shook,
                              refill_idle_grace_s=0.0)
    sfeed = _feed_scheduler(long_cfgs)
    t0 = time.time()
    strial2.run_population([], mesh=mesh, scheduler=sfeed)
    dt_stag = time.time() - t0
    stag_scores = sfeed.ordered_scores(len(long_cfgs))
    n_disagree = sum(1 for a, b in zip(cohort_scores, stag_scores)
                     if abs(a - b) > 1e-3)
    res["sha_rule_compare"] = {
        "trials": len(long_cfgs), "population": population,
        "ladder_units": LONG_LADDER,
        "cohort": {"seconds": dt_cohort, "truncated": chook.n_truncated,
                   "reclaimed": chook.n_reclaimed,
                   "best_trial": int(np.argmax(cohort_scores)),
                   "best_score": float(max(cohort_scores))},
        "staggered": {"seconds": dt_stag, "truncated": shook.n_truncated,
                      "reclaimed": shook.n_reclaimed,
                      "best_trial": int(np.argmax(stag_scores)),
                      "best_score": float(max(stag_scores)),
                      "eff_steps": [int(sfeed.extras[i]["steps"])
                                    for i in range(len(long_cfgs))]},
        "n_score_disagreements": n_disagree,
        "same_best_trial": int(np.argmax(cohort_scores)) == int(np.argmax(stag_scores)),
    }
    print(json.dumps(res))


def _recovery_row(arch: str, population: int, batch: int, seq: int,
                  seed: int) -> dict:
    """Crash-safety: snapshot overhead, quarantine, kill/resume equivalence."""
    import shutil
    import signal
    import tempfile

    from repro.checkpoint import LaneSnapshotStore
    from repro.core import faultinject
    from repro.core.job import Job, JobStatus
    from repro.core.resource.vectorized import VectorizedResourceManager
    from repro.core.tracking.database import TrackingDB
    from repro.launch.hpo import PopulationTrial

    out: dict = {}
    lcfgs = _ladder_workload(seed)
    tmp = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        # -- (a) snapshot overhead on the refill ladder (vmapped engine) -------
        def _refill_seconds(snapshot_every, store):
            trial = PopulationTrial(
                arch, REFILL_UNIT, batch, seq, seed, population=population,
                early_stop=_refill_hook(), refill_idle_grace_s=0.0,
                snapshot_every=snapshot_every, snapshots=store)
            feed = _feed_scheduler(lcfgs)
            t0 = time.time()
            trial.run_population([], scheduler=feed)
            return time.time() - t0, trial

        # warm both variants (step/lane-op/snapshot compiles + tracing)
        _refill_seconds(0, None)
        _refill_seconds(1, LaneSnapshotStore(root=os.path.join(tmp, "warm")))
        plain_s, _ = _refill_seconds(0, None)
        snap_s, strial = _refill_seconds(
            1, LaneSnapshotStore(root=os.path.join(tmp, "lanes")))
        out["snapshot_overhead"] = {
            "plain_seconds": plain_s, "snapshot_seconds": snap_s,
            "ratio": snap_s / plain_s, "snapshots": strial.n_snapshots,
        }

        # -- (b) poison-lane quarantine under a repeat-crash fault -------------
        faultinject.arm("raise@step=2,times=3")
        try:
            qtrial = PopulationTrial(arch, 6, batch, seq, seed, population=2,
                                     refill_idle_grace_s=0.1)
            rm = VectorizedResourceManager(n_parallel=2, lane_refill=True,
                                           restart_backoff_s=0.001)
            jobs = [Job(i, {"learning_rate": 1e-3, "stream": 50 + i},
                        f"slot{i}", lambda j: None) for i in range(2)]
            for j in jobs:
                rm._busy[j.resource_id] = None
                rm.run(j, qtrial)
            for j in jobs:
                assert j.wait(300.0), "quarantine probe timed out"
        finally:
            faultinject.disarm()
        out["quarantine"] = {
            "flight_deaths": rm.n_flight_deaths,
            "flight_restarts": rm.n_flight_restarts,
            "quarantined": rm.n_quarantined,
            "failed_jobs": sum(j.status == JobStatus.FAILED for j in jobs),
        }

        # -- (c) CLI kill at an event boundary + --resume ----------------------
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")

        def _cli(db, extra, fault=None):
            e = dict(env)
            if fault:
                e[faultinject.ENV_VAR] = fault
            cmd = [sys.executable, "-m", "repro.launch.hpo",
                   "--proposer", "random", "--vectorize", "4", "--lane-refill",
                   "--n-samples", "8", "--steps", "12", "--batch", "2",
                   "--seq", "16", "--seed", str(seed), "--db", db] + extra
            return subprocess.run(cmd, env=e, capture_output=True, text=True,
                                  timeout=1800)

        def _scores(db):
            t = TrackingDB(db)
            eid = t.latest_experiment_id()
            return {r["config"].get("stream", r["job_id"]): r["score"]
                    for r in t.jobs(eid) if r["status"] == "finished"}

        base_db = os.path.join(tmp, "base.sqlite")
        kill_db = os.path.join(tmp, "kill.sqlite")
        r = _cli(base_db, ["--snapshot-every", "1"])
        if r.returncode != 0:
            raise RuntimeError(f"recovery baseline failed:\n{r.stderr[-2000:]}")
        r = _cli(kill_db, ["--snapshot-every", "1"],
                 fault=f"kill@event={RECOVERY_KILL_EVENT}")
        killed_rc = r.returncode
        if killed_rc not in (-signal.SIGKILL, 128 + signal.SIGKILL):
            raise RuntimeError(
                f"kill@event did not SIGKILL the run (rc={killed_rc}):\n"
                f"{r.stderr[-2000:]}")
        r = _cli(kill_db, ["--resume"])
        if r.returncode != 0:
            raise RuntimeError(f"--resume failed:\n{r.stderr[-2000:]}")
        resumed = json.loads(r.stdout[r.stdout.index("{"):])
        a, b = _scores(base_db), _scores(kill_db)
        equiv = (max(abs(a[k] - b[k]) for k in a)
                 if set(a) == set(b) and a else float("inf"))
        out["kill_resume"] = {
            "trials": len(a), "killed_rc": killed_rc,
            "kill_event": RECOVERY_KILL_EVENT,
            "resumed_lanes": resumed.get("resumed_lanes", 0),
            "resumed_from_steps": resumed.get("resumed_from_steps", []),
            "equivalence_max_abs_diff": equiv,
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return out


def run(arch: str = "starcoder2-3b", n_trials: int = 8, population: int = 8,
        steps: int = 6, batch: int = 4, seq: int = 32, seed: int = 0):
    import jax

    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.hpo import PopulationTrial
    from repro.train import population as pop
    from repro.train import train_step as ts

    cfgs = _sample_configs(n_trials, seed)

    results = {}

    # -- serial_recompile: the legacy closure-over-hparams path ----------------
    ts.clear_step_cache()
    model_cfg = get_smoke_config(arch)
    data = SyntheticLM(model_cfg.vocab_size, seq, batch, seed=seed)
    t0 = time.time()
    compiles = 0
    serial_scores = []
    for cfg in cfgs:
        tc = TrainConfig(
            model=model_cfg, parallel=ParallelConfig(remat="none"),
            learning_rate=float(cfg["learning_rate"]),
            warmup_steps=max(1, int(cfg.get("warmup_frac", 0.1) * steps)),
            total_steps=steps,
            weight_decay=float(cfg.get("weight_decay", 0.1)),
            b2=float(cfg.get("b2", 0.95)),
            grad_clip=float(cfg.get("grad_clip", 1.0)),
            seed=seed,
        )
        state = ts.init_train_state(jax.random.PRNGKey(seed), tc)
        step_fn = jax.jit(ts.make_train_step(tc))
        score = -1e9
        for s in range(steps):
            state, metrics = step_fn(state, data.make_batch(s, stream=int(cfg["stream"])))
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                break
            score = -loss
        serial_scores.append(score)
        compiles += step_fn._cache_size()
    dt = time.time() - t0
    results["serial_recompile"] = {
        "seconds": dt, "trials_per_sec": n_trials / dt, "compiles": compiles,
    }

    # -- compile_once: HParams as a traced argument ----------------------------
    ts.clear_step_cache()
    trial = PopulationTrial(arch, steps, batch, seq, seed)
    t0 = time.time()
    once_scores = [trial(cfg) for cfg in cfgs]
    dt = time.time() - t0
    tc_static, _ = trial._setup()
    results["compile_once"] = {
        "seconds": dt, "trials_per_sec": n_trials / dt,
        "compiles": ts.get_compiled_train_step(tc_static)._cache_size(),
    }

    # -- vmapped: K trials in one device program -------------------------------
    pop.clear_population_cache()
    vtrial = PopulationTrial(arch, steps, batch, seq, seed, population=population)
    t0 = time.time()
    vmap_scores = []
    for i in range(0, n_trials, population):
        vmap_scores.extend(vtrial.run_population(cfgs[i:i + population]))
    dt = time.time() - t0
    tc_static, _ = vtrial._setup()
    results["vmapped"] = {
        "seconds": dt, "trials_per_sec": n_trials / dt, "population": population,
        "compiles": pop.get_compiled_population_step(
            tc_static, population, per_trial_batch=True)._cache_size(),
    }

    # -- sharded: population axis over an 8-virtual-device CPU mesh ------------
    probe = _probe_sharded(arch, n_trials, population, steps, batch, seq, seed)
    sharded_scores = probe["sharded"].pop("scores")
    probe_vmap_scores = probe["vmapped"].pop("scores")
    results["sharded"] = dict(probe["sharded"], n_devices=probe["n_devices"],
                              vmapped_same_mesh=probe["vmapped"])

    # -- streaming PBT + rung-rule comparison (same 8-device subprocess) -------
    results["pbt_stream"] = dict(probe["pbt_stream"])
    results["pbt_async_quality"] = dict(probe["pbt_async_quality"])
    results["sha_rule_compare"] = dict(probe["sha_rule_compare"])

    # -- inflight-stop flights vs one continuous refill flight -----------------
    results["inflight_stop"] = dict(probe["inflight_stop"])
    refill = dict(probe["refill"])
    refill_scores = refill.pop("scores")
    refill_eff = refill.pop("eff_steps")
    refill_div = refill.pop("diverged")
    results["refill"] = refill

    # -- crash-safe snapshots: overhead, quarantine, kill/resume ---------------
    results["recovery"] = _recovery_row(arch, population, batch, seq, seed)
    rec = results["recovery"]
    snapshot_overhead = rec["snapshot_overhead"]["ratio"]
    snap_pair = rec["snapshot_overhead"]
    snapshot_cost_s = ((snap_pair["snapshot_seconds"]
                        - snap_pair["plain_seconds"])
                       / max(1, snap_pair["snapshots"]))
    recovery_equiv = rec["kill_resume"]["equivalence_max_abs_diff"]
    resumed_steps = rec["kill_resume"]["resumed_from_steps"]

    # -- fused chunked dispatch vs the per-step loops (all four engines) -------
    chunked = dict(probe["chunked"])
    results["chunked"] = chunked
    chrefill = chunked["refill"]
    chunked_equiv = float(max(
        chunked[m]["equivalence_max_abs_diff"]
        for m in ("vmapped", "sharded", "refill", "pbt_stream")))
    chunked_vs_refill = chrefill["speedup"]
    chunked_dispatch_ratio = chrefill["fused"]["dispatches_per_step"]

    # -- device-resident prefetch ring vs the per-step host-feed loop ----------
    dring = dict(probe["data_ring"])
    results["data_ring"] = dring
    data_ring_ok = (
        dring["speedup"] >= DATA_RING_FLOOR
        and dring["ring"]["overlap_frac"] >= RING_OVERLAP_FLOOR
        and dring["ring"]["ring_fills"] >= 1
        and dring["ring"]["dispatches_per_step"] < 1.0
        and dring["equivalence_max_abs_diff"] <= CHUNKED_SCORE_TOL
    )

    # -- device-side decision rules: one dispatch drains the whole ladder ------
    devrules = dict(probe["device_rules"])
    results["device_rules"] = devrules
    devrules_equiv = float(max(devrules[m]["equivalence_max_abs_diff"]
                               for m in ("vmapped", "sharded")))
    devrules_dispatches = max(
        devrules[m]["device"]["ladder_device_dispatches"]
        for m in ("vmapped", "sharded"))
    devrules_ok = (
        devrules_dispatches == 1
        and devrules_equiv <= CHUNKED_SCORE_TOL
        and all(devrules[m]["eff_steps_equal"]
                and devrules[m]["truncated_equal"]
                and devrules[m]["device"]["truncated"] >= 1
                and devrules[m]["host"]["dispatches"] > 1
                for m in ("vmapped", "sharded"))
    )

    # -- elastic two-level regrid: survivors absorb freed devices --------------
    elastic = dict(probe["elastic_regrid"])
    results["elastic_regrid"] = elastic
    elastic_ok = (
        elastic["regrids"] >= 1
        and elastic["full_occupancy"]
        and elastic["later_rung_speedup"] >= ELASTIC_FLOOR
        and elastic["equivalence_max_abs_diff"] <= CHUNKED_SCORE_TOL
        and elastic["truncated_equal"]
    )

    # -- tensor-parallel width: the model axis must carry compute --------------
    tp = dict(probe["tp_width"])
    results["tp_width"] = tp
    tp_ok = (
        tp["w2_vs_w1_per_step_speedup"] >= TP_FLOOR
        and tp["equivalence_max_abs_diff"] <= TP_SCORE_TOL
        and tp["w1"]["collectives"] == 0
        and tp["w2"]["collectives"] > 0
    )

    # refill equivalence: every trial must score exactly what the serial
    # driver scores at the trial's *effective* step count — the original
    # budget's LR schedule, cut at the truncation step (early-stop semantics);
    # diverged lanes must report the sentinel
    lcfgs = _ladder_workload(seed)
    strial = PopulationTrial(arch, REFILL_UNIT, batch, seq, seed)
    refill_equiv = 0.0
    for cfg, score, eff, div in zip(lcfgs, refill_scores, refill_eff, refill_div):
        if div:
            refill_equiv = max(refill_equiv, abs(score - strial.DIVERGED_SCORE))
            continue
        serial_score = strial.serial_score_at(dict(cfg), eff)
        refill_equiv = max(refill_equiv, abs(score - serial_score))

    def max_diff(a, b):
        return float(max(abs(x - y) for x, y in zip(a, b)))

    equiv = max(max_diff(once_scores, vmap_scores),
                max_diff(once_scores, sharded_scores),
                max_diff(once_scores, probe_vmap_scores))
    speedup_vmap = results["vmapped"]["trials_per_sec"] / results["serial_recompile"]["trials_per_sec"]
    speedup_once = results["compile_once"]["trials_per_sec"] / results["serial_recompile"]["trials_per_sec"]
    # same-process, same-mesh comparison: sharded vs vmapped on 8 devices
    sharded_vs_vmapped = (results["sharded"]["trials_per_sec"]
                          / results["sharded"]["vmapped_same_mesh"]["trials_per_sec"])
    refill_vs_inflight = (results["inflight_stop"]["seconds"]
                          / results["refill"]["seconds"])
    pbt = results["pbt_stream"]
    ok = (
        speedup_vmap >= SPEEDUP_FLOOR
        and sharded_vs_vmapped >= SHARDED_FLOOR
        and results["compile_once"]["compiles"] == 1
        and results["vmapped"]["compiles"] == 1
        and results["sharded"]["compiles"] == 1
        and equiv <= SCORE_TOL
        and refill_vs_inflight >= REFILL_FLOOR
        and refill_equiv <= SCORE_TOL
        and chunked_vs_refill >= CHUNKED_FLOOR
        and chunked_equiv <= CHUNKED_SCORE_TOL
        and chunked_dispatch_ratio < 1.0
        and data_ring_ok
        and devrules_ok
        and elastic_ok
        and tp_ok
        and pbt["speedup"] >= PBT_STREAM_FLOOR
        and pbt["equivalence_max_abs_diff"] <= PBT_SCORE_TOL
        and pbt["stream_host_ckpt_roundtrips"] == 0
        and snapshot_overhead <= SNAPSHOT_OVERHEAD_CEIL
        and snapshot_cost_s <= SNAPSHOT_COST_CEIL_S
        and rec["quarantine"]["quarantined"] >= 1
        and recovery_equiv <= RECOVERY_SCORE_TOL
        and rec["kill_resume"]["resumed_lanes"] >= 1
        and bool(resumed_steps) and max(resumed_steps) > 0
    )
    out = {
        "arch": arch, "n_trials": n_trials, "steps": steps,
        "batch": batch, "seq": seq,
        "modes": results,
        "speedup_vmapped_vs_serial": speedup_vmap,
        "speedup_compile_once_vs_serial": speedup_once,
        "sharded_vs_vmapped_same_mesh": sharded_vs_vmapped,
        "refill_vs_inflight_stop_speedup": refill_vs_inflight,
        "chunked_vs_refill_speedup": chunked_vs_refill,
        "chunked_dispatches_per_step": chunked_dispatch_ratio,
        "data_ring_vs_per_step_speedup": dring["speedup"],
        "data_ring_overlap_frac": dring["ring"]["overlap_frac"],
        "data_ring_equivalence_max_abs_diff":
            dring["equivalence_max_abs_diff"],
        "pbt_stream_vs_serial_speedup": pbt["speedup"],
        "equivalence_max_abs_diff": equiv,
        "refill_equivalence_max_abs_diff": refill_equiv,
        "chunked_equivalence_max_abs_diff": chunked_equiv,
        "device_rules_ladder_dispatches": devrules_dispatches,
        "device_rules_equivalence_max_abs_diff": devrules_equiv,
        "elastic_regrid_later_rung_speedup": elastic["later_rung_speedup"],
        "elastic_regrid_equivalence_max_abs_diff":
            elastic["equivalence_max_abs_diff"],
        "tp_width_w2_per_step_speedup": tp["w2_vs_w1_per_step_speedup"],
        "tp_width_model_axis_collectives": tp["w2"]["collectives"],
        "tp_width_equivalence_max_abs_diff": tp["equivalence_max_abs_diff"],
        "pbt_equivalence_max_abs_diff": pbt["equivalence_max_abs_diff"],
        "recovery_snapshot_overhead_ratio": snapshot_overhead,
        "recovery_snapshot_cost_s": snapshot_cost_s,
        "recovery_equivalence_max_abs_diff": recovery_equiv,
        "pass": bool(ok),
        "paper_claim": (
            f"population engines: vmapped {speedup_vmap:.1f}x trials/sec over "
            f"serial recompile (floor {SPEEDUP_FLOOR}x); sharded over "
            f"{results['sharded']['n_devices']} devices {sharded_vs_vmapped:.2f}x "
            f"vmapped on the same mesh; continuous lane refill "
            f"{refill_vs_inflight:.2f}x the inflight-stop flights on the same "
            f"ASHA ladder (scores = serial driver at effective budgets); "
            f"fused chunked dispatch {chunked_vs_refill:.2f}x the per-step "
            f"refill loop on the same ladder (scores bit-equal across all "
            f"four engines, {chrefill['per_step']['dispatches']} -> "
            f"{chrefill['fused']['dispatches']} device dispatches, "
            f"{chunked_dispatch_ratio:.2f} per trained step); the "
            f"device-resident prefetch ring feeds host-supplied data to the "
            f"same fused scans {dring['speedup']:.2f}x faster than the "
            f"per-step host-feed loop (floor {DATA_RING_FLOOR}x), hiding "
            f"{100 * dring['ring']['overlap_frac']:.0f}% of host fill behind "
            f"device compute (floor {100 * RING_OVERLAP_FLOOR:.0f}%) at "
            f"unchanged scores (max diff "
            f"{dring['equivalence_max_abs_diff']:.2g}); device-side "
            f"decision rules run the whole "
            f"{len(devrules['ladder_units'])}-trial multi-rung ladder as "
            f"{devrules_dispatches} device dispatch on both the vmapped and "
            f"sharded engines (host-rule path: "
            f"{devrules['vmapped']['host']['dispatches']} dispatches), scores "
            f"and effective budgets equal to the host-rule path "
            f"(max diff {devrules_equiv:.2g}); elastic two-level regrid "
            f"re-leases the pod at every rung cut "
            f"({elastic['regrids']} regrids, lane/width history "
            f"{elastic['lane_width_history']}) and runs the same shrink-heavy "
            f"ladder {elastic['later_rung_speedup']:.2f}x faster than the "
            f"fixed-width sharded flight (floor {ELASTIC_FLOOR}x, scores "
            f"within {elastic['equivalence_max_abs_diff']:.2g}); the "
            f"tensor-parallel model axis carries real compute: "
            f"{tp['trials']} survivors on the full {tp['n_devices']}-device "
            f"pod step {tp['w2_vs_w1_per_step_speedup']:.2f}x faster at "
            f"width 2 than width 1 (floor {TP_FLOOR}x; "
            f"{tp['w2']['collectives']} model-axis all-reduces vs "
            f"{tp['w1']['collectives']} at width 1, scores within "
            f"{tp['equivalence_max_abs_diff']:.2g}); "
            f"streaming PBT {pbt['speedup']:.1f}x the generation-barriered "
            f"serial PBT driver at equal total steps (scores equal, "
            f"{pbt['serial_host_ckpt_roundtrips']} -> 0 host checkpoint "
            f"round-trips); crash-safe streaming: per-event lane snapshots "
            f"cost {100 * (snapshot_overhead - 1):.1f}% wall-clock, a SIGKILL "
            f"at an event boundary resumes {rec['kill_resume']['resumed_lanes']} "
            f"lanes from their snapshot step with per-trial scores equal to "
            f"the uninterrupted run (max diff {recovery_equiv:.2g}); compiles "
            f"{results['serial_recompile']['compiles']} -> 1"
        ),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--probe-sharded":
        _probe_main(sys.argv[2:])
    else:
        print(json.dumps(run(), indent=1))
