"""Paper Fig. 4 — different proposers explore different regions of the space.

Runs the paper's five-hyperparameter CNN search space under each proposer
(identical budget), collects every proposed configuration, and summarizes the
per-dimension distribution (mean/std/quartiles).  The paper's point is
qualitative — the search *paths* differ — which we quantify as the spread of
per-proposer means relative to the space width.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.experiment import Experiment

# the paper's §IV hyperparameters (Code 2-style)
SPACE = [
    {"name": "conv1", "type": "int", "range": [8, 64]},
    {"name": "conv2", "type": "int", "range": [16, 128]},
    {"name": "fc1", "type": "int", "range": [32, 256]},
    {"name": "dropout", "type": "float", "range": [0.0, 0.6]},
    {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-1], "scale": "log"},
]


def _cheap_surrogate(cfg):
    """Analytic stand-in for CNN accuracy: smooth, peaked in-range optimum —
    enough for proposers' exploration behaviour to differ visibly."""
    lr_term = -(np.log10(float(cfg["learning_rate"])) + 2.5) ** 2  # peak at 10^-2.5
    cap = (float(cfg["conv1"]) / 64 + float(cfg["conv2"]) / 128 + float(cfg["fc1"]) / 256)
    drop_term = -((float(cfg["dropout"]) - 0.15) ** 2) * 4
    return lr_term + 0.5 * cap + drop_term


def run(budget: int = 40) -> Dict:
    proposals: Dict[str, list] = {}
    for name in ("random", "grid", "gp", "tpe", "hyperband", "bohb"):
        seen = []

        def target(cfg):
            seen.append({k: float(cfg[k]) for k in
                         ("conv1", "conv2", "fc1", "dropout", "learning_rate")})
            return _cheap_surrogate(cfg)

        Experiment(
            {"proposer": name, "parameter_config": SPACE, "n_samples": budget,
             "n_parallel": 4, "target": "max", "random_seed": 0},
            target,
        ).run()
        proposals[name] = seen

    stats = {}
    for name, rows in proposals.items():
        stats[name] = {}
        for dim in ("conv1", "conv2", "fc1", "dropout", "learning_rate"):
            vals = np.array([r[dim] for r in rows])
            if dim == "learning_rate":
                vals = np.log10(vals)
            stats[name][dim] = {
                "n": len(vals),
                "mean": round(float(vals.mean()), 4),
                "std": round(float(vals.std()), 4),
                "q25": round(float(np.percentile(vals, 25)), 4),
                "q75": round(float(np.percentile(vals, 75)), 4),
            }

    # quantify "different paths": model-based proposers CONCENTRATE around the
    # optimum (smaller lr std) while random/grid spread over the whole range
    lr_stds = {n: stats[n]["learning_rate"]["std"] for n in stats}
    informed = min(lr_stds.get("gp", 9), lr_stds.get("tpe", 9))
    uninformed = max(lr_stds.get("random", 0), lr_stds.get("grid", 0))
    return {
        "per_proposer_distributions": stats,
        "lr_std_informed_min": round(informed, 3),
        "lr_std_uninformed_max": round(uninformed, 3),
        "paper_claim": "different HPO algorithms search different paths",
        "pass": informed < 0.8 * uninformed,
    }
