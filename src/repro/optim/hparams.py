"""Per-trial hyperparameters as a traced pytree.

The HPO hot path used to bake ``learning_rate`` / ``weight_decay`` / ``b2`` /
``grad_clip`` / schedule lengths into the ``TrainConfig`` closure, so every
trial's ``jax.jit(make_train_step(tc))`` was a *different* Python callable and
paid a full XLA recompile.  ``HParams`` moves those knobs into a pytree that is
passed as a traced argument: one compiled step then serves every trial of a
given architecture, and a whole population of trials can ride a leading
``vmap`` axis (see ``repro.train.population``).

Contract: anything in ``HParams`` may differ per trial without recompiling;
anything still read from ``TrainConfig`` inside the step (model architecture,
parallelism, dtypes, ``b1``, ``eps``, ``z_loss``) is static and keys the
compile cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HParams:
    """Traced per-trial hyperparameters (every field is a jnp scalar leaf)."""

    learning_rate: Any
    weight_decay: Any
    b2: Any
    grad_clip: Any          # <= 0 disables clipping (traced via jnp.where)
    warmup_steps: Any       # float32; schedule math is already float
    total_steps: Any


def hparams_from_config(tc: TrainConfig) -> HParams:
    """Lift the tunable knobs of a TrainConfig into a traced HParams."""
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    return HParams(
        learning_rate=f32(tc.learning_rate),
        weight_decay=f32(tc.weight_decay),
        b2=f32(tc.b2),
        grad_clip=f32(tc.grad_clip),
        warmup_steps=f32(max(tc.warmup_steps, 1)),
        total_steps=f32(tc.total_steps),
    )


def hparams_from_dict(cfg: Dict[str, Any], tc: TrainConfig) -> HParams:
    """Build HParams from an HPO proposal dict, defaulting to ``tc``'s values.

    Recognised keys mirror the search space in ``repro.launch.hpo``:
    ``learning_rate``, ``weight_decay``, ``b2``, ``grad_clip`` and either
    explicit ``warmup_steps``/``total_steps`` or ``warmup_frac`` applied to
    ``tc.total_steps``.
    """
    total = float(cfg.get("total_steps", tc.total_steps))
    if "warmup_steps" in cfg:
        warmup = float(cfg["warmup_steps"])
    elif "warmup_frac" in cfg:
        warmup = float(cfg["warmup_frac"]) * total
    else:
        warmup = float(tc.warmup_steps)
    f32 = lambda v: jnp.asarray(float(v), jnp.float32)
    return HParams(
        learning_rate=f32(cfg.get("learning_rate", tc.learning_rate)),
        weight_decay=f32(cfg.get("weight_decay", tc.weight_decay)),
        b2=f32(cfg.get("b2", tc.b2)),
        grad_clip=f32(cfg.get("grad_clip", tc.grad_clip)),
        warmup_steps=f32(max(warmup, 1.0)),
        total_steps=f32(total),
    )


def stack_hparams(hps: Sequence[HParams]) -> HParams:
    """Stack per-trial HParams along a new leading population axis."""
    assert hps, "empty population"
    return jax.tree.map(lambda *xs: jnp.stack(xs), *hps)
