"""AdamW with dtype-configurable sharded state (pure pytrees, no optax here).

Memory policy knobs (ParallelConfig) that keep the 100B+ cells under
16 GB/chip on v5e:

* ``mu_dtype`` / ``nu_dtype`` — moments in bf16 halve optimizer memory;
* ``master_dtype`` — optional fp32 master copy when params are bf16
  (None = update in param dtype, saving 4 bytes/param);
* all states inherit the parameter's sharding (ZeRO-3: FSDP axis shards
  them over data(+pod), TP axes over model).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig
from ..models.layers import dtype_of

OptState = Dict[str, Any]


def init_opt_state(params, tc: TrainConfig) -> OptState:
    pc = tc.parallel
    mu_dt, nu_dt = dtype_of(pc.mu_dtype), dtype_of(pc.nu_dtype)
    state: OptState = {
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mu_dt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=nu_dt), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if pc.master_dtype is not None:
        mdt = dtype_of(pc.master_dtype)
        state["master"] = jax.tree.map(lambda p: p.astype(mdt), params)
    return state


def opt_state_specs(p_specs) -> Dict[str, Any]:
    """Optimizer states share the parameter specs; step is replicated."""
    return {"mu": p_specs, "nu": p_specs, "step": ()}


def global_norm(tree) -> jax.Array:
    """L2 norm over all leaves.  Under the population engines' tensor-parallel
    shard_map (tp_shard_context armed with a gnorm_mask), width-sharded leaves
    hold only their model-axis shard, so their sum-of-squares is psum'd over
    the lane row while replicated leaves count once — every device in the row
    sees the same (full) norm, keeping grad-clip decisions width-invariant."""
    from ..distributed.sharding import tp_gnorm_sumsq

    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    tp_total = tp_gnorm_sumsq(leaves, tree)
    if tp_total is not None:
        return jnp.sqrt(tp_total)
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads, params, state: OptState, lr: jax.Array, tc: TrainConfig,
    hp: Optional["HParams"] = None,
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step.

    ``b2`` / ``weight_decay`` / ``grad_clip`` come from ``hp`` when given — a
    *traced* HParams pytree, so distinct trials share one compiled update —
    and fall back to the static ``tc`` values otherwise (identical numerics:
    the traced formulation constant-folds under jit).  ``b1`` and ``eps``
    stay static.
    """
    b1, eps = tc.b1, tc.eps
    if hp is None:
        from .hparams import hparams_from_config

        hp = hparams_from_config(tc)
    b2 = jnp.asarray(hp.b2, jnp.float32)
    wd = jnp.asarray(hp.weight_decay, jnp.float32)
    gc = jnp.asarray(hp.grad_clip, jnp.float32)
    step = state["step"] + 1
    gnorm = global_norm(grads)
    # traced grad_clip: gc <= 0 disables clipping without a Python branch
    clip = jnp.where(gc > 0, jnp.minimum(1.0, gc / (gnorm + 1e-9)), 1.0)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    masters = state.get("master", params)

    def upd(g, p, m, v, master):
        gf = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh, vh = m_new / c1, v_new / c2
        delta = mh / (jnp.sqrt(vh) + eps)
        base = master.astype(jnp.float32)
        if p.ndim >= 2:  # decay matrices, not norms/biases (wd==0 is a no-op)
            delta = delta + wd * base
        new_master = base - lr * delta
        return (
            new_master.astype(p.dtype),
            m_new.astype(m.dtype),
            v_new.astype(v.dtype),
            new_master.astype(master.dtype),
        )

    flat = jax.tree.map(upd, grads, params, state["mu"], state["nu"], masters)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_state: OptState = {"mu": new_mu, "nu": new_nu, "step": step}
    if "master" in state:
        new_state["master"] = jax.tree.map(
            lambda t: t[3], flat, is_leaf=lambda x: isinstance(x, tuple)
        )
    return new_params, new_state, {"grad_norm": gnorm}
