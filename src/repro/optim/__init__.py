from .adamw import adamw_update, init_opt_state, global_norm
from .schedule import warmup_cosine, constant
