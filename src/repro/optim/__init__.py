from .adamw import adamw_update, init_opt_state, global_norm
from .hparams import HParams, hparams_from_config, hparams_from_dict, stack_hparams
from .schedule import warmup_cosine, constant
