"""repro — Auptimizer-in-JAX: HPO orchestration + multi-pod training substrate."""

__version__ = "1.0.0"
