"""Modality frontend STUBS (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These helpers generate deterministic synthetic embeddings with the right
shapes/dtypes so the hubert (audio frames) and pixtral (image patches)
backbones can be exercised end-to-end on CPU, and document what a real
frontend would produce.
"""
from __future__ import annotations

import numpy as np


def synthetic_audio_frames(batch: int, n_frames: int, d_model: int, seed: int = 0) -> np.ndarray:
    """Stand-in for a wav2vec2-style conv feature encoder output:
    (batch, n_frames, d_model) bf16-able float32 frames (~50 Hz frame rate)."""
    rng = np.random.default_rng((seed, 0xA0D10))
    # smooth over time like real speech features (AR(1) mixing)
    x = rng.standard_normal((batch, n_frames, d_model)).astype(np.float32)
    for t in range(1, n_frames):
        x[:, t] = 0.7 * x[:, t - 1] + 0.3 * x[:, t]
    return x


def synthetic_image_patches(batch: int, n_patches: int, d_model: int, seed: int = 0) -> np.ndarray:
    """Stand-in for a Pixtral-ViT patch projection: (batch, n_patches, d_model).
    Patches carry a low-frequency spatial signal like projected image content."""
    rng = np.random.default_rng((seed, 0x1777A6E))
    side = max(int(np.sqrt(n_patches)), 1)
    coarse = rng.standard_normal((batch, side // 2 + 1, side // 2 + 1, d_model)).astype(np.float32)
    up = np.kron(coarse, np.ones((1, 2, 2, 1), np.float32))[:, :side, :side]
    flat = up.reshape(batch, side * side, d_model)
    if flat.shape[1] < n_patches:
        pad = np.zeros((batch, n_patches - flat.shape[1], d_model), np.float32)
        flat = np.concatenate([flat, pad], axis=1)
    return flat[:, :n_patches]
