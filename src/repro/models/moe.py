"""Mixture-of-Experts FFN: token-choice top-k routing, sort-based capacity
dispatch (EP-shardable), optional always-on shared experts (DeepSeek style).

Dispatch avoids the O(T*E*C) one-hot tensor of Switch-style implementations:
assignments are sorted by expert id, scattered into an (E, C, d) buffer
(capacity-dropped with `mode="drop"`), processed with one stacked einsum per
matmul, and gathered back.  Sharding: the E axis maps to the mesh "model"
axis -> expert parallelism; XLA turns the scatter/gather into all-to-alls.

Returns (y, aux_loss) — aux is the Switch load-balancing loss
E * Σ_e f_e·P_e, threaded out of the scanned blocks by the caller.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Params, Specs, dense_init, dtype_of
from .mlp import mlp_apply, mlp_init, mlp_specs


def moe_init(key, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    kr, ki, ko, ks = jax.random.split(key, 4)
    n_in = 2 if cfg.activation == "swiglu" else 1
    p = {
        "router": dense_init(kr, (d, E), jnp.float32, fan_in=d),  # fp32 router
        "wi": dense_init(ki, (E, d, n_in, ff), pdt, fan_in=d),
        "wo": dense_init(ko, (E, ff, d), pdt, fan_in=ff),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks, cfg, d_ff=cfg.n_shared_experts * cfg.moe_d_ff)
    return p


def moe_specs(cfg: ModelConfig) -> Specs:
    s = {
        "router": ("embed", None),
        "wi": ("expert", "embed", None, "moe_ff"),
        "wo": ("expert", "moe_ff", "embed"),
    }
    if cfg.n_shared_experts:
        s["shared"] = {"wi": ("embed", None, "ff"), "wo": ("ff", "embed")}
    return s


def _dispatch_tables(top_e, top_p, T, k, E, C):
    """Sort-based dispatch tables for T local tokens: returns
    (slot, keep, tok_idx, weights_sorted) — all (T*k,)."""
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e)                                  # stable
    sorted_e = flat_e[order]
    idx = jnp.arange(T * k)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_in_e = idx - run_start
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)       # dropped -> OOB
    tok_idx = order // k
    weights = top_p.reshape(-1)[order]
    return slot, keep, tok_idx, weights


def _moe_shard_map(p: Params, x: jax.Array, cfg: ModelConfig, mesh, rules):
    """Explicitly-local MoE under shard_map (the production TP/EP path).

    Activations enter replicated over the model axis (TP layout), so every
    model shard runs the cheap dispatch math redundantly on its data shard's
    tokens, computes ONLY its E/n_model experts, and one psum over the model
    axis recombines — the same collective cost as a dense TP FFN.  This
    avoids XLA's SPMD partitioner turning the dispatch scatter/gather into
    mesh-wide partial-gather + all-reduce (measured 25x worse).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in rules.get("batch", ()) if a in mesh.axis_names)
    # keep only the prefix of data axes that evenly divides the batch dim
    # (shard_map is strict; e.g. a 16-sample microbatch on pod*data = 32)
    keep, prod = [], 1
    for a in dp:
        if x.shape[0] % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
    dp = tuple(keep)
    dp_spec = (dp if len(dp) > 1 else dp[0]) if dp else None
    mp = "model"
    n_mp = mesh.shape[mp]
    E, k, d = cfg.n_experts, cfg.moe_top_k, cfg.d_model
    E_l = E // n_mp

    def local_fn(x_loc, router, wi_loc, wo_loc):
        B_l, S, _ = x_loc.shape
        T = B_l * S
        xt = x_loc.reshape(T, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        f = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * k)
        aux = E * jnp.sum(f * probs.mean(axis=0))
        if dp:
            aux = jax.lax.pmean(aux, dp)

        C = max(1, int(math.ceil(cfg.capacity_factor * T * k / E)))
        slot, keep, tok_idx, weights = _dispatch_tables(top_e, top_p, T, k, E, C)

        # ---- local experts only: this shard never materializes the other
        # shards' (E, C, d) buffers — dispatch tables are small ints, the
        # only d-wide traffic is one (E_l*C, d) gather in and one out.
        e0 = jax.lax.axis_index(mp) * E_l
        nloc = E_l * C
        slot_rel = slot - e0 * C
        in_local = (slot_rel >= 0) & (slot_rel < nloc) & keep
        slot_safe = jnp.where(in_local, slot_rel, nloc)          # OOB -> dropped
        entry_of_slot = jnp.zeros((nloc + 1,), jnp.int32).at[slot_safe].set(
            jnp.arange(T * k, dtype=jnp.int32) + 1, mode="drop"
        )[:nloc]
        has_tok = entry_of_slot > 0
        src_tok = tok_idx[jnp.maximum(entry_of_slot - 1, 0)]
        buf_l = jnp.where(has_tok[:, None], xt[src_tok], 0).reshape(E_l, C, d)

        h = jnp.einsum("ecd,ednf->ecnf", buf_l, wi_loc)
        if cfg.activation == "swiglu":
            h = jax.nn.silu(h[:, :, 0]) * h[:, :, 1]
        else:
            h = jax.nn.gelu(h[:, :, 0])
        out_l = jnp.einsum("ecf,efd->ecd", h, wo_loc).astype(jnp.float32)
        out_l = out_l.reshape(nloc, d)

        read_idx = jnp.where(in_local, slot_rel, 0)
        expert_out = jnp.where(in_local[:, None], out_l[read_idx], 0.0)
        y = jnp.zeros((T, d), jnp.float32).at[tok_idx].add(expert_out * weights[:, None])
        y = jax.lax.psum(y, mp)                        # combine expert shards (TP AR)
        return y.astype(x_loc.dtype).reshape(B_l, S, d), aux

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(dp_spec, None, None),
            P(None, None),
            P(mp, None, None, None),
            P(mp, None, None),
        ),
        out_specs=(P(dp_spec, None, None), P()),
        check_rep=False,
    )
    y, aux = fn(x, p["router"], p["wi"], p["wo"])
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux


def moe_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, *, dropless: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Grouped sort-based dispatch.

    ``cfg.moe_groups`` splits tokens into G independent dispatch groups with
    per-group capacity.  G=1 is the global baseline; G = number of data
    shards makes every sort/scatter/gather LOCAL to its shard under SPMD
    (the argsort/scatter of a global dispatch cannot be partitioned and
    replicates catastrophically), while the expert einsums reshard the
    (G, E, C, d) buffer expert-over-model — the GShard/Switch all-to-all
    pattern expressed through sharding constraints.
    """
    from ..distributed.sharding import _CTX, constrain

    ctx = _CTX.get()
    if (
        ctx is not None
        and not dropless
        and "model" in ctx[0].axis_names
        and cfg.n_experts % ctx[0].shape["model"] == 0
    ):
        return _moe_shard_map(p, x, cfg, ctx[0], ctx[1])

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    T = B * S
    G = max(1, cfg.moe_groups) if not dropless else 1
    if T % G or (T // G) < 1:
        G = 1
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    xt = constrain(xt, ("batch", None, "act_embed"))

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G, Tg, E)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (G, Tg, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch): E * sum_e f_e * P_e -------------------
    f = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * k)
    P = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f * P)

    # --- per-group sort-based capacity dispatch ---------------------------------
    # dropless (decode / exactness-sensitive paths): every assignment fits.
    C = Tg * k if dropless else max(1, int(math.ceil(cfg.capacity_factor * Tg * k / E)))
    flat_e = top_e.reshape(G, Tg * k)                            # (G, Tg*k)
    order = jnp.argsort(flat_e, axis=1)                          # stable, per group
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # position within the expert's buffer: index - start of its run
    idx = jnp.broadcast_to(jnp.arange(Tg * k)[None], (G, Tg * k))
    is_start = jnp.concatenate(
        [jnp.ones((G, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1
    )
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=1)
    pos_in_e = idx - run_start
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)       # dropped -> OOB
    tok_idx = order // k                                          # (G, Tg*k)

    g_iota = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tg * k))
    vals = jnp.take_along_axis(xt, tok_idx[..., None], axis=1)   # (G, Tg*k, d)
    buf = jnp.zeros((G, E * C, d), xt.dtype).at[g_iota, slot].set(vals, mode="drop")
    buf = buf.reshape(G, E, C, d)
    # tokens move data-sharding -> expert-sharding here (all-to-all under SPMD)
    buf = constrain(buf, ("batch", "expert", None, None))

    # --- expert compute (stacked einsums; E shards over the model axis) ---------
    h = jnp.einsum("gecd,ednf->gecnf", buf, p["wi"])
    if cfg.activation == "swiglu":
        h = jax.nn.silu(h[:, :, :, 0]) * h[:, :, :, 1]
    else:
        h = jax.nn.gelu(h[:, :, :, 0])
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    # second all-to-all: expert-sharding -> data-sharding, so the gather-back
    # below is local to each data shard (gathering from an expert-sharded
    # buffer would all-gather the whole thing everywhere)
    out_buf = constrain(out_buf, ("batch", None, None, None))
    out_buf = out_buf.reshape(G, E * C, d)

    # --- gather back + combine with routing weights -----------------------------
    safe_slot = jnp.where(keep, slot, 0)
    expert_out = jnp.where(keep[..., None], out_buf[g_iota, safe_slot], 0.0)
    weights = jnp.take_along_axis(top_p.reshape(G, Tg * k), order, axis=1)
    contrib = expert_out.astype(jnp.float32) * weights[..., None]   # fp32 combine
    y = jnp.zeros((G, Tg, d), jnp.float32).at[g_iota, tok_idx].add(contrib)
    y = y.astype(xt.dtype)
    y = constrain(y, ("batch", None, "act_embed"))

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg).reshape(G, Tg, d)
    return y.reshape(B, S, d), aux
