"""Model assembly: super-block scan over heterogeneous (mixer, ffn) layers.

A model = embedding -> [prefix layers] -> scan(superblock) x n_superblocks ->
final norm -> unembed.  Params/caches for the scanned body carry a leading
``n_superblocks`` axis, which keeps the HLO O(|pattern|) regardless of depth
(88-layer granite compiles as fast as 27-layer deepseek) and lets XLA overlap
each layer's collectives with the next layer's compute.

Public surface:
    init_params / param_specs
    forward(params, batch, cfg)                 -> (logits, aux)   train/prefill
    init_cache / cache_specs
    decode_step(params, cache, tokens, pos, cfg) -> (logits, cache) serving
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from . import attention as attn
from . import mamba as mam
from . import mlp as mlpm
from . import moe as moem
from .layers import (
    Params,
    Specs,
    apply_rmsnorm,
    dtype_of,
    embed_tokens,
    embedding_init,
    embedding_specs,
    rmsnorm_init,
    rmsnorm_specs,
    unembed,
)

_MIXER_INIT = {
    "attn": attn.gqa_init,
    "attn_local": attn.gqa_init,
    "attn_mla": attn.mla_init,
    "mamba": mam.mamba_init,
}
_MIXER_SPECS = {
    "attn": attn.gqa_specs,
    "attn_local": attn.gqa_specs,
    "attn_mla": attn.mla_specs,
    "mamba": mam.mamba_specs,
}


# ------------------------------ init --------------------------------------------------
def _layer_init(key, mixer: str, ffn: str, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p: Params = {
        "norm1": rmsnorm_init(cfg.d_model, pdt),
        "mixer": _MIXER_INIT[mixer](k1, cfg),
    }
    if cfg.post_norm:
        p["norm1_post"] = rmsnorm_init(cfg.d_model, pdt)
    if ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, pdt)
        p["ffn"] = moem.moe_init(k2, cfg) if ffn == "moe" else mlpm.mlp_init(k2, cfg)
        if cfg.post_norm:
            p["norm2_post"] = rmsnorm_init(cfg.d_model, pdt)
    return p


def _layer_specs(mixer: str, ffn: str, cfg: ModelConfig) -> Specs:
    s: Specs = {"norm1": rmsnorm_specs(), "mixer": _MIXER_SPECS[mixer](cfg)}
    if cfg.post_norm:
        s["norm1_post"] = rmsnorm_specs()
    if ffn != "none":
        s["norm2"] = rmsnorm_specs()
        s["ffn"] = moem.moe_specs(cfg) if ffn == "moe" else mlpm.mlp_specs(cfg)
        if cfg.post_norm:
            s["norm2_post"] = rmsnorm_specs()
    return s


def _superblock_init(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, len(cfg.pattern))
    return {
        f"l{i}": _layer_init(keys[i], m, f, cfg)
        for i, (m, f) in enumerate(cfg.pattern)
    }


def init_params(key, cfg: ModelConfig) -> Params:
    k_emb, k_pre, k_body, k_fin = jax.random.split(key, 4)
    params: Params = {"embedding": embedding_init(k_emb, cfg)}
    if cfg.prefix_pattern:
        pre_keys = jax.random.split(k_pre, len(cfg.prefix_pattern))
        params["prefix"] = [
            _layer_init(pre_keys[i], m, f, cfg)
            for i, (m, f) in enumerate(cfg.prefix_pattern)
        ]
    body_keys = jax.random.split(k_body, cfg.n_superblocks)
    params["blocks"] = jax.vmap(lambda k: _superblock_init(k, cfg))(body_keys)
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype_of(cfg.param_dtype))
    return params


def param_specs(cfg: ModelConfig) -> Specs:
    specs: Specs = {"embedding": embedding_specs(cfg)}
    if cfg.prefix_pattern:
        specs["prefix"] = [_layer_specs(m, f, cfg) for m, f in cfg.prefix_pattern]
    sb = {f"l{i}": _layer_specs(m, f, cfg) for i, (m, f) in enumerate(cfg.pattern)}
    # scanned params have a leading n_superblocks axis -> prepend None
    specs["blocks"] = jax.tree.map(
        lambda t: (None,) + t, sb,
        is_leaf=lambda x: isinstance(x, tuple) and all(i is None or isinstance(i, str) for i in x),
    )
    specs["final_norm"] = rmsnorm_specs()
    return specs


# ------------------------------ forward (train / prefill) ------------------------------
def _layer_apply(
    lp: Params, x: jax.Array, mixer: str, ffn: str, cfg: ModelConfig, positions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    h = apply_rmsnorm(x, lp["norm1"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
    if mixer == "mamba":
        h = mam.mamba_apply(lp["mixer"], h, cfg)
    elif mixer == "attn_mla":
        h = attn.mla_apply(lp["mixer"], h, cfg, positions)
    else:
        h = attn.gqa_apply(lp["mixer"], h, cfg, positions, local=(mixer == "attn_local"))
    if cfg.post_norm:
        h = apply_rmsnorm(h, lp["norm1_post"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
    x = x + h
    x = constrain(x, ("batch", "act_seq", "act_embed"))
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = apply_rmsnorm(x, lp["norm2"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
        if ffn == "moe":
            h, aux = moem.moe_apply(lp["ffn"], h, cfg)
        else:
            h = mlpm.mlp_apply(lp["ffn"], h, cfg)
        if cfg.post_norm:
            h = apply_rmsnorm(h, lp["norm2_post"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
        x = x + h
        x = constrain(x, ("batch", "act_seq", "act_embed"))
    return x, aux


def _superblock_apply(sp: Params, x, cfg: ModelConfig, positions) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    for i, (m, f) in enumerate(cfg.pattern):
        x, a = _layer_apply(sp[f"l{i}"], x, m, f, cfg, positions)
        aux = aux + a
    return x, aux


def forward(
    params: Params,
    tokens: Optional[jax.Array],
    cfg: ModelConfig,
    *,
    inputs_embeds: Optional[jax.Array] = None,
    remat: str = "full",
    last_only: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits fp32 (B,S,V), aux MoE loss scalar).

    ``last_only``: unembed only the final position (serving prefill — avoids a
    (B, S, vocab) logits tensor when only the next-token distribution is needed).
    """
    if inputs_embeds is not None:
        x = inputs_embeds.astype(dtype_of(cfg.compute_dtype))
    else:
        x = embed_tokens(params["embedding"], tokens, cfg)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, ("batch", "act_seq", "act_embed"))

    aux = jnp.zeros((), jnp.float32)
    for i, (m, f) in enumerate(cfg.prefix_pattern):
        x, a = _layer_apply(params["prefix"][i], x, m, f, cfg, positions)
        aux = aux + a

    def body(carry, sp):
        x, aux = carry
        x, a = _superblock_apply(sp, x, cfg, positions)
        return (x, aux + a), None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])

    if last_only:
        x = x[:, -1:]
    x = apply_rmsnorm(x, params["final_norm"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
    logits = unembed(params["embedding"], x, cfg)
    logits = constrain(logits, ("batch", "act_seq", "vocab"))
    return logits, aux


# ------------------------------ serving (decode) ---------------------------------------
def _layer_cache_init(mixer: str, cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Params:
    if mixer == "mamba":
        return mam.mamba_cache_init(cfg, batch, dtype)
    if mixer == "attn_mla":
        return attn.mla_cache_init(cfg, batch, max_seq, dtype)
    return attn.gqa_cache_init(cfg, batch, max_seq, dtype)


def _layer_cache_specs(mixer: str, cfg: ModelConfig) -> Specs:
    if mixer == "mamba":
        return mam.mamba_cache_specs(cfg)
    if mixer == "attn_mla":
        return attn.mla_cache_specs(cfg)
    return attn.gqa_cache_specs(cfg)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
    cache: Params = {}
    if cfg.prefix_pattern:
        cache["prefix"] = [
            _layer_cache_init(m, cfg, batch, max_seq, dtype) for m, _ in cfg.prefix_pattern
        ]
    one_sb = {
        f"l{i}": _layer_cache_init(m, cfg, batch, max_seq, dtype)
        for i, (m, _) in enumerate(cfg.pattern)
    }
    cache["blocks"] = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_superblocks,) + a.shape), one_sb
    )
    return cache


def cache_specs(cfg: ModelConfig) -> Specs:
    specs: Specs = {}
    if cfg.prefix_pattern:
        specs["prefix"] = [_layer_cache_specs(m, cfg) for m, _ in cfg.prefix_pattern]
    sb = {f"l{i}": _layer_cache_specs(m, cfg) for i, (m, _) in enumerate(cfg.pattern)}
    specs["blocks"] = jax.tree.map(
        lambda t: (None,) + t, sb,
        is_leaf=lambda x: isinstance(x, tuple) and all(i is None or isinstance(i, str) for i in x),
    )
    return specs


def _layer_decode(
    lp: Params, x, cache, pos, mixer: str, ffn: str, cfg: ModelConfig
) -> Tuple[jax.Array, Params]:
    h = apply_rmsnorm(x, lp["norm1"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
    if mixer == "mamba":
        h, new_cache = mam.mamba_decode(lp["mixer"], h, cache, cfg)
    elif mixer == "attn_mla":
        h, new_cache = attn.mla_decode(lp["mixer"], h, cache, pos, cfg)
    else:
        h, new_cache = attn.gqa_decode(
            lp["mixer"], h, cache, pos, cfg, local=(mixer == "attn_local")
        )
    if cfg.post_norm:
        h = apply_rmsnorm(h, lp["norm1_post"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
    x = x + h
    if ffn != "none":
        h = apply_rmsnorm(x, lp["norm2"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
        if ffn == "moe":
            h, _ = moem.moe_apply(lp["ffn"], h, cfg, dropless=True)  # decode: never drop
        else:
            h = mlpm.mlp_apply(lp["ffn"], h, cfg)
        if cfg.post_norm:
            h = apply_rmsnorm(h, lp["norm2_post"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
        x = x + h
    return x, new_cache


def decode_step(
    params: Params, cache: Params, tokens: jax.Array, pos: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, Params]:
    """One serving step: tokens (B, 1) + position ``pos`` -> (logits (B,1,V), cache)."""
    x = embed_tokens(params["embedding"], tokens, cfg)
    x = constrain(x, ("batch", "act_seq", "act_embed"))

    new_prefix = []
    for i, (m, f) in enumerate(cfg.prefix_pattern):
        x, nc = _layer_decode(params["prefix"][i], x, cache["prefix"][i], pos, m, f, cfg)
        new_prefix.append(nc)

    def body(x, inputs):
        sp, sc = inputs
        new_sc = {}
        for i, (m, f) in enumerate(cfg.pattern):
            x, nc = _layer_decode(sp[f"l{i}"], x, sc[f"l{i}"], pos, m, f, cfg)
            new_sc[f"l{i}"] = nc
        return x, new_sc

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
    x = apply_rmsnorm(x, params["final_norm"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
    logits = unembed(params["embedding"], x, cfg)
    new_cache: Params = {"blocks": new_blocks}
    if cfg.prefix_pattern:
        new_cache["prefix"] = new_prefix
    return logits, new_cache
