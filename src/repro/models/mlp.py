"""Dense FFN: SwiGLU (llama/gemma family) or GeLU (starcoder2/hubert)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import tp_enter, tp_reduce
from .layers import Params, Specs, dense_init, dtype_of


def mlp_init(key, cfg: ModelConfig, d_ff: int = 0) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    if cfg.activation == "swiglu":
        # gate & up stacked on axis 1 -> one einsum, fewer HLO ops under scan
        return {
            "wi": dense_init(k1, (d, 2, ff), pdt, fan_in=d),
            "wo": dense_init(k2, (ff, d), pdt, fan_in=ff),
        }
    return {
        "wi": dense_init(k1, (d, 1, ff), pdt, fan_in=d),
        "wo": dense_init(k2, (ff, d), pdt, fan_in=ff),
    }


def mlp_specs(cfg: ModelConfig) -> Specs:
    return {"wi": ("embed", None, "ff"), "wo": ("ff", "embed")}


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    # Megatron split under the population TP seams (no-ops elsewhere): wi is
    # column-parallel over ff, wo row-parallel, one psum per MLP.
    h = jnp.einsum("bsd,dcf->bscf", tp_enter(x, "mlp"), p["wi"])
    if cfg.activation == "swiglu":
        h = jax.nn.silu(h[:, :, 0]) * h[:, :, 1]
    else:
        h = jax.nn.gelu(h[:, :, 0])
    return tp_reduce(jnp.einsum("bsf,fd->bsd", h, p["wo"]), "mlp")
