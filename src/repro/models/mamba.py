"""Mamba-1 selective-SSM mixer (falcon-mamba, jamba).

x -> in_proj -> (u, z); u -> causal depthwise conv(K) -> silu ->
selective scan (kernels.ops.ssm_scan; Pallas on TPU) -> y * silu(z) -> out_proj.

Decode keeps two pieces of state per layer: the last K-1 conv inputs and the
(B, d_inner, N) SSM state — O(1) in sequence length, which is why the
``long_500k`` cell runs on the SSM/hybrid archs only.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import tp_enter, tp_reduce
from ..kernels import ops
from .layers import Params, Specs, dense_init, dtype_of


def mamba_init(key, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    d, di, N, K, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv, cfg.dt_rank
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": dense_init(k1, (d, 2, di), pdt, fan_in=d),
        "conv_w": dense_init(k2, (K, di), pdt, fan_in=K),
        "conv_b": jnp.zeros((di,), pdt),
        "x_proj": dense_init(k3, (di, dr + 2 * N), pdt, fan_in=di),
        "dt_proj": dense_init(k4, (dr, di), pdt, fan_in=dr),
        # softplus(dt_bias) ~= 0.01: tokens start with slow dynamics
        "dt_bias": jnp.full((di,), math.log(math.expm1(0.01)), pdt),
        "A_log": jnp.log(A),                     # fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(k5, (di, d), pdt, fan_in=di),
    }


def mamba_specs(cfg: ModelConfig) -> Specs:
    return {
        "in_proj": ("embed", None, "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", None),
        "dt_proj": (None, "inner"),
        "dt_bias": ("inner",),
        "A_log": ("inner", None),
        "D": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _split_xproj(h: jax.Array, cfg: ModelConfig):
    dr, N = cfg.dt_rank, cfg.ssm_state
    return h[..., :dr], h[..., dr : dr + N], h[..., dr + N :]


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq: u (B, L, D), w (K, D)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    L = u.shape[1]
    out = sum(pad[:, j : j + L] * w[j] for j in range(K))
    return out + b


def mamba_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    # Population TP seams (no-ops elsewhere): every mamba weight carries the
    # d_inner channel dim, so the whole mixer runs on width-local channels —
    # in_proj column-parallel, out_proj row-parallel.  The x_proj seam is the
    # subtle one: its OUTPUT (dt_raw/Bc/Cc) must be replicated (Bc/Cc gate all
    # channels in the scan), so the row-parallel x_proj closes with tp_reduce,
    # and the immediately following tp_enter re-enters width-sharded consumers
    # (dt_proj, the local-channel scan) whose cotangents are partial.
    uz = jnp.einsum("bsd,dci->bsci", tp_enter(x, "mamba"), p["in_proj"])
    u, z = uz[:, :, 0], uz[:, :, 1]
    u = jax.nn.silu(_causal_conv(u, p["conv_w"], p["conv_b"]))
    h = tp_enter(tp_reduce(jnp.einsum("bsi,ij->bsj", u, p["x_proj"]), "mamba"), "mamba")
    dt_raw, Bc, Cc = _split_xproj(h, cfg)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_raw, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ops.ssm_scan(u, dt, A, Bc, Cc, p["D"], fused=cfg.fused_ssm)
    y = y * jax.nn.silu(z)
    return tp_reduce(jnp.einsum("bsi,id->bsd", y, p["out_proj"]), "mamba")


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba_cache_specs(cfg: ModelConfig) -> Specs:
    return {"conv": ("batch", None, "inner"), "h": ("batch", "inner", None)}


def mamba_decode(
    p: Params, x: jax.Array, cache: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step. x: (B, 1, d)."""
    uz = jnp.einsum("bsd,dci->bsci", x, p["in_proj"])
    u, z = uz[:, 0, 0], uz[:, 0, 1]                               # (B, di)
    window = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # (B, K, di)
    u_conv = jax.nn.silu(jnp.einsum("bki,ki->bi", window, p["conv_w"]) + p["conv_b"])
    dt_raw, Bc, Cc = _split_xproj(jnp.einsum("bi,ij->bj", u_conv, p["x_proj"]), cfg)
    dt = jax.nn.softplus(jnp.einsum("br,ri->bi", dt_raw, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h = ops.ssm_decode_step(u_conv, dt, A, Bc, Cc, p["D"], cache["h"])
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "h": h}
