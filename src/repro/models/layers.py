"""Shared primitives: initializers, norms, embeddings, dtype plumbing.

Parameters are plain nested dicts of jnp arrays.  Every init function has a
matching ``*_specs`` returning the same tree with tuples of *logical axis
names* as leaves; ``repro.distributed.sharding`` maps those onto the mesh.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops

Params = Dict[str, Any]
Specs = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


def dense_init(key, shape: Tuple[int, ...], dtype, fan_in: Optional[int] = None) -> jax.Array:
    """Truncated-normal with 1/sqrt(fan_in) scaling (fan_in = shape[0] default)."""
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    # 1/sqrt(d) keeps tied-unembedding logits O(1); gemma-style ``scale_embed``
    # multiplies activations back up by sqrt(d) after lookup.
    std = 1.0 / math.sqrt(d)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32) * std).astype(dtype)


# -- norm ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)  # stored as (gamma - 1), gemma convention


def rmsnorm_specs() -> Tuple:
    return (None,)


def apply_rmsnorm(x: jax.Array, gamma: jax.Array, eps: float,
                  fused: bool = False) -> jax.Array:
    return ops.rmsnorm(x, gamma, eps=eps, fused=fused)


# -- embedding / unembedding ----------------------------------------------------------
def embedding_init(key, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"embed": embed_init(k1, cfg.vocab_size, cfg.d_model, pdt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), pdt)
    return p


def embedding_specs(cfg: ModelConfig) -> Specs:
    s = {"embed": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        s["unembed"] = ("embed", "vocab")
    return s


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.compute_dtype))
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    return x


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final logits with optional gemma2 softcap; fp32 output for a stable loss."""
    table = params.get("unembed")
    if table is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, table).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits
