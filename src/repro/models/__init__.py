from . import attention, layers, mamba, mlp, moe, rope, transformer

__all__ = ["attention", "layers", "mamba", "mlp", "moe", "rope", "transformer"]
