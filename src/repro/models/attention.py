"""Attention mixers: GQA (global / sliding-window local) and MLA (DeepSeek).

Train/prefill paths call ``kernels.ops.attention`` (flash kernel on TPU, jnp
oracle elsewhere).  Decode paths update a KV cache at ``pos``:

* GQA caches (k, v) per layer — (B, max_seq, n_kv, head_dim);
* MLA caches the **compressed** latent (c_kv, k_rope) — 512+64 floats/token
  instead of 2·H·Dh = 4096 — and runs the *absorbed* decode form
  (q projected into latent space), which is the technique's entire point.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain, tp_enter, tp_reduce
from ..kernels import ops, ref
from .layers import Params, Specs, dense_init, dtype_of, rmsnorm_init
from .rope import apply_rope


# =============================== GQA ==============================================
def gqa_init(key, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, H, Dh), pdt, fan_in=d),
        "wk": dense_init(kk, (d, Hkv, Dh), pdt, fan_in=d),
        "wv": dense_init(kv, (d, Hkv, Dh), pdt, fan_in=d),
        "wo": dense_init(ko, (H, Dh, d), pdt, fan_in=H * Dh),
    }


def gqa_specs(cfg: ModelConfig) -> Specs:
    return {
        "wq": ("embed", "heads", "head"),
        "wk": ("embed", "kv_heads", "head"),
        "wv": ("embed", "kv_heads", "head"),
        "wo": ("heads", "head", "embed"),
    }


def gqa_apply(
    p: Params,
    x: jax.Array,                        # (B, S, d)
    cfg: ModelConfig,
    positions: jax.Array,                # (B, S)
    *,
    local: bool = False,
) -> jax.Array:
    # tp_enter/tp_reduce are the explicit tensor-parallel seams for the
    # population engines' shard_map path (no-ops elsewhere): heads shard over
    # the lane's model-axis row, so q/k/v projections are column-parallel and
    # the wo contraction is row-parallel.
    xs = tp_enter(x, "attn")
    q = jnp.einsum("bsd,dhk->bshk", xs, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xs, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xs, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # TP over heads when divisible, else Ulysses-style sequence parallelism:
    # "act_seq_attn" picks up the model axis only if "heads" could not.
    q = constrain(q, ("batch", "act_seq_attn", "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    out = ops.attention(
        q, k, v,
        causal=not cfg.encoder_only,
        window=cfg.sliding_window if local else None,
        softcap=cfg.attn_softcap,
        fused=cfg.fused_attention,
    )
    out = constrain(out, ("batch", "act_seq_attn", "heads", None))
    return tp_reduce(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "attn")


def gqa_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_seq, Hkv, Dh), dtype),
        "v": jnp.zeros((batch, max_seq, Hkv, Dh), dtype),
    }


def gqa_cache_specs(cfg: ModelConfig) -> Specs:
    return {
        "k": ("batch", "cache_seq", "kv_heads", "head"),
        "v": ("batch", "cache_seq", "kv_heads", "head"),
    }


def gqa_decode(
    p: Params,
    x: jax.Array,                        # (B, 1, d)
    cache: Dict[str, jax.Array],
    pos: jax.Array,                      # scalar int32: index being written
    cfg: ModelConfig,
    *,
    local: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    pos_b = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k = apply_rope(k, pos_b, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    out = ref.attention(
        q, ck, cv,
        causal=True,
        window=cfg.sliding_window if local else None,
        softcap=cfg.attn_softcap,
        q_offset=pos,
        kv_len=pos + 1,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}


# =============================== MLA ==============================================
def mla_init(key, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    d, H, Dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    r, rr = cfg.kv_lora_rank, cfg.rope_head_dim
    kq, ka, kb1, kb2, ko = jax.random.split(key, 5)
    return {
        "wq": dense_init(kq, (d, H, Dh + rr), pdt, fan_in=d),
        "wkv_a": dense_init(ka, (d, r + rr), pdt, fan_in=d),
        "kv_norm": rmsnorm_init(r, pdt),
        "wk_b": dense_init(kb1, (r, H, Dh), pdt, fan_in=r),
        "wv_b": dense_init(kb2, (r, H, Dh), pdt, fan_in=r),
        "wo": dense_init(ko, (H, Dh, d), pdt, fan_in=H * Dh),
    }


def mla_specs(cfg: ModelConfig) -> Specs:
    return {
        "wq": ("embed", "heads", "head"),
        "wkv_a": ("embed", None),
        "kv_norm": (None,),
        "wk_b": (None, "heads", "head"),
        "wv_b": (None, "heads", "head"),
        "wo": ("heads", "head", "embed"),
    }


def _mla_qkc(p, x, cfg, positions):
    """Shared q / compressed-kv computation. Returns (q_nope, q_rope, c, k_rope)."""
    from .layers import apply_rmsnorm

    Dh, rr = cfg.resolved_head_dim, cfg.rope_head_dim
    r = cfg.kv_lora_rank
    # TP seam discipline: wq is head-sharded (column parallel) so its input
    # passes through tp_enter, but wkv_a / kv_norm are REPLICATED and must
    # consume the raw x — routing their full contribution through the psum
    # seam would overcount those gradients width-fold.
    q = jnp.einsum("bsd,dhk->bshk", tp_enter(x, "attn"), p["wq"])
    q_nope, q_rope = q[..., :Dh], q[..., Dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c, k_rope = ckv[..., :r], ckv[..., r:]
    c = apply_rmsnorm(c, p["kv_norm"], cfg.norm_eps, fused=cfg.fused_rmsnorm)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c, k_rope


def mla_apply(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array) -> jax.Array:
    """Prefill/train path: decompress K,V and run standard attention."""
    Dh = cfg.resolved_head_dim
    q_nope, q_rope, c, k_rope = _mla_qkc(p, x, cfg, positions)
    # c / k_rope are replicated activations feeding head-sharded consumers
    # (wk_b / wv_b up-projections, the per-head rope broadcast) — tp_enter
    # here psums their head-local partial cotangents before they flow back
    # into the replicated wkv_a/kv_norm branch.
    c = tp_enter(c, "attn")
    k_rope = tp_enter(k_rope, "attn")
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c, p["wv_b"])
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (cfg.rope_head_dim,))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    scale = 1.0 / math.sqrt(Dh + cfg.rope_head_dim)
    q_full = constrain(q_full, ("batch", "act_seq_attn", "heads", None))
    k_full = constrain(k_full, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    out = ops.attention(q_full, k_full, v, causal=True, scale=scale,
                        fused=cfg.fused_attention)
    out = constrain(out, ("batch", "act_seq_attn", "heads", None))
    return tp_reduce(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "attn")


def mla_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    return {
        "c": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
    }


def mla_cache_specs(cfg: ModelConfig) -> Specs:
    return {"c": ("batch", "cache_seq", None), "kr": ("batch", "cache_seq", None)}


def mla_decode(
    p: Params, x: jax.Array, cache: Dict[str, jax.Array], pos: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed MLA decode: attention runs entirely in the latent space."""
    B = x.shape[0]
    pos_b = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _mla_qkc(p, x, cfg, pos_b)
    cc = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype), (0, pos, 0))
    ckr = jax.lax.dynamic_update_slice(cache["kr"], kr_new.astype(cache["kr"].dtype), (0, pos, 0))

    scale = 1.0 / math.sqrt(cfg.resolved_head_dim + cfg.rope_head_dim)
    # absorb wk_b into q: (B,1,H,Dh) x (r,H,Dh) -> (B,1,H,r)
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope.astype(jnp.float32), p["wk_b"].astype(jnp.float32))
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat, cc.astype(jnp.float32))
        + jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32), ckr.astype(jnp.float32))
    ) * scale
    S = cc.shape[1]
    mask = jnp.arange(S)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, ref.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cc.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhk->bqhk", out_lat, p["wv_b"].astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"c": cc, "kr": ckr}
