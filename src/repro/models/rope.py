"""Rotary position embeddings (half-rotation / llama convention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                                  # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv        # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                                  # (..., S, H, D): add head axis
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
