"""Model / training / parallelism configuration dataclasses.

One ``ModelConfig`` covers all 10 assigned families via the *super-block
pattern*: a model is ``prefix_layers`` (unscanned) followed by
``n_superblocks`` repetitions of ``pattern`` executed under ``lax.scan`` with
stacked parameters.  Each pattern entry is ``(mixer, ffn)``:

    mixer ∈ {"attn", "attn_local", "attn_mla", "mamba"}
    ffn   ∈ {"dense", "moe", "none"}

Scanning keeps the HLO size O(pattern) instead of O(n_layers) — essential for
compile time on 42-88-layer models — and gives XLA a natural window to overlap
per-layer collectives with the next layer's compute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

Pattern = Tuple[Tuple[str, str], ...]

MIXERS = ("attn", "attn_local", "attn_mla", "mamba")
FFNS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10000.0
    sliding_window: int = 4096           # window for "attn_local" mixers
    attn_softcap: Optional[float] = None     # gemma2: 50.0
    final_softcap: Optional[float] = None    # gemma2: 30.0
    # MLA (deepseek)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    # dense FFN
    d_ff: int = 0
    activation: str = "swiglu"           # "swiglu" | "gelu"
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    moe_groups: int = 1                  # dispatch groups (= data shards for EP; see moe.py)
    # Mamba
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0                 # 0 => ceil(d_model/16)
    # structure
    pattern: Pattern = (("attn", "dense"),)
    prefix_pattern: Pattern = ()         # unscanned leading layers (deepseek dense layer)
    encoder_only: bool = False           # bidirectional, no decode step
    tie_embeddings: bool = True
    frontend: str = "none"               # "none" | "audio" | "vision" (stub: embeddings in)
    norm_eps: float = 1e-6
    post_norm: bool = False              # gemma2: extra post-mixer/post-ffn norms
    scale_embed: bool = False            # gemma2: embeddings scaled by sqrt(d)
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # kernels: force the Pallas rmsnorm / flash attention / ssm scan
    # (interpret mode off TPU) inside the train step instead of the reference
    # ops.  Static model fields so the population compile caches key on them
    # (via static_step_key).
    fused_rmsnorm: bool = False
    fused_attention: bool = False
    fused_ssm: bool = False

    def __post_init__(self):
        for mixer, ffn in self.pattern + self.prefix_pattern:
            assert mixer in MIXERS, mixer
            assert ffn in FFNS, ffn
        body = self.n_layers - len(self.prefix_pattern)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {body} body layers not divisible by pattern of {len(self.pattern)}"
        )

    @property
    def n_superblocks(self) -> int:
        return (self.n_layers - len(self.prefix_pattern)) // len(self.pattern)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def has_attention(self) -> bool:
        pats = self.pattern + self.prefix_pattern
        return any(m.startswith("attn") for m, _ in pats)

    @property
    def has_mamba(self) -> bool:
        return any(m == "mamba" for m, _ in self.pattern + self.prefix_pattern)

    @property
    def has_moe(self) -> bool:
        return any(f == "moe" for _, f in self.pattern + self.prefix_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no pattern entry does full-length dense attention —
        the prompt's criterion for running long_500k."""
        return not any(m in ("attn", "attn_mla") for m, _ in self.pattern + self.prefix_pattern)

    # -- parameter counting (for roofline MODEL_FLOPS) ---------------------------
    def param_counts(self) -> Dict[str, float]:
        d, hd = self.d_model, self.resolved_head_dim
        counts = {"embed": self.vocab_size * d}
        if not self.tie_embeddings:
            counts["unembed"] = self.vocab_size * d

        def mixer_params(m: str) -> float:
            if m == "mamba":
                di, ds, dr = self.d_inner, self.ssm_state, self.dt_rank
                return (d * 2 * di + di * d + di * (dr + 2 * ds) + dr * di
                        + di * self.ssm_conv + di * ds + di)
            if m == "attn_mla":
                r, rr = self.kv_lora_rank, self.rope_head_dim
                # wq projects to (H, hd + rope_head_dim)
                q = d * self.n_heads * (hd + rr) if not self.q_lora_rank else (
                    d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (hd + rr))
                kv = d * (r + rr) + r * self.n_heads * (hd + hd)  # k_nope + v up-proj
                o = self.n_heads * hd * d
                return q + kv + o
            # gqa / local
            return d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d

        def ffn_params(f: str) -> float:
            if f == "none":
                return 0.0
            if f == "moe":
                n_mats = 3 if self.activation == "swiglu" else 2
                per = n_mats * d * self.moe_d_ff
                return (self.n_experts + self.n_shared_experts) * per + d * self.n_experts
            n_mats = 3 if self.activation == "swiglu" else 2
            return n_mats * d * self.d_ff

        def ffn_active(f: str) -> float:
            if f == "moe":
                n_mats = 3 if self.activation == "swiglu" else 2
                per = n_mats * d * self.moe_d_ff
                return (self.moe_top_k + self.n_shared_experts) * per
            return ffn_params(f)

        total_block = active_block = 0.0
        body = list(self.prefix_pattern) + list(self.pattern) * self.n_superblocks
        for m, f in body:
            total_block += mixer_params(m) + ffn_params(f)
            active_block += mixer_params(m) + ffn_active(f)
        counts["blocks_total"] = total_block
        counts["blocks_active"] = active_block
        counts["total"] = counts["embed"] + counts.get("unembed", 0) + total_block
        counts["active"] = counts["embed"] + counts.get("unembed", 0) + active_block
        return counts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: either a training step or a decode step."""

    name: str
    seq_len: int
    global_batch: int
    kind: str = "train"  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Parallelism + memory policy for one run."""

    mesh_shape: Tuple[int, ...] = (1, 1)
    mesh_axes: Tuple[str, ...] = ("data", "model")
    microbatch: int = 0                  # 0 = no gradient accumulation
    remat: str = "full"                  # "none" | "full" | "dots"
    # optimizer state dtypes (memory levers for the 100B+ archs)
    master_dtype: Optional[str] = None   # None = update params in param_dtype
    mu_dtype: str = "float32"
    nu_dtype: str = "float32"
    grad_allreduce_dtype: str = "bfloat16"  # gradient compression on the wire
    shard_cache_seq: bool = False        # long-context decode: shard KV/seq over data axis
    zero_stage: str = "fsdp"             # "fsdp" (params+opt data-sharded) | "zero1"
                                         # (params replicated over data, opt sharded:
                                         #  kills per-microbatch weight all-gathers)

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.mesh_axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    parallel: ParallelConfig = ParallelConfig()
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    z_loss: float = 0.0
