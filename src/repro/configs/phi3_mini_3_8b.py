"""phi3-mini-3.8b [dense] — 32L d=3072 32H (GQA kv=32 => MHA) ff=8192
vocab=32064.  RoPE + SwiGLU. [arXiv:2404.14219; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        n_layers=32,
        d_model=3072,
        vocab_size=32064,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        rope_theta=10000.0,
        activation="swiglu",
        pattern=(("attn", "dense"),),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        pattern=(("attn", "dense"),),
        tie_embeddings=False,
        param_dtype="float32",
        compute_dtype="float32",
    )
