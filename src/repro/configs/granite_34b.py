"""granite-34b [dense] — 88L d=6144 48H (MQA kv=1) ff=24576 vocab=49152.
Granite Code 34B: GPTBigCode-family MQA + 2-matrix GeLU MLP (the 3-matrix
SwiGLU variant would be 47B; the published checkpoint is ~34B).
[arXiv:2405.04324; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        n_layers=88,
        d_model=6144,
        vocab_size=49152,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        rope_theta=10000.0,
        activation="gelu",
        pattern=(("attn", "dense"),),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        pattern=(("attn", "dense"),),
        param_dtype="float32",
        compute_dtype="float32",
    )
