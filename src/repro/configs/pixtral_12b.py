"""pixtral-12b [vlm] — 40L d=5120 32H (GQA kv=8) ff=14336 vocab=131072.
Mistral-Nemo-style decoder backbone; the Pixtral ViT frontend is a STUB —
input_specs() supplies precomputed patch embeddings (B, S, d).
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        n_layers=40,
        d_model=5120,
        vocab_size=131072,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        rope_theta=1000000.0,
        activation="swiglu",
        pattern=(("attn", "dense"),),
        tie_embeddings=False,
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        pattern=(("attn", "dense"),),
        tie_embeddings=False,
        frontend="vision",
        param_dtype="float32",
        compute_dtype="float32",
    )
