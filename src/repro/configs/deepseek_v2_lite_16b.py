"""deepseek-v2-lite-16b [moe] — 27L d=2048 16H, MLA kv_lora=512, MoE 64
routed top-6 + 2 shared, expert ff=1408, vocab=102400.  First layer is a
dense-FFN MLA layer (prefix), the remaining 26 are MLA+MoE (scanned).
[arXiv:2405.04434; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        n_layers=27,
        d_model=2048,
        vocab_size=102400,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        kv_lora_rank=512,
        rope_head_dim=64,
        d_ff=10944,                 # the one dense layer
        n_experts=64,
        n_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1408,
        activation="swiglu",
        prefix_pattern=(("attn_mla", "dense"),),
        pattern=(("attn_mla", "moe"),),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke",
        n_layers=3,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        kv_lora_rank=32,
        rope_head_dim=16,
        d_ff=128,
        n_experts=8,
        n_shared_experts=2,
        moe_top_k=2,
        moe_d_ff=32,
        prefix_pattern=(("attn_mla", "dense"),),
        pattern=(("attn_mla", "moe"),),
        tie_embeddings=False,
        param_dtype="float32",
        compute_dtype="float32",
    )
