"""Architecture registry: 10 assigned archs + per-arch run policy.

``get_config(id)`` / ``get_smoke_config(id)`` return ModelConfigs;
``cells(id)`` enumerates the (arch x shape) dry-run cells with skip reasons
(encoder-only archs have no decode; long_500k runs only on sub-quadratic
archs — see DESIGN.md §5);
``memory_policy(id, shape)`` picks optimizer-state dtypes / microbatch so the
cell fits 16 GB/chip on the production mesh.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

from .base import SHAPES, ModelConfig, ParallelConfig, ShapeConfig, TrainConfig

ARCH_IDS: Tuple[str, ...] = (
    "gemma2-9b",
    "phi3-mini-3.8b",
    "starcoder2-3b",
    "granite-34b",
    "hubert-xlarge",
    "jamba-1.5-large-398b",
    "falcon-mamba-7b",
    "pixtral-12b",
    "deepseek-v2-lite-16b",
    "qwen3-moe-30b-a3b",
)

_MODULES = {
    "gemma2-9b": "gemma2_9b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "starcoder2-3b": "starcoder2_3b",
    "granite-34b": "granite_34b",
    "hubert-xlarge": "hubert_xlarge",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "pixtral-12b": "pixtral_12b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke()


def cells(arch: str) -> List[Tuple[ShapeConfig, Optional[str]]]:
    """All 4 shape cells for an arch, each with a skip reason or None."""
    cfg = get_config(arch)
    out: List[Tuple[ShapeConfig, Optional[str]]] = []
    for shape in SHAPES.values():
        skip = None
        if shape.kind == "decode" and cfg.encoder_only:
            skip = "encoder-only: no autoregressive decode step"
        elif shape.name == "long_500k" and not cfg.sub_quadratic:
            skip = "full-attention arch: 500k KV working set (prompt rule: sub-quadratic only)"
        out.append((shape, skip))
    return out


def memory_policy(arch: str, shape: ShapeConfig, multi_pod: bool = False) -> ParallelConfig:
    """Per-cell parallelism + memory policy targeting 16 GB/chip (v5e).

    Big-model levers: bf16 Adam moments, no fp32 master, microbatching.
    """
    mesh_shape = (2, 16, 16) if multi_pod else (16, 16)
    mesh_axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    big = arch in ("jamba-1.5-large-398b", "granite-34b")
    mu = nu = "bfloat16" if big else "float32"
    micro = 0
    if shape.kind == "train":
        # logits (mb, seq, vocab) are the activation-memory driver
        micro = {256: 32}.get(shape.global_batch, 0)
        if big:
            micro = 16
        # a microbatch smaller than the data parallelism cannot shard
        dp = 32 if multi_pod else 16
        if micro:
            micro = max(micro, dp)
    return ParallelConfig(
        mesh_shape=mesh_shape,
        mesh_axes=mesh_axes,
        microbatch=micro,
        remat="full" if shape.kind == "train" else "none",
        master_dtype=None,
        mu_dtype=mu,
        nu_dtype=nu,
        grad_allreduce_dtype="bfloat16",
        shard_cache_seq=(shape.name == "long_500k"),
    )
