"""jamba-1.5-large-398b [hybrid] — 72L d=8192 64H (GQA kv=8) ff=24576
vocab=65536, MoE 16e top-2.  Mamba:attention 7:1 interleave, MoE every 2nd
layer.  Super-block of 8: [M Mmoe M Mmoe A Mmoe M Mmoe] x 9. [arXiv:2403.19887; hf]"""
from .base import ModelConfig

_PATTERN = (
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("attn", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        n_layers=72,
        d_model=8192,
        vocab_size=65536,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        n_experts=16,
        moe_top_k=2,
        moe_d_ff=24576,
        ssm_state=16,
        ssm_expand=2,
        ssm_conv=4,
        activation="swiglu",
        pattern=_PATTERN,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        n_layers=8,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        n_experts=4,
        moe_top_k=2,
        moe_d_ff=64,
        ssm_state=8,
        pattern=_PATTERN,
        param_dtype="float32",
        compute_dtype="float32",
    )
