"""falcon-mamba-7b [ssm] — 64L d=4096, attention-free Mamba-1, ssm_state=16,
vocab=65024.  No FFN (the Mamba mixer is the whole block). [arXiv:2410.05355]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        n_layers=64,
        d_model=4096,
        vocab_size=65024,
        d_ff=0,
        ssm_state=16,
        ssm_expand=2,
        ssm_conv=4,
        pattern=(("mamba", "none"),),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        d_ff=0,
        ssm_state=8,
        pattern=(("mamba", "none"),),
        param_dtype="float32",
        compute_dtype="float32",
    )
