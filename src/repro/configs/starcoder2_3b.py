"""starcoder2-3b [dense] — 30L d=3072 24H (GQA kv=2) ff=12288 vocab=49152.
GQA + RoPE, GeLU MLP. [arXiv:2402.19173; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        n_layers=30,
        d_model=3072,
        vocab_size=49152,
        n_heads=24,
        n_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        rope_theta=999999.0,
        activation="gelu",
        pattern=(("attn", "dense"),),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        activation="gelu",
        pattern=(("attn", "dense"),),
        param_dtype="float32",
        compute_dtype="float32",
    )
