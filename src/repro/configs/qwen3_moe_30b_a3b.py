"""qwen3-moe-30b-a3b [moe] — 48L d=2048 32H (GQA kv=4), 128 experts top-8,
expert ff=768, vocab=151936, no shared experts. [hf:Qwen/Qwen3-30B-A3B]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        n_layers=48,
        d_model=2048,
        vocab_size=151936,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        rope_theta=1000000.0,
        n_experts=128,
        moe_top_k=8,
        moe_d_ff=768,
        activation="swiglu",
        pattern=(("attn", "moe"),),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        n_experts=8,
        moe_top_k=2,
        moe_d_ff=32,
        pattern=(("attn", "moe"),),
        tie_embeddings=False,
        param_dtype="float32",
        compute_dtype="float32",
    )
