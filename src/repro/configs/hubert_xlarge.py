"""hubert-xlarge [audio] — 48L d=1280 16H (MHA) ff=5120 vocab=504.
Encoder-only (bidirectional, no decode step); the CNN waveform frontend is a
STUB — input_specs() supplies precomputed frame embeddings (B, S, d).
vocab=504 is the masked-prediction codebook. [arXiv:2106.07447; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        n_layers=48,
        d_model=1280,
        vocab_size=504,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        activation="gelu",
        pattern=(("attn", "dense"),),
        encoder_only=True,
        tie_embeddings=False,
        frontend="audio",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        activation="gelu",
        pattern=(("attn", "dense"),),
        encoder_only=True,
        tie_embeddings=False,
        frontend="audio",
        param_dtype="float32",
        compute_dtype="float32",
    )
