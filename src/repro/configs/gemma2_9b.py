"""gemma2-9b [dense] — 42L d=3584 16H (GQA kv=8) ff=14336 vocab=256000.
Local(4096-window)+global alternating attention, attn softcap 50, final logit
softcap 30, post-norms, sqrt(d)-scaled embeddings. [arXiv:2408.00118; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        n_layers=42,
        d_model=3584,
        vocab_size=256000,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        rope_theta=10000.0,
        sliding_window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        activation="swiglu",
        pattern=(("attn_local", "dense"), ("attn", "dense")),
        post_norm=True,
        scale_embed=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke",
        n_layers=4,
        d_model=64,
        vocab_size=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        sliding_window=8,
        attn_softcap=50.0,
        final_softcap=30.0,
        pattern=(("attn_local", "dense"), ("attn", "dense")),
        post_norm=True,
        scale_embed=True,
        param_dtype="float32",
        compute_dtype="float32",
    )
