"""Device-resident prefetch ring: host-fed fused scans without device stalls.

The fused multi-step scan engine (``--chunk-steps``) removed the per-step
host round-trip by synthesizing batches *inside* the compiled program — which
works only for the counter-based synthetic stream.  ``PrefetchRing`` opens
that engine to host-supplied data: a ring of ``windows`` chunk-windows of
per-lane token blocks lives ON DEVICE as one ``(capacity, K, batch,
seq_len+1)`` int32 array, a background host thread fills windows ahead of the
consumer (``HostDataset.lane_block`` -> ``jax.device_put`` -> a donated
``dynamic_update_slice`` write), and the ring scan indexes it by
``step % capacity`` — device compute only waits on the feed if the host
falls a full ring behind.

Fence protocol (single lock + condition, two monotone step pointers):

- ``_filled_to``: batches for global steps ``[..., _filled_to)`` are on
  device at the current lane generation.  Advanced only by the fill thread.
- ``_consumed_to``: the driver has dispatched every scan that reads steps
  below this.  Advanced only by ``consume_to``.  The filler never lets
  ``_filled_to - _consumed_to`` exceed ``capacity`` — an unconsumed slot is
  never overwritten.

``wait_filled(s, want)`` blocks until steps ``[s, s + want)`` are filled
(accumulating ``fill_wait_s`` — the time device work actually waited on the
host).  The driver asks for exactly the ``ChunkPlanner.chunk_to`` horizon it
is about to dispatch, so chunk horizons stay capped to filled windows while
the dispatch sequence remains bit-identical to the in-scan-synth engine —
a lagging fill costs wait time, never a different chunk split (which would
reorder result arrival under a stateful proposer).

Donation ordering makes the single device array safe to rotate from the fill
thread: the write donates the ring buffer, and the runtime sequences it after
every already-dispatched scan that reads the old value; the driver always
re-fetches the current handle via ``slots()`` under the lock.

``set_lanes(streams, offsets, at_step)`` re-keys the ring when the lane
table changes (refill splice, PBT clone, restored snapshot): it bumps a
generation counter so in-flight and already-filled windows are discarded and
the filler restarts from ``at_step`` with the new per-lane cursors.  Lane
``i``'s batch for global step ``s`` is ``dataset.lane_block`` at step
``offsets[i] + s`` — offsets carry each lane's private data cursor
(``base_data - start`` in the streaming driver), so crash-restored lanes
resume mid-stream exactly.  A ``set_lanes`` call with an UNCHANGED lane
table is a no-op: hp-only event boundaries (rung truncations, hparam
updates) re-key with the same (stream, cursor) table, and the prefetched
windows they would otherwise discard are still byte-correct.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Optional, Sequence

import numpy as np


_RING_WRITE = None


def _ring_write(ring, block, slot0):
    """Write ``block`` (n, K, B, L+1) into the ring at ``slot0`` — the ring
    argument is DONATED, so rotation reuses the device buffer instead of
    doubling memory, and the runtime sequences the write after every
    in-flight scan that reads the old value."""
    global _RING_WRITE
    if _RING_WRITE is None:
        import jax

        def write(ring, block, slot0):
            return jax.lax.dynamic_update_slice_in_dim(
                ring, block, slot0, axis=0)

        _RING_WRITE = jax.jit(write, donate_argnums=(0,))
    return _RING_WRITE(ring, block, slot0)


class PrefetchRing:
    """W chunk-windows of per-lane token blocks on device, host-filled ahead.

    ``dataset`` is a ``repro.data.pipeline.HostDataset``; ``win_steps`` is
    the fused-scan chunk size (one window backs one maximal chunk);
    ``windows`` is the prefetch depth (2 = classic double buffering);
    ``sharding`` optionally places the lane axis on the ``pop`` mesh axis for
    the sharded engine (``NamedSharding(mesh, P(None, 'pop', None, None))``).
    """

    def __init__(self, dataset, population: int, win_steps: int,
                 windows: int = 2, sharding=None):
        import jax
        import jax.numpy as jnp

        assert windows >= 2, "need at least two windows to overlap fill"
        self.dataset = dataset
        self.population = int(population)
        self.win_steps = max(1, int(win_steps))
        self.windows = int(windows)
        self.capacity = self.windows * self.win_steps
        self._shape = (self.capacity, self.population,
                       int(dataset.global_batch), int(dataset.seq_len) + 1)
        self._sharding = sharding
        zeros = jnp.zeros(self._shape, jnp.int32)
        self._ring = (jax.device_put(zeros, sharding)
                      if sharding is not None else zeros)

        self._lock = threading.Condition()
        self._streams: Optional[np.ndarray] = None
        self._offsets: Optional[np.ndarray] = None
        self._gen = 0
        self._filled_to = 0
        self._consumed_to = 0
        self._stopped = False
        self._error: Optional[BaseException] = None

        # telemetry: time the consumer blocked on the feed vs time the host
        # spent producing — overlap_frac ~ 1 means fill fully hidden
        self.fill_wait_s = 0.0
        self.fill_busy_s = 0.0
        self.n_fills = 0
        self.n_invalidations = 0

        self._thread = threading.Thread(
            target=self._fill_loop, name="prefetch-ring-fill", daemon=True)
        self._thread.start()

    # -- driver-facing fences ---------------------------------------------------
    def set_lanes(self, streams: Sequence[int], offsets: Sequence[int],
                  at_step: int) -> None:
        """(Re)key the ring: lane ``i`` at global step ``s`` reads
        ``streams[i]`` at data step ``offsets[i] + s``.  Invalidate anything
        filled past ``at_step`` — the lane table changed under it."""
        assert len(streams) == self.population
        new_streams = np.asarray(list(streams), np.int64)
        new_offsets = np.asarray([int(o) for o in offsets], np.int64)
        with self._lock:
            if (self._streams is not None
                    and np.array_equal(self._streams, new_streams)
                    and np.array_equal(self._offsets, new_offsets)):
                # identical lane table: every filled window still maps the
                # same (stream, data-step) coordinates — keep the prefetch
                # instead of discarding it (hp-only event boundaries re-key
                # with an unchanged table every time)
                return
            if self._streams is not None and self._filled_to > int(at_step):
                self.n_invalidations += 1  # prefetched windows discarded
            self._streams = new_streams
            self._offsets = new_offsets
            self._gen += 1
            self._filled_to = int(at_step)
            self._consumed_to = int(at_step)
            self._lock.notify_all()

    def wait_filled(self, s: int, want: int = 1) -> int:
        """Block until batches for global steps ``[s, s + want)`` are on
        device; return the contiguous filled extent from ``s`` (>= ``want``).

        ``want`` must not exceed ``capacity``.  The driver asks for exactly
        the chunk it is about to dispatch, so the dispatch sequence is
        IDENTICAL to the in-scan-synth engine's — a lagging host fill shows
        up as ``fill_wait_s`` (and a lower ``overlap_frac``), never as a
        different chunk split, which would perturb result-arrival order under
        a stateful proposer."""
        want = max(1, min(int(want), self.capacity))
        t0 = None
        with self._lock:
            while self._filled_to < s + want and self._error is None \
                    and not self._stopped:
                if t0 is None:
                    t0 = time.perf_counter()
                self._lock.wait(timeout=0.5)
            if t0 is not None:
                self.fill_wait_s += time.perf_counter() - t0
            if self._error is not None:
                raise RuntimeError("prefetch ring fill failed") \
                    from self._error
            if self._stopped:
                raise RuntimeError("prefetch ring stopped while waiting")
            return int(self._filled_to - s)

    def consume_to(self, s: int) -> None:
        """All scans reading steps below ``s`` are dispatched — their slots
        may be rewritten (donation sequences the rewrite after the reads)."""
        with self._lock:
            if s > self._consumed_to:
                self._consumed_to = int(s)
                self._lock.notify_all()

    @contextlib.contextmanager
    def reserve(self):
        """The current device ring array, pinned for one dispatch.

        Dispatch the ring scan INSIDE this block: the fill thread's donated
        rotation deletes the old python handle, so a handle fetched outside
        the lock can die between fetch and dispatch.  Holding the lock spans
        only the (async) dispatch call — once dispatched, the runtime owns
        the buffer dependency and the rotation sequences after the read.
        """
        with self._lock:
            yield self._ring

    @property
    def overlap_frac(self) -> float:
        """Fraction of host fill time hidden behind device compute."""
        if self.fill_busy_s <= 0.0:
            return 1.0
        return max(0.0, min(1.0, 1.0 - self.fill_wait_s / self.fill_busy_s))

    def stop(self) -> None:
        with self._lock:
            self._stopped = True
            self._lock.notify_all()
        self._thread.join(timeout=5.0)

    # -- fill thread ------------------------------------------------------------
    def _fill_loop(self) -> None:
        import jax
        import jax.numpy as jnp

        try:
            while True:
                with self._lock:
                    while not self._stopped and (
                            self._streams is None
                            or self._filled_to - self._consumed_to
                            >= self.capacity):
                        self._lock.wait(timeout=0.5)
                    if self._stopped:
                        return
                    gen = self._gen
                    s0 = self._filled_to
                    streams = self._streams.copy()
                    offsets = self._offsets.copy()
                    free = self.capacity - (s0 - self._consumed_to)
                    slot0 = s0 % self.capacity
                    n = min(self.win_steps, self.capacity - slot0, free)

                t0 = time.perf_counter()
                window = getattr(self.dataset, "lane_window", None)
                if window is not None:
                    # one vectorized call per window — amortizes the
                    # per-call synthesis overhead across all n steps
                    block = window(streams, offsets + s0, n)
                else:
                    block = np.stack([
                        self.dataset.lane_block(streams, offsets + (s0 + t))
                        for t in range(n)
                    ])  # (n, K, B, L+1) int32
                dev = jax.device_put(
                    jnp.asarray(block, jnp.int32), self._sharding) \
                    if self._sharding is not None else jnp.asarray(
                        block, jnp.int32)

                with self._lock:
                    if self._stopped:
                        return
                    if gen != self._gen:
                        continue  # lane table changed mid-build: discard
                    self._ring = _ring_write(
                        self._ring, dev, jnp.asarray(slot0, jnp.int32))
                    self._filled_to = s0 + n
                    self.n_fills += 1
                    self.fill_busy_s += time.perf_counter() - t0
                    self._lock.notify_all()
        except BaseException as e:  # propagate to the blocked consumer
            with self._lock:
                self._error = e
                self._lock.notify_all()
