"""Deterministic synthetic data pipeline — one pure generator, host AND device.

Language modeling: a seeded 2nd-order Markov token stream — structured enough
that a model visibly learns (loss drops from ln(V) toward the process
entropy), cheap enough for CPU smoke training, and exactly reproducible from
``(seed, step)`` so a restored checkpoint resumes on the *same* batch sequence
(the data cursor is just the step counter).

**Counter-based synthesis.** Every random draw is a pure function of its
coordinates — ``hash(kind, seed, stream, step, shard, row, position)`` over
32-bit integer arithmetic (xor / rotate / wrapping multiply) that NumPy and
``jax.numpy`` execute bit-for-bit identically.  The same ``synth_batch``
therefore runs on the host (``xp=numpy`` — the classic ``make_batch`` path)
and *inside a compiled program* (``xp=jax.numpy`` — the fused multi-step scan
engine synthesizes its batches on device, ``repro.train.population.
make_population_scan_step``), and the two are bit-identical by construction.
There is no sequential PRNG state: a batch at ``(stream, step)`` never
depends on any other batch having been drawn.

Host sharding: ``make_batch(step, shard, n_shards)`` yields that host's slice
of the global batch; shards draw from disjoint hash streams.

Per-trial streams (population HPO): ``stream`` is two extra hash words so
every trial of a population consumes an *independent* data sequence;
``make_population_batch`` stacks K such batches along a leading population
axis for the vmapped/sharded engines.  Negative streams are reserved
sentinels (idle/padding population lanes): they wrap to the top of the u64
range, far from any real (small, non-negative) trial stream.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Protocol, Sequence, Tuple

import numpy as np

_U64 = 0xFFFFFFFFFFFFFFFF
_U32 = 0xFFFFFFFF

# draw kinds: the leading hash word, so the three per-position draw families
# (initial tokens / follow-the-rule uniforms / noise tokens) never collide
_KIND_INIT = 0xA11CE
_KIND_FOLLOW = 0xF0110
_KIND_NOISE = 0x707E5


def _rotl13(xp, h):
    u = xp.uint32
    return (h << u(13)) | (h >> u(19))


def _hash_u32(xp, shape, words) -> Any:
    """Combine integer ``words`` (scalars or arrays broadcastable against
    ``shape`` — pre-expand trailing dims yourself) into one uint32 hash.

    murmur3-style combine + finalizer over pure uint32 ops (xor, rotate,
    wrapping ``*``/``+``, logical shifts) — every op is specified bit-exactly
    by both NumPy and XLA, which is what makes host and device batches
    bit-identical.
    """
    u = xp.uint32
    h = xp.full(shape, 0x9E3779B9, dtype=xp.uint32)
    for w in words:
        if isinstance(w, (int, np.integer)):
            # mask host ints before the array constructor sees them: a top-half
            # sentinel word (e.g. 0xFFFFFFFF) must not overflow jnp's int32
            # literal inference
            w = np.uint32(int(w) & _U32)
        w = xp.broadcast_to(xp.asarray(w).astype(xp.uint32), shape)
        h = h ^ (w * u(0xCC9E2D51))
        h = _rotl13(xp, h)
        h = h * u(5) + u(0xE6546B64)
    h = h ^ (h >> u(16))
    h = h * u(0x85EBCA6B)
    h = h ^ (h >> u(13))
    h = h * u(0xC2B2AE35)
    h = h ^ (h >> u(16))
    return h


def _u01(xp, h):
    """uint32 hash -> float32 uniform in [0, 1): the top 24 bits scaled by
    2^-24 — exact in float32, so the comparison against ``order_mix`` lands
    identically on host and device."""
    return (h >> xp.uint32(8)).astype(xp.float32) * xp.float32(2.0 ** -24)


def _rule32(xp, a, b, vocab: int):
    """Fixed pseudo-random bigram successor function (the Markov 'language').

    Independent of seed/stream/step — it is the process being learned, not a
    noise source — and pure uint32, so the recurrence replays identically
    wherever it runs.
    """
    h = _hash_u32(xp, a.shape, [a.astype(xp.uint32), b.astype(xp.uint32)])
    return (h % xp.uint32(vocab)).astype(xp.int32)


def split_stream(stream: int) -> Tuple[int, int]:
    """A (possibly negative, possibly 64-bit) stream id as two uint32 hash
    words.  Negative sentinels wrap to the top of the u64 range, far from any
    real (small, non-negative) trial stream."""
    s = int(stream) & _U64
    return s & _U32, (s >> 32) & _U32


def split_streams(streams: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized ``split_stream``: two uint32[K] word arrays for the
    population engines (host-built once per flight, consumed on device)."""
    pairs = [split_stream(s) for s in streams]
    return (np.asarray([p[0] for p in pairs], np.uint32),
            np.asarray([p[1] for p in pairs], np.uint32))


def synth_tokens(xp, spec: "SyntheticLM", rows_shape, step, stream_lo,
                 stream_hi, shard=0):
    """The pure generator: token array of shape ``rows_shape + (seq_len+1,)``.

    ``rows_shape`` is the batch-rows shape (``(b,)`` for one batch,
    ``(K, b)`` for a population); ``step`` / ``stream_lo`` / ``stream_hi`` /
    ``shard`` are integers or arrays broadcastable against ``rows_shape``
    (pass per-lane values shaped ``(K, 1)``).  With ``xp=numpy`` this is the
    host path; with ``xp=jax.numpy`` it traces into a compiled program —
    same bits either way.  ``step`` may be a traced scalar/array under jax.
    """
    vocab = int(spec.vocab_size)
    row = xp.arange(rows_shape[-1], dtype=xp.uint32)
    coords = [spec.seed, stream_lo, stream_hi, step, shard, row]

    def draw(kind, t):
        return _hash_u32(xp, rows_shape, [kind] + coords + [t])

    def tok(kind, t):
        return (draw(kind, t) % xp.uint32(vocab)).astype(xp.int32)

    t0, t1 = tok(_KIND_INIT, 0), tok(_KIND_INIT, 1)
    mix = xp.float32(spec.order_mix)

    def next_tok(a, b, t):
        follow = _u01(xp, draw(_KIND_FOLLOW, t)) < mix
        return xp.where(follow, _rule32(xp, a, b, vocab), tok(_KIND_NOISE, t))

    if xp is np:
        toks = np.empty(rows_shape + (spec.seq_len + 1,), np.int32)
        toks[..., 0], toks[..., 1] = t0, t1
        for t in range(2, spec.seq_len + 1):
            toks[..., t] = next_tok(toks[..., t - 2], toks[..., t - 1], t)
        return toks
    import jax

    def body(carry, t):
        a, b = carry
        nxt = next_tok(a, b, t)
        return (b, nxt), nxt

    ts = xp.arange(2, spec.seq_len + 1, dtype=xp.uint32)
    _, rest = jax.lax.scan(body, (t0, t1), ts)
    rest = xp.moveaxis(rest, 0, -1)  # (T-2,) + rows -> rows + (T-2,)
    return xp.concatenate([t0[..., None], t1[..., None], rest], axis=-1)


def tokens_to_batch(xp, spec: "SyntheticLM", toks) -> Dict[str, Any]:
    """``synth_tokens`` output -> the training-batch dict contract
    (``tokens`` int32, ``targets`` int32, ``mask`` float32 ones)."""
    return {
        "tokens": toks[..., :-1],
        "targets": toks[..., 1:].astype(xp.int32),
        "mask": xp.ones(toks.shape[:-1] + (spec.seq_len,), xp.float32),
    }


def synth_batch(spec: "SyntheticLM", stream, step, *, xp=np, shard=0,
                n_shards: int = 1) -> Dict[str, Any]:
    """One training batch as a pure function of ``(stream, step)``.

    The single source of truth for batch synthesis: ``SyntheticLM.make_batch``
    is this with ``xp=numpy``; the fused scan engine calls it with
    ``xp=jax.numpy`` and a traced ``step`` so batches materialize on device,
    bit-identical to the host's.  ``stream`` must be a host int here (it is
    split into hash words); traced per-lane streams go through
    ``synth_population_batch``.
    """
    assert spec.global_batch % n_shards == 0
    b = spec.global_batch // n_shards
    lo, hi = split_stream(stream)
    toks = synth_tokens(xp, spec, (b,), step, lo, hi, shard=shard)
    return tokens_to_batch(xp, spec, toks)


def synth_population_batch(spec: "SyntheticLM", stream_lo, stream_hi, steps,
                           *, xp=np) -> Dict[str, Any]:
    """K per-lane batches with a leading population axis, from per-lane
    stream words (uint32[K], see ``split_streams``) and per-lane step cursors
    (int[K]; traced under jax).  Lane ``i``'s slab is bit-identical to
    ``synth_batch(spec, streams[i], steps[i])`` — the device-side twin of
    ``make_population_batch``.
    """
    k = stream_lo.shape[0]
    b = spec.global_batch
    lo = xp.asarray(stream_lo)[:, None]
    hi = xp.asarray(stream_hi)[:, None]
    st = xp.asarray(steps)[:, None]
    toks = synth_tokens(xp, spec, (k, b), st, lo, hi)
    return tokens_to_batch(xp, spec, toks)


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order_mix: float = 0.85  # P(follow the markov rule) vs uniform noise

    @property
    def spec_key(self) -> Tuple:
        """Hashable identity of the generator — keys the scan-step compile
        cache (a program bakes the batch synthesis in, so it is specific to
        this exact stream definition)."""
        return (int(self.vocab_size), int(self.seq_len),
                int(self.global_batch), int(self.seed), float(self.order_mix))

    def make_batch(
        self, step: int, shard: int = 0, n_shards: int = 1, stream: int = 0
    ) -> Dict[str, np.ndarray]:
        """Host batch: ``synth_batch`` evaluated with NumPy.  Bit-identical
        to the device synthesis at the same coordinates — the fused scan
        engine's equivalence contract."""
        return synth_batch(self, stream, int(step), xp=np, shard=int(shard),
                           n_shards=int(n_shards))

    def make_population_batch(
        self, step, streams: Sequence[int]
    ) -> Dict[str, np.ndarray]:
        """K independent per-trial batches stacked on a leading population axis.

        Trial ``i`` of the population consumes the stream ``streams[i]``
        sequence — leaf shapes become ``(K, batch, ...)`` for the population
        engines' ``per_trial_batch`` mode.  ``step`` may be a single int (all
        lanes at the same cursor — the batch-synchronous engines) or one int
        per lane: a *refilled* lane joined the flight late, so it replays its
        own stream from its own local step 0 while older lanes are further in.
        """
        steps = [int(step)] * len(streams) if np.isscalar(step) \
            else [int(s) for s in step]
        assert len(steps) == len(streams)
        lo, hi = split_streams(streams)
        return synth_population_batch(
            self, lo, hi, np.asarray(steps, np.int64), xp=np)


class HostDataset(Protocol):
    """What the prefetch ring needs from a host data source.

    One method: a *lane block* — per-lane token rows for K population lanes,
    each lane at its own step cursor, shaped ``(K, batch, seq_len + 1)`` int32
    (the raw ``synth_tokens`` layout; ``tokens_to_batch`` splits it into
    tokens/targets/mask on device).  Implementations must be pure functions
    of ``(streams, steps)`` so a crash-restored flight replays the same
    bytes — the ring's resume contract is exactly the data-cursor contract
    the synthetic stream already has.
    """

    seq_len: int
    global_batch: int

    def lane_block(self, streams: Sequence[int], steps) -> np.ndarray:
        """Token rows ``(K, global_batch, seq_len + 1)`` int32 for lane ``i``
        reading ``streams[i]`` at step ``steps[i]``."""
        ...

    # Implementations may additionally provide
    #     lane_window(streams, steps, n) -> (n, K, global_batch, seq_len + 1)
    # — ``n`` consecutive lane blocks built in one vectorized call,
    # bit-identical to stacking ``lane_block`` per step.  The ring's fill
    # thread prefers it: one call per prefetch window instead of one per
    # step keeps the host fill cheap enough to hide behind device compute.


@dataclasses.dataclass
class SynthHostDataset:
    """``HostDataset`` over the counter-based synthetic stream — the ring's
    bit-equality oracle.  ``lane_block`` evaluates the SAME ``synth_tokens``
    the fused scan traces on device (``xp=numpy`` here, ``xp=jax.numpy``
    there), so a ring filled from this adapter reproduces the in-scan synth
    engine's batches bit-for-bit: the cross-engine matrix can assert ring-fed
    scores equal in-scan-synth scores exactly."""

    spec: SyntheticLM

    @property
    def seq_len(self) -> int:
        return int(self.spec.seq_len)

    @property
    def global_batch(self) -> int:
        return int(self.spec.global_batch)

    def lane_block(self, streams: Sequence[int], steps) -> np.ndarray:
        lo, hi = split_streams(streams)
        st = np.asarray([int(s) for s in steps], np.int64)
        return synth_tokens(np, self.spec, (len(streams), self.global_batch),
                            st[:, None], lo[:, None], hi[:, None])

    def lane_window(self, streams: Sequence[int], steps, n: int) -> np.ndarray:
        """``n`` consecutive ``lane_block`` slabs — steps ``steps[i] + t`` for
        ``t in [0, n)`` — built in ONE vectorized synthesis call, shape
        ``(n, K, global_batch, seq_len + 1)``.  Bit-identical to stacking
        ``lane_block`` per step; one call amortizes the hash-round overhead
        over the whole prefetch window instead of paying it per step, which
        is what keeps the ring's fill thread cheap enough to hide."""
        lo, hi = split_streams(streams)
        st = np.asarray([int(s) for s in steps], np.int64)
        step = (st[None, :, None]
                + np.arange(int(n), dtype=np.int64)[:, None, None])
        return synth_tokens(
            np, self.spec, (int(n), len(streams), self.global_batch),
            step, lo[None, :, None], hi[None, :, None])


@dataclasses.dataclass
class ArrayHostDataset:
    """``HostDataset`` over a real token corpus held in host memory: a
    ``(n_rows, seq_len + 1)`` int32 array (e.g. a memory-mapped tokenized
    shard).  Lane ``i`` at step ``s`` reads ``global_batch`` consecutive rows
    starting at ``(streams[i] * stream_stride + s * global_batch) % n_rows``
    — per-trial streams start at disjoint offsets and the cursor is just the
    step counter, so resume replays identically."""

    tokens: np.ndarray
    global_batch: int
    stream_stride: int = 997  # co-prime-ish lane offset into the corpus

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32)
        assert self.tokens.ndim == 2 and len(self.tokens) > 0

    @property
    def seq_len(self) -> int:
        return int(self.tokens.shape[1]) - 1

    def lane_block(self, streams: Sequence[int], steps) -> np.ndarray:
        n = len(self.tokens)
        b = int(self.global_batch)
        out = np.empty((len(streams), b, self.tokens.shape[1]), np.int32)
        for i, (stream, step) in enumerate(zip(streams, steps)):
            start = (int(stream) * self.stream_stride + int(step) * b) % n
            idx = (start + np.arange(b)) % n
            out[i] = self.tokens[idx]
        return out

    def lane_window(self, streams: Sequence[int], steps, n: int) -> np.ndarray:
        """``n`` consecutive ``lane_block`` slabs in one gather, shape
        ``(n, K, global_batch, seq_len + 1)`` — same rows as stacking
        ``lane_block`` per step."""
        nrows = len(self.tokens)
        b = int(self.global_batch)
        sid = np.asarray([int(s) for s in streams], np.int64)
        st = np.asarray([int(s) for s in steps], np.int64)
        step = st[None, :] + np.arange(int(n), dtype=np.int64)[:, None]
        start = sid[None, :] * self.stream_stride + step * b
        idx = (start[..., None] + np.arange(b)) % nrows
        return self.tokens[idx]


class HostPrefetcher:
    """Prefetch-ahead feed for the SERIAL drivers: build batch ``s+1`` and
    dispatch its ``jax.device_put`` while the (asynchronously dispatched)
    step ``s`` program is still running, BEFORE the driver blocks on step
    ``s``'s loss.  A plain generator cannot do this — the consumer blocks on
    ``float(metrics["loss"])`` before it would ever pull the next item — so
    the serial loops call ``pop(s)`` / ``prefetch(s + 1)`` explicitly around
    the blocking read.  Batches are byte-identical to the direct
    ``make_batch`` path (same builder, same coordinates); only the timing of
    the host work moves.
    """

    def __init__(self, build):
        self._build = build  # step -> host batch (dict of numpy arrays)
        self._next: Any = None  # (step, device batch) or None

    def _put(self, step: int):
        import jax

        return jax.device_put(self._build(step))

    def prefetch(self, step: int) -> None:
        """Stage batch ``step`` on device ahead of time (async dispatch)."""
        self._next = (step, self._put(step))

    def pop(self, step: int):
        """The batch for ``step``: the staged one if it matches, else built
        on the spot (first step, or a driver that skipped around)."""
        if self._next is not None and self._next[0] == step:
            batch = self._next[1]
            self._next = None
            return batch
        self._next = None
        return self._put(step)


@dataclasses.dataclass
class SyntheticClassification:
    """Template-plus-noise image classification (paper §IV's MNIST stand-in).

    Each class has a fixed random template; samples are template + Gaussian
    noise.  Accuracy responds smoothly to model capacity / lr / dropout, so
    HPO curves (paper Fig. 5) are meaningful.
    """

    n_classes: int = 10
    image_size: int = 16
    noise: float = 5.0
    seed: int = 7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # spatially smooth class templates (low-frequency random fields):
        # conv + pooling layers can pick these up; white-noise templates would
        # make the task adversarial to exactly the architectures being tuned
        coarse = rng.standard_normal((self.n_classes, 4, 4)).astype(np.float32)
        up = self.image_size // 4
        smooth = np.kron(coarse, np.ones((1, up, up), np.float32))
        # light blur across pixels to avoid blocky edges
        smooth = (smooth + np.roll(smooth, 1, 1) + np.roll(smooth, 1, 2)
                  + np.roll(smooth, -1, 1) + np.roll(smooth, -1, 2)) / 5.0
        self.templates = (2.0 * smooth[..., None]).astype(np.float32)

    def make_split(self, n: int, seed: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed)
        labels = rng.integers(self.n_classes, size=n)
        x = self.templates[labels] + self.noise * rng.standard_normal(
            (n, self.image_size, self.image_size, 1)
        ).astype(np.float32)
        return {"x": x.astype(np.float32), "y": labels.astype(np.int32)}
