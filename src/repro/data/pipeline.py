"""Deterministic synthetic data pipeline.

Language modeling: a seeded 2nd-order Markov token stream — structured enough
that a model visibly learns (loss drops from ln(V) toward the process
entropy), cheap enough for CPU smoke training, and exactly reproducible from
``(seed, step)`` so a restored checkpoint resumes on the *same* batch sequence
(the data cursor is just the step counter).

Host sharding: ``make_batch(step, shard, n_shards)`` yields that host's slice
of the global batch; shards draw from disjoint seed streams.

Per-trial streams (population HPO): ``stream`` folds an HPO trial's stream id
into the PRNG seed so every trial of a population consumes an *independent*
data sequence; ``make_population_batch`` stacks K such batches along a leading
population axis for the vmapped/sharded engines.  ``stream=0`` reproduces the
legacy shared stream bit-for-bit, so pre-stream checkpoints still resume on
the same batch sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order_mix: float = 0.85  # P(follow the markov rule) vs uniform noise

    def _rule(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        # fixed pseudo-random bigram successor function
        return (a * 6364136223846793005 + b * 1442695040888963407 + 1013904223) % self.vocab_size

    def make_batch(
        self, step: int, shard: int = 0, n_shards: int = 1, stream: int = 0
    ) -> Dict[str, np.ndarray]:
        assert self.global_batch % n_shards == 0
        b = self.global_batch // n_shards
        # stream 0 keeps the legacy (seed, step, shard) entropy tuple so the
        # shared-stream batch sequence is unchanged; nonzero streams extend it.
        # Negative streams are reserved sentinels (idle/padding population
        # lanes) — masking to uint64 keeps SeedSequence happy and lands them
        # far away from any real (small, non-negative) trial stream.
        stream = int(stream) & 0xFFFFFFFFFFFFFFFF
        entropy = (self.seed, step, shard) + ((stream,) if stream else ())
        rng = np.random.default_rng(entropy)
        toks = np.empty((b, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(self.vocab_size, size=b)
        toks[:, 1] = rng.integers(self.vocab_size, size=b)
        for t in range(2, self.seq_len + 1):
            follow = rng.random(b) < self.order_mix
            nxt = self._rule(toks[:, t - 2].astype(np.int64), toks[:, t - 1].astype(np.int64))
            rand = rng.integers(self.vocab_size, size=b)
            toks[:, t] = np.where(follow, nxt, rand)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((b, self.seq_len), np.float32),
        }

    def make_population_batch(
        self, step, streams: Sequence[int]
    ) -> Dict[str, np.ndarray]:
        """K independent per-trial batches stacked on a leading population axis.

        Trial ``i`` of the population consumes the stream ``streams[i]``
        sequence — leaf shapes become ``(K, batch, ...)`` for the population
        engines' ``per_trial_batch`` mode.  ``step`` may be a single int (all
        lanes at the same cursor — the batch-synchronous engines) or one int
        per lane: a *refilled* lane joined the flight late, so it replays its
        own stream from its own local step 0 while older lanes are further in.
        """
        steps = [int(step)] * len(streams) if np.isscalar(step) else [int(s) for s in step]
        assert len(steps) == len(streams)
        per = [self.make_batch(st, stream=s) for st, s in zip(steps, streams)]
        return {k: np.stack([p[k] for p in per]) for k in per[0]}


@dataclasses.dataclass
class SyntheticClassification:
    """Template-plus-noise image classification (paper §IV's MNIST stand-in).

    Each class has a fixed random template; samples are template + Gaussian
    noise.  Accuracy responds smoothly to model capacity / lr / dropout, so
    HPO curves (paper Fig. 5) are meaningful.
    """

    n_classes: int = 10
    image_size: int = 16
    noise: float = 5.0
    seed: int = 7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # spatially smooth class templates (low-frequency random fields):
        # conv + pooling layers can pick these up; white-noise templates would
        # make the task adversarial to exactly the architectures being tuned
        coarse = rng.standard_normal((self.n_classes, 4, 4)).astype(np.float32)
        up = self.image_size // 4
        smooth = np.kron(coarse, np.ones((1, up, up), np.float32))
        # light blur across pixels to avoid blocky edges
        smooth = (smooth + np.roll(smooth, 1, 1) + np.roll(smooth, 1, 2)
                  + np.roll(smooth, -1, 1) + np.roll(smooth, -1, 2)) / 5.0
        self.templates = (2.0 * smooth[..., None]).astype(np.float32)

    def make_split(self, n: int, seed: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed)
        labels = rng.integers(self.n_classes, size=n)
        x = self.templates[labels] + self.noise * rng.standard_normal(
            (n, self.image_size, self.image_size, 1)
        ).astype(np.float32)
        return {"x": x.astype(np.float32), "y": labels.astype(np.int32)}
