from .pipeline import SyntheticLM, SyntheticClassification
