"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: Pallas kernels are validated against these
under ``interpret=True`` sweeps, and the dry-run / roofline path runs them so
XLA's cost analysis sees the true math.  fp32 accumulation everywhere it
matters (softmax, norm statistics, SSM state).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 statistics; returns x's dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def _softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def attention(
    q: jax.Array,                  # (B, Sq, H, Dq)
    k: jax.Array,                  # (B, Sk, Hkv, Dq)
    v: jax.Array,                  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding window (local attention)
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,             # position of q[0] within the kv sequence
    kv_len: Optional[jax.Array] = None,  # valid kv length (decode with cache)
) -> jax.Array:
    """Grouped-query attention oracle. Returns (B, Sq, H, Dv)."""
    B, Sq, H, Dq = q.shape
    _, Sk, Hkv, Dv = v.shape
    assert H % Hkv == 0, (H, Hkv)
    g = H // Hkv
    scale = scale if scale is not None else Dq ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    # grouped heads: n = kv head, g = query heads per kv head
    scores = jnp.einsum("bqngd,bknd->bngqk", qf.reshape(B, Sq, Hkv, g, Dq), kf)
    scores = _softcap(scores, softcap)

    q_pos = q_offset + jnp.arange(Sq)[:, None]          # (Sq, 1)
    k_pos = jnp.arange(Sk)[None, :]                     # (1, Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask &= k_pos < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)

    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def _block_bounds(iq, nk, block_q, block_kv, q_offset, causal, window, causal_skip):
    """Static [lo, hi) kv-block range visible to q block ``iq`` (flash skip)."""
    lo = 0
    if causal and causal_skip:
        hi = min(nk, (q_offset + (iq + 1) * block_q + block_kv - 1) // block_kv)
        if window is not None:
            lo = max(0, (q_offset + iq * block_q - window + 1) // block_kv)
    else:
        hi = nk
    return lo, hi


def _block_mask(q_pos, k_pos, causal, window, valid_k):
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    mask &= (k_pos < valid_k)[None, :]
    return mask


def _blocked_fwd(
    q, k, v, *, causal, window, softcap, scale, q_offset, kv_len,
    block_q, block_kv, causal_skip,
):
    """Flash-style forward. Returns (out (B,Sq,H,Dv), lse (B,Hkv,g,Sq) fp32)."""
    B, Sq, H, Dq = q.shape
    _, Sk, Hkv, Dv = v.shape
    g = H // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_kv
    qf = q.astype(jnp.float32) * scale
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (Sq + pad_q) // block_q, (Sk + pad_k) // block_kv
    # (nk, B, blk, Hkv, D): scan slices are contiguous loads
    kb = kf.reshape(B, nk, block_kv, Hkv, Dq).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(B, nk, block_kv, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    valid_k = Sk if kv_len is None else kv_len
    qf = qf.reshape(B, nq, block_q, Hkv, g, Dq)
    # Ulysses archs only (H doesn't divide the model axis): shard the
    # sequence dim inside each q block — without this the static q-block
    # loop replicates over the model axis.  When H divides, SPMD keeps the
    # (Hkv, g) product head-sharded across the reshape; constraining seq
    # there would force per-layer reshards (measured 2x worse on qwen3).
    from ..distributed.sharding import constrain, ctx_mesh
    mesh = ctx_mesh()
    seq_shard = (
        mesh is not None
        and "model" in mesh.axis_names
        and H % mesh.shape["model"] != 0
    )
    if seq_shard:
        qf = constrain(qf, ("batch", None, "act_seq_attn", "kv_heads", None, None))

    def q_block(iq, qblk):
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)
        m0 = jnp.full((B, Hkv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, block_q), jnp.float32)
        a0 = jnp.zeros((B, block_q, Hkv, g, Dv), jnp.float32)
        lo, hi = _block_bounds(iq, nk, block_q, block_kv, q_offset, causal, window, causal_skip)

        def body(carry, inp):
            m, l, acc, ik = carry
            kblk, vblk = inp
            s = jnp.einsum("bqngd,bknd->bngqk", qblk, kblk)
            s = _softcap(s, softcap)
            k_pos = ik * block_kv + jnp.arange(block_kv)
            mask = _block_mask(q_pos, k_pos, causal, window, valid_k)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # fully-masked rows keep m=-inf; exp(-inf - -inf) guard:
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bngqk,bknd->bqngd", p, vblk
            )
            return (m_new, l, acc, ik + 1), None

        (m, l, acc, _), _ = jax.lax.scan(
            body,
            (m0, l0, a0, jnp.full((), lo, jnp.int32)),
            (kb[lo:hi], vb[lo:hi]),
        )
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        if seq_shard:
            out = constrain(out, ("batch", "act_seq_attn", "kv_heads", None, None))
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        lse = m_safe + jnp.log(jnp.maximum(l, 1e-30))
        lse = jnp.where(jnp.isfinite(m), lse, NEG_INF)
        return out, lse

    outs, lses = [], []
    for i in range(nq):
        o, e = q_block(i, qf[:, i])
        outs.append(o)
        lses.append(e)
    out = jnp.stack(outs, axis=1).reshape(B, nq * block_q, H, Dv)[:, :Sq]
    lse = jnp.concatenate(lses, axis=-1)[..., :Sq]  # (B,Hkv,g,Sq)
    return out.astype(q.dtype), lse


def _blocked_bwd(
    q, k, v, out, lse, dout, *, causal, window, softcap, scale, q_offset,
    block_q, block_kv, causal_skip,
):
    """Flash backward: recompute P blockwise from (q,k,lse); no S^2 residuals."""
    B, Sq, H, Dq = q.shape
    _, Sk, Hkv, Dv = v.shape
    g = H // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_kv
    qf = q.astype(jnp.float32)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    do = dout.astype(jnp.float32)
    of = out.astype(jnp.float32)
    if pad_q:
        zq = ((0, 0), (0, pad_q), (0, 0), (0, 0))
        qf, do, of = jnp.pad(qf, zq), jnp.pad(do, zq), jnp.pad(of, zq)
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, pad_q)), constant_values=NEG_INF)
    if pad_k:
        zk = ((0, 0), (0, pad_k), (0, 0), (0, 0))
        kf, vf = jnp.pad(kf, zk), jnp.pad(vf, zk)
    nq, nk = (Sq + pad_q) // block_q, (Sk + pad_k) // block_kv
    kb = kf.reshape(B, nk, block_kv, Hkv, Dq).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(B, nk, block_kv, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    qf = qf.reshape(B, nq, block_q, Hkv, g, Dq)
    do = do.reshape(B, nq, block_q, Hkv, g, Dv)
    of = of.reshape(B, nq, block_q, Hkv, g, Dv)
    lse = lse.reshape(B, Hkv, g, nq, block_q)

    dkb0 = jnp.zeros_like(kb)
    dvb0 = jnp.zeros_like(vb)

    def q_block(iq, qblk, doblk, oblk, lseblk, dkb, dvb):
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)
        delta = jnp.einsum("bqngd,bqngd->bngq", doblk, oblk)  # (B,Hkv,g,blk_q)
        # rows with the NEG_INF sentinel (fully masked / q padding) must give
        # p = exp(s - inf) = 0, never exp(s + 1e30)
        lse_safe = jnp.where(lseblk > NEG_INF / 2, lseblk, jnp.inf)
        lo, hi = _block_bounds(iq, nk, block_q, block_kv, q_offset, causal, window, causal_skip)
        dq0 = jnp.zeros((B, block_q, Hkv, g, Dq), jnp.float32)

        def body(carry, inp):
            dq, dkb, dvb, ik = carry
            kblk, vblk = inp
            s_raw = scale * jnp.einsum("bqngd,bknd->bngqk", qblk, kblk)
            if softcap is not None:
                tanh_val = jnp.tanh(s_raw / softcap)
                s = softcap * tanh_val
            else:
                s = s_raw
            k_pos = ik * block_kv + jnp.arange(block_kv)
            mask = _block_mask(q_pos, k_pos, causal, window, Sk)
            p = jnp.where(
                mask[None, None, None], jnp.exp(s - lse_safe[..., None]), 0.0
            )
            dv_c = jnp.einsum("bngqk,bqngd->bknd", p, doblk)
            dp = jnp.einsum("bqngd,bknd->bngqk", doblk, vblk)
            ds = p * (dp - delta[..., None])
            if softcap is not None:
                ds = ds * (1.0 - tanh_val * tanh_val)
            ds = ds * scale
            dq = dq + jnp.einsum("bngqk,bknd->bqngd", ds, kblk)
            dk_c = jnp.einsum("bngqk,bqngd->bknd", ds, qblk)
            j = ik - lo
            dkb = jax.lax.dynamic_update_index_in_dim(
                dkb, jax.lax.dynamic_index_in_dim(dkb, j, 0, False) + dk_c, j, 0
            )
            dvb = jax.lax.dynamic_update_index_in_dim(
                dvb, jax.lax.dynamic_index_in_dim(dvb, j, 0, False) + dv_c, j, 0
            )
            return (dq, dkb, dvb, ik + 1), None

        (dq, dkw, dvw, _), _ = jax.lax.scan(
            body,
            (dq0, dkb[lo:hi], dvb[lo:hi], jnp.full((), lo, jnp.int32)),
            (kb[lo:hi], vb[lo:hi]),
        )
        dkb = jax.lax.dynamic_update_slice_in_dim(dkb, dkw, lo, 0)
        dvb = jax.lax.dynamic_update_slice_in_dim(dvb, dvw, lo, 0)
        return dq, dkb, dvb

    dqs = []
    dkb, dvb = dkb0, dvb0
    for i in range(nq):
        dq_i, dkb, dvb = q_block(i, qf[:, i], do[:, i], of[:, i], lse[:, :, :, i], dkb, dvb)
        dqs.append(dq_i)
    dq = jnp.stack(dqs, axis=1).reshape(B, nq * block_q, H, Dq)[:, :Sq]
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nk * block_kv, Hkv, Dq)[:, :Sk]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nk * block_kv, Hkv, Dv)[:, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.lru_cache(maxsize=None)
def _make_blocked_attention(causal, window, softcap, scale, q_offset, block_q, block_kv, causal_skip):
    """custom_vjp blocked attention for a static config (flash fwd + bwd)."""
    kw = dict(
        causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, block_q=block_q, block_kv=block_kv,
        causal_skip=causal_skip,
    )

    @jax.custom_vjp
    def attn(q, k, v):
        with jax.named_scope("kernel_flash_attn"):
            out, _ = _blocked_fwd(q, k, v, kv_len=None, **kw)
        return out

    def fwd(q, k, v):
        with jax.named_scope("kernel_flash_attn"):
            out, lse = _blocked_fwd(q, k, v, kv_len=None, **kw)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        with jax.named_scope("kernel_flash_attn_bwd"):
            return _blocked_bwd(q, k, v, out, lse, dout, **kw)

    attn.defvjp(fwd, bwd)
    return attn


def attention_blocked(
    q: jax.Array,                  # (B, Sq, H, Dq)
    k: jax.Array,                  # (B, Sk, Hkv, Dq)
    v: jax.Array,                  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
    block_q: int = 1024,
    block_kv: int = 1024,
    causal_skip: bool = True,
) -> jax.Array:
    """Flash-style blocked attention in pure jnp: the CPU/dry-run stand-in for
    the Pallas kernel.

    Numerically equivalent to ``attention`` (fp32 online softmax) but never
    materializes the (Sq, Sk) score matrix, statically skips out-of-mask kv
    blocks, and carries a **flash custom_vjp**: backward recomputes P
    blockwise from (q, k, lse) instead of letting the scan VJP stack O(S^2)
    probability residuals.
    """
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if kv_len is None and isinstance(q_offset, int):
        fn = _make_blocked_attention(
            causal, window, softcap, scale, q_offset, block_q, block_kv, causal_skip
        )
        return fn(q, k, v)
    out, _ = _blocked_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, kv_len=kv_len, block_q=block_q, block_kv=block_kv,
        causal_skip=causal_skip,
    )
    return out


def ssm_scan(
    x: jax.Array,    # (B, L, D)  post-conv/silu inputs
    dt: jax.Array,   # (B, L, D)  softplus'd timestep
    A: jax.Array,    # (D, N)     negative state matrix (continuous)
    Bc: jax.Array,   # (B, L, N)  input gate
    Cc: jax.Array,   # (B, L, N)  output gate
    D: jax.Array,    # (D,)       skip
    h0: Optional[jax.Array] = None,  # (B, D, N) initial state
    chunk: int = 128,
):
    """Mamba-1 selective scan oracle (chunked lax.scan, fp32 state).

    Returns (y: (B, L, D), h_last: (B, D, N)).

    Discretization: dA = exp(dt*A), dB = dt*B (Euler for B as in Mamba).
    """
    Bsz, L, Dm = x.shape
    N = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)

    h = jnp.zeros((Bsz, Dm, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    pad = (-L) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
        Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
        Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    def chunk_body(h, inp):
        xc, dtc, bc, cc = inp  # (B, Q, D), (B, Q, D), (B, Q, N), (B, Q, N)
        dA = jnp.exp(dtc[..., None] * Af)                 # (B, Q, D, N)
        dBx = (dtc * xc)[..., None] * bc[:, :, None, :]   # (B, Q, D, N)

        def assoc(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        aa, bb = jax.lax.associative_scan(assoc, (dA, dBx), axis=1)
        hs = aa * h[:, None] + bb                          # (B, Q, D, N)
        yc = jnp.einsum("bqdn,bqn->bqd", hs, cc)
        return hs[:, -1], yc

    xs = (
        xf.reshape(Bsz, nc, chunk, Dm).transpose(1, 0, 2, 3),
        dtf.reshape(Bsz, nc, chunk, Dm).transpose(1, 0, 2, 3),
        Bf.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3),
        Cf.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3),
    )
    h_last, ys = jax.lax.scan(chunk_body, h, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, Lp, Dm)[:, :L]
    y = y + xf[:, :L] * D.astype(jnp.float32)
    return y.astype(x.dtype), h_last


def ssm_decode_step(x, dt, A, Bc, Cc, D, h):
    """Single-token SSM state update.  x,dt: (B, D); Bc,Cc: (B, N); h: (B, D, N)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * A.astype(jnp.float32))        # (B, D, N)
    dBx = (dtf * xf)[..., None] * Bc.astype(jnp.float32)[:, None, :]
    h = h * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32)) + xf * D.astype(jnp.float32)
    return y.astype(x.dtype), h
