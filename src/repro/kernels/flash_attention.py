"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

TPU-native adaptation of the flash algorithm:

* grid = (batch, q_heads, num_q_blocks, num_kv_blocks) — on TPU the last grid
  dimension iterates sequentially on-core, so the online-softmax state for one
  (b, h, iq) lives in VMEM scratch across the kv sweep; no HBM round-trips.
* BlockSpec tiling: q tile (block_q, head_dim) and k/v tiles
  (block_kv, head_dim) are staged HBM->VMEM by Pallas; the (block_q, block_kv)
  score tile exists only in VMEM/VREGs and is immediately consumed by the MXU
  for the P·V partial product — the memory win the roofline counts.
* GQA: the q-head grid coordinate maps to kv head h // group via the k/v
  index_maps — kv tiles are fetched once per group on TPU (grid order makes
  consecutive h hit the same kv tile).
* causal / sliding-window masks + gemma2 logit softcap computed from iota
  inside the kernel; fully-masked tiles still run (masked to -inf) — block
  *skipping* is done by the jnp stand-in and is a documented follow-up here
  (splash-style index maps).

Backward: ``flash_attention`` is wrapped in jax.custom_vjp — forward is this
kernel (plus an lse output), backward reuses the validated flash-structured
jnp backward from ``ref`` (blockwise P recompute, no O(S^2) residuals).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,           # (1, block_q/kv, 1, D) VMEM tiles
    o_ref, lse_ref,                # outputs
    m_scr, l_scr, acc_scr,         # VMEM scratch carried across the kv sweep
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    block_q: int,
    block_kv: int,
    nk: int,
    sq: int,
    sk: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, Dv)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                           # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = (q_pos < sq) & (k_pos < sk)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe[:, None]), 0.0)
    corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_safe), 0.0)
    m_scr[...] = m_new
    l_scr[...] = l_prev * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        m = m_scr[...]
        lse = jnp.where(m > NEG_INF / 2, m + jnp.log(l), NEG_INF)
        lse_ref[0, 0, :] = lse.astype(lse_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_kv", "interpret"),
)
def _flash_fwd_pallas(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Sk, Hkv, D)
    v: jax.Array,   # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    scale: float,
    block_q: int,
    block_kv: int,
    interpret: bool,
):
    B, Sq, H, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    assert H % Hkv == 0
    g = H // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Sk + pad_k) // block_kv

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, nk=nk, sq=Sq, sk=Sk,
    )
    out, lse = _call(kernel, grid, q, k, v, B, Sq, H, D, Dv, pad_q, block_q, block_kv, g, interpret)
    return out[:, :Sq], lse[..., :Sq]


def _call(kernel, grid, q, k, v, B, Sq, H, D, Dv, pad_q, block_q, block_kv, g, interpret):
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, iq, ik: (b, ik, h // g, 0)),
            pl.BlockSpec((1, block_kv, 1, Dv), lambda b, h, iq, ik: (b, ik, h // g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, Dv), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sq + pad_q, H, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq + pad_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


@functools.lru_cache(maxsize=None)
def _make_flash(causal, window, softcap, scale, block_q, block_kv, interpret):
    kw = dict(causal=causal, window=window, softcap=softcap, scale=scale,
              block_q=block_q, block_kv=block_kv)

    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = _flash_fwd_pallas(q, k, v, interpret=interpret, **kw)
        return out

    def fwd(q, k, v):
        out, lse = _flash_fwd_pallas(q, k, v, interpret=interpret, **kw)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        B, Sq, H, D = q.shape
        Hkv = k.shape[2]
        g = H // Hkv
        # ref's flash backward wants lse as (B, Hkv, g, Sq)
        lse_r = lse.reshape(B, Hkv, g, Sq)
        return ref._blocked_bwd(
            q, k, v, out, lse_r, dout,
            causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=0, block_q=block_q, block_kv=block_kv, causal_skip=True,
        )

    attn.defvjp(fwd, bwd)
    return attn


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Pallas flash attention (GQA, sliding window, softcap); flash-vjp grads."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    fn = _make_flash(causal, window, softcap, scale, block_q, block_kv, interpret)
    return fn(q, k, v)
