"""Mamba-1 selective-scan Pallas kernel (TPU target, interpret-validated).

TPU-native adaptation of the Mamba CUDA scan: instead of a warp-level
parallel scan, the sequence is cut into VMEM-sized chunks and the grid's last
dimension sweeps chunks **sequentially on-core**, carrying the (D_blk, N) SSM
state in VMEM scratch — the TPU analogue of keeping the recurrence in
registers/SMEM.  Within a chunk the recurrence runs as a fori_loop of rank-1
state updates, fully vectorized over the channel block on the VPU:

    h[t] = exp(dt[t] * A) * h[t-1] + (dt[t] * x[t]) ⊗ B[t]
    y[t] = h[t] · C[t] + D * x[t]

grid = (batch, D/block_d, L/chunk); block spec tiles:
    x, dt  (chunk, block_d)   B, C  (chunk, N)   A (block_d, N)   D (block_d,)

The channel dim is blocked (block_d) so falcon-mamba's d_inner=8192 chunk
tiles stay ~4 MiB; N=16 keeps the state tiny.  fp32 state throughout.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                y_ref, hout_ref, h_scr, *, chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0, :, :].astype(jnp.float32)

    x = x_ref[0, :, :].astype(jnp.float32)     # (chunk, Dblk)
    dt = dt_ref[0, :, :].astype(jnp.float32)   # (chunk, Dblk)
    bc = b_ref[0, :, :].astype(jnp.float32)    # (chunk, N)
    cc = c_ref[0, :, :].astype(jnp.float32)    # (chunk, N)
    a = a_ref[...].astype(jnp.float32)         # (Dblk, N)
    d = d_ref[...].astype(jnp.float32)         # (Dblk,)

    def step(t, carry):
        h, y = carry
        dA = jnp.exp(dt[t][:, None] * a)                     # (Dblk, N)
        dBx = (dt[t] * x[t])[:, None] * bc[t][None, :]       # (Dblk, N)
        h = h * dA + dBx
        yt = h @ cc[t] + d * x[t]                            # (Dblk,)
        y = jax.lax.dynamic_update_index_in_dim(y, yt, t, 0)
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_scr[...] = h
    y_ref[0, :, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _finish():
        hout_ref[0, :, :] = h

@functools.partial(
    jax.jit, static_argnames=("chunk", "block_d", "interpret")
)
def ssm_scan_pallas(
    x: jax.Array,    # (B, L, D)
    dt: jax.Array,   # (B, L, D)
    A: jax.Array,    # (D, N)
    Bc: jax.Array,   # (B, L, N)
    Cc: jax.Array,   # (B, L, N)
    D: jax.Array,    # (D,)
    h0: Optional[jax.Array] = None,   # (B, D, N)
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,D), h_last (B,D,N)); matches ref.ssm_scan."""
    B, L, Dm = x.shape
    N = A.shape[1]
    block_d = min(block_d, Dm)
    assert Dm % block_d == 0, (Dm, block_d)
    pad = (-L) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0))
        x, dt = jnp.pad(x, zp), jnp.pad(dt, zp)
        Bc, Cc = jnp.pad(Bc, zp), jnp.pad(Cc, zp)
    Lp = L + pad
    nc = Lp // chunk
    nd = Dm // block_d
    if h0 is None:
        h0 = jnp.zeros((B, Dm, N), jnp.float32)

    grid = (B, nd, nc)
    kernel = functools.partial(_ssm_kernel, chunk=chunk, nc=nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, j, ic: (b, ic, j)),
            pl.BlockSpec((1, chunk, block_d), lambda b, j, ic: (b, ic, j)),
            pl.BlockSpec((1, chunk, N), lambda b, j, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, j, ic: (b, ic, 0)),
            pl.BlockSpec((block_d, N), lambda b, j, ic: (j, 0)),
            pl.BlockSpec((block_d,), lambda b, j, ic: (j,)),
            pl.BlockSpec((1, block_d, N), lambda b, j, ic: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, j, ic: (b, ic, j)),
            pl.BlockSpec((1, block_d, N), lambda b, j, ic: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Lp, Dm), x.dtype),
            jax.ShapeDtypeStruct((B, Dm, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bc, Cc, A, D, h0)
    return y[:, :L], h_last
