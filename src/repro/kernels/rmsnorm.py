"""Fused RMSNorm Pallas kernel (TPU target, interpret-validated on CPU).

One pass over HBM instead of XLA's normalize-then-scale chain: each grid step
loads a (block_rows, d) tile into VMEM, computes fp32 row statistics on the
VPU, applies the (1 + gamma) scale, and writes the tile back in the input
dtype.  d stays whole per tile (a row's statistic needs the full feature dim)
— all assigned archs have d <= 8192, i.e. <= 32 KiB fp32 per row, far under
VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps) * (1.0 + g_ref[...].astype(jnp.float32))[None, :]
    o_ref[...] = (x * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(
    x: jax.Array,
    gamma: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """RMSNorm over the last dim; leading dims are flattened into rows."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = ((rows + pad) // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, gamma)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
