"""Dispatching wrappers for the kernel layer.

``attention`` / ``rmsnorm`` / ``ssm_scan`` choose between the Pallas TPU
kernel and the pure-jnp oracle:

* backend == "tpu" and shapes are tile-aligned  -> pallas kernel
* anything else (CPU container, dry-run, odd shapes) -> ref oracle

``force`` overrides for tests: "ref", "pallas" (with interpret=True on CPU).
The dry-run always takes the ref path so XLA cost analysis sees the real math.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref

_FORCE = os.environ.get("REPRO_KERNELS", "")  # "", "ref", "pallas"
# perf levers (exposed for §Perf baseline/optimized comparisons)
_BLOCKED_MIN_SK = int(os.environ.get("REPRO_ATTN_BLOCKED_MIN_SK", "2048"))
_CAUSAL_SKIP = os.environ.get("REPRO_ATTN_CAUSAL_SKIP", "1") == "1"


def _use_pallas(interpret_ok: bool = False) -> bool:
    if _FORCE == "ref":
        return False
    if _FORCE == "pallas":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# -- rmsnorm ---------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _fused_rmsnorm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    """Pallas rmsnorm on the training hot path (``--fused-rmsnorm``).

    The forward pass is the kernel (interpret mode off TPU — it handles
    unaligned feature dims, so the %128 tile gate below does not apply);
    the backward pass is the reference norm's VJP — exact w.r.t. the same
    math, and it keeps the kernel free of a hand-written transpose rule.
    """
    from .rmsnorm import rmsnorm_pallas

    return rmsnorm_pallas(x, gamma, eps=eps, interpret=_interpret())


def _fused_rmsnorm_fwd(x, gamma, eps):
    return _fused_rmsnorm(x, gamma, eps), (x, gamma)


def _fused_rmsnorm_bwd(eps, res, g):
    x, gamma = res
    _, vjp = jax.vjp(lambda xx, gg: ref.rmsnorm(xx, gg, eps), x, gamma)
    return vjp(g)


_fused_rmsnorm.defvjp(_fused_rmsnorm_fwd, _fused_rmsnorm_bwd)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
            fused: bool = False) -> jax.Array:
    if fused and _FORCE != "ref":
        return _fused_rmsnorm(x, gamma, float(eps))
    if _use_pallas() and x.shape[-1] % 128 == 0:
        from .rmsnorm import rmsnorm_pallas

        return rmsnorm_pallas(x, gamma, eps=eps, interpret=_interpret())
    with jax.named_scope("kernel_rmsnorm"):
        return ref.rmsnorm(x, gamma, eps)


# -- attention ---------------------------------------------------------------------
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
    fused: bool = False,
) -> jax.Array:
    B, Sq, H, Dq = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    if fused and _FORCE != "ref" and kv_len is None and q_offset == 0:
        # --fused-attention: force the Pallas flash kernel (interpret mode off
        # TPU) on the training hot path regardless of tile alignment — the
        # kernel pads q/k/v internally, so smoke-sized sequences work too.
        # Decode paths (kv_len / q_offset) keep the ref oracle.
        from .flash_attention import flash_attention

        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            interpret=_interpret(),
        )
    aligned = Sq % 128 == 0 and q.shape[1] == k.shape[1] and Dq in (64, 128, 192, 256) and Dv in (64, 128, 192, 256)
    if _use_pallas() and aligned and kv_len is None and q_offset == 0:
        from .flash_attention import flash_attention

        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            interpret=_interpret(),
        )
    if Sk > _BLOCKED_MIN_SK and isinstance(q_offset, int):
        # flash-style blocked jnp path: O(block^2) memory, static causal/window
        # block skipping — the CPU/dry-run stand-in for the Pallas kernel.
        # named_scope marks the region the TPU Pallas kernel fuses (its
        # internal tensors never touch HBM); the roofline analyzer separates
        # these bytes out (see launch/hlo_cost.py).
        with jax.named_scope("kernel_flash_attn"):
            return ref.attention_blocked(
                q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
                q_offset=q_offset, kv_len=kv_len,
                causal_skip=_CAUSAL_SKIP,
            )
    with jax.named_scope("kernel_attn"):
        return ref.attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset, kv_len=kv_len,
        )


# -- selective scan -------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _fused_ssm(x, dt, A, Bc, Cc, D, chunk: int):
    """Pallas selective scan on the training hot path (``--fused-ssm``).

    Forward is the chunked Pallas kernel (interpret mode off TPU; it pads L
    internally and ``block_d`` is snapped to a divisor of the channel dim so
    smoke geometries work); backward is the reference scan's VJP — exact
    w.r.t. the same math.  Fresh-state only (h0=None): the decode/resume
    paths keep the ref oracle.
    """
    import math as _math

    from .ssm_scan import ssm_scan_pallas

    return ssm_scan_pallas(
        x, dt, A, Bc, Cc, D, h0=None, chunk=chunk,
        block_d=_math.gcd(x.shape[-1], 512), interpret=_interpret())


def _fused_ssm_fwd(x, dt, A, Bc, Cc, D, chunk):
    return _fused_ssm(x, dt, A, Bc, Cc, D, chunk), (x, dt, A, Bc, Cc, D)


def _fused_ssm_bwd(chunk, res, ct):
    _, vjp = jax.vjp(lambda *a: ref.ssm_scan(*a, h0=None, chunk=chunk), *res)
    return vjp(ct)


_fused_ssm.defvjp(_fused_ssm_fwd, _fused_ssm_bwd)


def ssm_scan(x, dt, A, Bc, Cc, D, h0=None, chunk: int = 128, fused: bool = False):
    L = x.shape[1]
    if fused and _FORCE != "ref" and h0 is None:
        return _fused_ssm(x, dt, A, Bc, Cc, D, chunk)
    if _use_pallas() and L % chunk == 0 and x.shape[-1] % 128 == 0:
        from .ssm_scan import ssm_scan_pallas

        return ssm_scan_pallas(x, dt, A, Bc, Cc, D, h0=h0, chunk=chunk, interpret=_interpret())
    with jax.named_scope("kernel_ssm_scan"):
        return ref.ssm_scan(x, dt, A, Bc, Cc, D, h0=h0, chunk=chunk)


def ssm_decode_step(x, dt, A, Bc, Cc, D, h):
    return ref.ssm_decode_step(x, dt, A, Bc, Cc, D, h)
