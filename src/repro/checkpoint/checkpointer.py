"""Atomic, optionally-async checkpointing for pytree states.

Layout: ``<dir>/step_<n>/arrays.npz`` (flattened path->array) +
``manifest.json``.  Writes go to ``step_<n>.tmp`` then ``os.rename`` — a crash
mid-save never corrupts the latest checkpoint, which is the property the
fault-tolerance tests assert.  ``save_async`` snapshots to host memory
synchronously (cheap) and writes on a background thread so the train loop
keeps stepping.  ``latest_step``/``restore`` drive auto-resume in the
launchers; HPO trial checkpoints (Hyperband promotion, PBT inherit) use the
same machinery keyed by the proposer's ``hb_key``/``pbt_ckpt`` aux values.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def fix(node):
        if isinstance(node, dict) and node and all(k.isdigit() for k in node):
            return [fix(node[str(i)]) for i in range(len(node))]
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict[str, Any]] = None) -> str:
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        return self._write(step, flat, extra or {})

    def save_async(self, step: int, state: Any, extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # snapshot on the caller's thread (device->host); write in background
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}

        def _bg():
            try:
                self._write(step, flat, extra or {})
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=_bg, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict[str, Any]) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "time": time.time(), "keys": sorted(flat), **extra}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, default=str)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> Tuple[Any, Dict[str, Any]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return _unflatten(flat), manifest
