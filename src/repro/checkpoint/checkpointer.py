"""Atomic, optionally-async checkpointing for pytree states.

Layout: ``<dir>/step_<n>/arrays.npz`` (flattened path->array) +
``manifest.json``.  Writes go to ``step_<n>.tmp`` then ``os.rename`` — a crash
mid-save never corrupts the latest checkpoint, which is the property the
fault-tolerance tests assert.  ``save_async`` snapshots to host memory
synchronously (cheap) and writes on a background thread so the train loop
keeps stepping.  ``latest_step``/``restore`` drive auto-resume in the
launchers; HPO trial checkpoints (Hyperband promotion, PBT inherit) use the
same machinery keyed by the proposer's ``hb_key``/``pbt_ckpt`` aux values.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def fix(node):
        if isinstance(node, dict) and node and all(k.isdigit() for k in node):
            return [fix(node[str(i)]) for i in range(len(node))]
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict[str, Any]] = None) -> str:
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        return self._write(step, flat, extra or {})

    def save_async(self, step: int, state: Any, extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # snapshot on the caller's thread (device->host); write in background
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}

        def _bg():
            try:
                self._write(step, flat, extra or {})
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=_bg, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict[str, Any]) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        old = final + ".old"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "time": time.time(), "keys": sorted(flat), **extra}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, default=str)
        # atomic replace: never a window with NO restorable copy of this step.
        # rmtree(final) before the rename would lose the checkpoint if the
        # process dies in between — instead the previous dir is renamed aside
        # and only removed once the new one is in place; ``all_steps`` /
        # ``restore`` pick up an orphaned ``.old`` left by a crash here.
        if os.path.exists(old):
            shutil.rmtree(old)  # leftover from a previous crash, superseded
        if os.path.exists(final):
            os.rename(final, old)
        os.rename(tmp, final)
        if os.path.exists(old):
            shutil.rmtree(old)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            base = os.path.join(self.dir, f"step_{s:08d}")
            shutil.rmtree(base, ignore_errors=True)
            shutil.rmtree(base + ".old", ignore_errors=True)

    # -- restore ---------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        """Steps with a restorable checkpoint.  Non-conforming ``step_*``
        entries (junk files, partial copies) are skipped with a warning
        instead of bricking resume; an orphaned ``step_N.old`` (crash between
        the two renames in ``_write``) counts as step N."""
        import warnings

        out = set()
        for name in os.listdir(self.dir):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            base = name[:-4] if name.endswith(".old") else name
            try:
                step = int(base[5:])
            except ValueError:
                warnings.warn(
                    f"ignoring non-checkpoint entry {name!r} in {self.dir}",
                    stacklevel=2)
                continue
            if name.endswith(".old") and os.path.exists(os.path.join(self.dir, base)):
                continue  # superseded: the final dir for this step exists
            out.add(step)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> Tuple[Any, Dict[str, Any]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not os.path.exists(path) and os.path.exists(path + ".old"):
            path += ".old"  # crash between _write's renames: old copy survives
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return _unflatten(flat), manifest


class LaneSnapshotStore:
    """Per-trial lane snapshots for crash-safe streaming flights.

    Keyed by *lineage* — the trial's data-stream id, which is stable across
    flight restarts and ``--resume`` (the Experiment re-stamps a re-queued
    job's original stream) — each entry holds the latest harvested lane state
    (``make_lane_snapshot``) plus the host cursors needed to resume the lane
    mid-budget: local step, data cursor, applied-step base, stream word.

    In-memory always (flight-restart recovery inside one process); with
    ``root`` each ``put`` additionally lands on disk through a per-lineage
    ``Checkpointer`` (atomic replace, junk-hardened listing), which is what
    ``--resume`` reads after a host crash.  ``forget`` drops a completed
    trial's snapshot — it can never be leased again.
    """

    def __init__(self, root: Optional[str] = None, keep: int = 2):
        self.root = root
        self.keep = int(keep)
        self._mem: Dict[int, Tuple[Any, Dict[str, Any]]] = {}
        self._ckpt: Dict[int, Checkpointer] = {}
        self._lock = threading.Lock()
        self.n_persisted = 0
        if root:
            os.makedirs(root, exist_ok=True)

    def _ckpt_of(self, lineage: int) -> Checkpointer:
        with self._lock:
            ck = self._ckpt.get(lineage)
            if ck is None:
                ck = Checkpointer(
                    os.path.join(self.root, f"lineage_{int(lineage)}"),
                    keep=self.keep)
                self._ckpt[lineage] = ck
        return ck

    def put(self, lineage: int, snap: Any, meta: Dict[str, Any]) -> None:
        lineage = int(lineage)
        with self._lock:
            self._mem[lineage] = (snap, dict(meta))
        if self.root:
            self._ckpt_of(lineage).save(int(meta["local"]), snap, extra=meta)
            self.n_persisted += 1

    def get(self, lineage: int) -> Optional[Tuple[Any, Dict[str, Any]]]:
        lineage = int(lineage)
        with self._lock:
            hit = self._mem.get(lineage)
        if hit is not None:
            return hit
        if not self.root:
            return None
        d = os.path.join(self.root, f"lineage_{lineage}")
        if not os.path.isdir(d):
            return None
        ck = self._ckpt_of(lineage)
        if ck.latest_step() is None:
            return None
        snap, manifest = ck.restore()
        with self._lock:
            self._mem[lineage] = (snap, manifest)
        return snap, manifest

    def forget(self, lineage: int) -> None:
        lineage = int(lineage)
        with self._lock:
            self._mem.pop(lineage, None)
            self._ckpt.pop(lineage, None)
        if self.root:
            shutil.rmtree(
                os.path.join(self.root, f"lineage_{lineage}"), ignore_errors=True)

    def lineages(self) -> List[int]:
        """Every lineage with a restorable snapshot (memory or disk)."""
        out = set()
        with self._lock:
            out.update(self._mem)
        if self.root and os.path.isdir(self.root):
            for name in os.listdir(self.root):
                if name.startswith("lineage_"):
                    try:
                        out.add(int(name[8:]))
                    except ValueError:
                        continue
        return sorted(out)
