from .checkpointer import Checkpointer
