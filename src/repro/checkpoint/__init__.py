from .checkpointer import Checkpointer, LaneSnapshotStore
