"""The paper's §IV demonstration model: 2 conv + 2 fc, Adam, global dropout.

Hyperparameters exactly as the paper's experiment: ``conv1``, ``conv2``
(filter counts), ``fc1`` (hidden width), ``learning_rate``, ``dropout``, and
``n_iterations`` (epochs — the Hyperband/BOHB budget axis).  Trains on the
synthetic classification task and returns test accuracy, so HPO curves
(Fig. 4/5) are meaningful on CPU in seconds.

Also the EAS §V client model: ``arch`` json {"conv": [[f,k],...], "fc": n}
overrides the fixed two-conv structure, and function-preserving morphism
init (widen = channel duplication + halved outgoing weights, deepen =
identity-ish layer) gives children a warm start from the parent.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import SyntheticClassification


def _conv_init(key, k: int, cin: int, cout: int) -> jax.Array:
    std = 1.0 / math.sqrt(k * k * cin)
    return jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout), jnp.float32) * std


def init_cnn(key, arch: Dict[str, Any], n_classes: int = 10, image_size: int = 16):
    params: Dict[str, Any] = {"conv": []}
    cin = 1
    keys = jax.random.split(key, len(arch["conv"]) + 2)
    size = image_size
    for i, (f, k) in enumerate(arch["conv"]):
        params["conv"].append({"w": _conv_init(keys[i], k, cin, f), "b": jnp.zeros((f,))})
        cin = f
        size //= 2  # each conv block pools 2x
    flat = size * size * cin
    params["fc1"] = {
        "w": jax.random.truncated_normal(keys[-2], -2, 2, (flat, arch["fc"]), jnp.float32)
        / math.sqrt(flat),
        "b": jnp.zeros((arch["fc"],)),
    }
    params["out"] = {
        "w": jax.random.truncated_normal(keys[-1], -2, 2, (arch["fc"], n_classes), jnp.float32)
        / math.sqrt(arch["fc"]),
        "b": jnp.zeros((n_classes,)),
    }
    return params


def cnn_forward(params, x, dropout: float = 0.0, key=None):
    for layer in params["conv"]:
        x = jax.lax.conv_general_dilated(
            x, layer["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) + layer["b"]
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    if dropout > 0 and key is not None:
        mask = jax.random.bernoulli(key, 1 - dropout, x.shape)
        x = x * mask / (1 - dropout)
    return x @ params["out"]["w"] + params["out"]["b"]


def morph_params(key, parent_params, parent_arch, child_arch, n_classes=10, image_size=16):
    """Net2net-ish warm start: copy overlapping channels, init the rest fresh."""
    child = init_cnn(key, child_arch, n_classes, image_size)

    def copy_overlap(dst, src):
        sl = tuple(slice(0, min(a, b)) for a, b in zip(dst.shape, src.shape))
        return dst.at[sl].set(src[sl])

    for i in range(min(len(child["conv"]), len(parent_params["conv"]))):
        child["conv"][i]["w"] = copy_overlap(child["conv"][i]["w"], parent_params["conv"][i]["w"])
        child["conv"][i]["b"] = copy_overlap(child["conv"][i]["b"], parent_params["conv"][i]["b"])
    for name in ("fc1", "out"):
        child[name]["w"] = copy_overlap(child[name]["w"], parent_params[name]["w"])
        child[name]["b"] = copy_overlap(child[name]["b"], parent_params[name]["b"])
    return child


def train_cnn(config: Dict[str, Any], *, n_train: int = 2048, n_test: int = 512,
              batch: int = 128, image_size: int = 16, seed: int = 0) -> float:
    """Paper §IV job: config -> test accuracy.  ~1 s/epoch on this CPU."""
    arch = (
        json.loads(config["arch"])
        if "arch" in config and config["arch"]
        else {
            "conv": [[int(config.get("conv1", 16)), 3], [int(config.get("conv2", 32)), 3]],
            "fc": int(config.get("fc1", 64)),
        }
    )
    lr = float(config.get("learning_rate", 1e-3))
    dropout = float(config.get("dropout", 0.1))
    epochs = max(1, int(config.get("n_iterations", 3)))

    data = SyntheticClassification(image_size=image_size)
    train, test = data.make_split(n_train, seed + 1), data.make_split(n_test, seed + 2)
    key = jax.random.PRNGKey(seed)
    params = init_cnn(key, arch, data.n_classes, image_size)
    if config.get("arch_parent"):
        parent_arch = json.loads(config["arch_parent"])
        params = morph_params(key, init_cnn(key, parent_arch, data.n_classes, image_size),
                              parent_arch, arch, data.n_classes, image_size)

    # plain Adam, as in the paper
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, mu, nu, t, x, y, dkey):
        def loss_fn(p):
            logits = cnn_forward(p, x, dropout, dkey)
            lse = jax.nn.logsumexp(logits, -1)
            return (lse - jnp.take_along_axis(logits, y[:, None], 1)[:, 0]).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        mu = jax.tree.map(lambda m, gr: 0.9 * m + 0.1 * gr, mu, g)
        nu = jax.tree.map(lambda v, gr: 0.999 * v + 0.001 * gr * gr, nu, g)
        mh = jax.tree.map(lambda m: m / (1 - 0.9 ** t), mu)
        vh = jax.tree.map(lambda v: v / (1 - 0.999 ** t), nu)
        params = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + 1e-8), params, mh, vh)
        return params, mu, nu, loss

    n_batches = n_train // batch
    t = 0
    for ep in range(epochs):
        perm = np.random.default_rng(seed + ep).permutation(n_train)
        for i in range(n_batches):
            idx = perm[i * batch : (i + 1) * batch]
            t += 1
            key, dkey = jax.random.split(key)
            params, mu, nu, _ = step(
                params, mu, nu, t, train["x"][idx], train["y"][idx], dkey
            )

    logits = cnn_forward(params, test["x"])
    return float((logits.argmax(-1) == test["y"]).mean())
