"""Cross-entropy loss with optional z-loss and MoE aux weighting."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(
    logits: jax.Array,   # (B, S, V) fp32
    targets: jax.Array,  # (B, S) int32
    mask: jax.Array,     # (B, S) float
    z_loss: float = 0.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    lse = jax.nn.logsumexp(logits, axis=-1)                      # (B, S)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    metrics = {"ce_loss": loss}
    if z_loss > 0:
        zl = z_loss * ((lse * lse) * mask).sum() / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    acc = ((logits.argmax(-1) == targets) * mask).sum() / denom
    metrics["accuracy"] = acc
    return loss, metrics
