"""Vmapped population trial engine: K HPO trials in one device program.

Serial HPO evaluates trials as independent Python jobs — each pays its own
XLA compile and runs one small model at a time, leaving the accelerator
mostly idle.  Because ``make_hparam_train_step`` takes the tunable knobs as a
*traced* ``HParams`` pytree, a whole population of trials of one architecture
can instead ride a leading ``vmap`` axis: one jitted program advances all K
trials per step, amortizing both compilation (exactly one, regardless of how
many trials the experiment runs) and per-step dispatch.

Population state layout::

    {"inner":     vmapped train state (leading axis K),
     "diverged":  bool[K]   — latch; a NaN/inf loss freezes that trial,
     "last_loss": f32[K]    — loss at each trial's last *applied* step}

Semantics per jitted ``pop_step(pstate, batch, hp)``:

* a trial is **active** while ``opt.step < hp.total_steps`` and not diverged —
  ``hp.total_steps`` doubles as the per-trial step budget, so trials with
  different budgets (e.g. Hyperband rungs) coexist in one batch: exhausted
  trials freeze in place while the rest continue;
* a non-finite loss at an active step sets the ``diverged`` latch and the
  update is *not* applied — the sick trial freezes, the batch lives on
  (vmapped divergence masking);
* ``last_loss`` records the loss of the most recent applied update, i.e. each
  trial's own final loss once it halts.

The shared ``batch`` is broadcast to every trial (``in_axes=(0, None, 0)``),
matching the serial driver where every trial consumes the same seeded stream.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig
from ..optim.hparams import HParams
from .train_step import init_train_state, make_hparam_train_step, static_step_key

PopState = Dict[str, Any]


def _per_trial(mask: jax.Array, new, old):
    m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def init_population_state(key, tc: TrainConfig, population: int) -> PopState:
    """Initialize K identical trials from one PRNG key.

    All trials start from the same weights (the serial driver inits every
    trial with the same seed); only their traced hyperparameters differ.
    Use ``init_population_state_from_keys`` for per-trial init seeds.
    """
    one = init_train_state(key, tc)
    inner = jax.tree.map(lambda x: jnp.broadcast_to(x, (population,) + x.shape), one)
    return _wrap(inner, population)


def init_population_state_from_keys(keys, tc: TrainConfig) -> PopState:
    """Initialize one trial per PRNG key (keys shape ``(K, 2)``)."""
    inner = jax.vmap(lambda k: init_train_state(k, tc))(keys)
    return _wrap(inner, int(keys.shape[0]))


def _wrap(inner, k: int) -> PopState:
    return {
        "inner": inner,
        "diverged": jnp.zeros((k,), bool),
        "last_loss": jnp.full((k,), jnp.inf, jnp.float32),
    }


def make_population_train_step(tc: TrainConfig) -> Callable:
    """``(pstate, batch, hp) -> (pstate, metrics)`` over a leading K axis.

    ``hp`` is a stacked ``HParams`` (every leaf shape ``(K,)``); metrics come
    back per-trial (leading K) plus an ``active`` mask.
    """
    step = make_hparam_train_step(tc)
    vstep = jax.vmap(step, in_axes=(0, None, 0))

    def pop_step(pstate: PopState, batch, hp: HParams):
        inner = pstate["inner"]
        in_budget = inner["opt"]["step"].astype(jnp.float32) < hp.total_steps
        active = in_budget & ~pstate["diverged"]
        new_inner, metrics = vstep(inner, batch, hp)
        finite = jnp.isfinite(metrics["loss"])
        applied = active & finite
        merged = jax.tree.map(lambda n, o: _per_trial(applied, n, o), new_inner, inner)
        return {
            "inner": merged,
            "diverged": pstate["diverged"] | (active & ~finite),
            "last_loss": jnp.where(applied, metrics["loss"], pstate["last_loss"]),
        }, dict(metrics, active=active)

    return pop_step


# -- compile-once cache (one entry per (static config, population size)) --------

_POP_CACHE: Dict[Tuple, Any] = {}
_POP_CACHE_LOCK = threading.Lock()


def get_compiled_population_step(tc: TrainConfig, population: int):
    """Memoized ``jax.jit`` of the population step with donated state."""
    key = (static_step_key(tc), int(population))
    with _POP_CACHE_LOCK:
        fn = _POP_CACHE.get(key)
        if fn is None:
            fn = jax.jit(make_population_train_step(tc), donate_argnums=0)
            _POP_CACHE[key] = fn
    return fn


def clear_population_cache() -> None:
    with _POP_CACHE_LOCK:
        _POP_CACHE.clear()


def population_scores(pstate: PopState, diverged_score: float = -1e9):
    """HPO convention: score = -final_loss, with a sentinel for diverged trials.

    Trials that never applied a step (budget 0) also get the sentinel.
    """
    last = pstate["last_loss"]
    ok = ~pstate["diverged"] & jnp.isfinite(last)
    return jnp.where(ok, -last, jnp.float32(diverged_score))
