"""Population trial engines: K HPO trials in one (possibly sharded) program.

Serial HPO evaluates trials as independent Python jobs — each pays its own
XLA compile and runs one small model at a time, leaving the accelerator
mostly idle.  Because ``make_hparam_train_step`` takes the tunable knobs as a
*traced* ``HParams`` pytree, a whole population of trials of one architecture
can instead ride a leading ``vmap`` axis: one jitted program advances all K
trials per step, amortizing both compilation (exactly one, regardless of how
many trials the experiment runs) and per-step dispatch.

Two engines share the same population-step semantics:

* **vmapped** (``get_compiled_population_step``) — all K trials on one device;
* **sharded** (``get_compiled_sharded_population_step``) — the population axis
  is split over an N-device mesh with ``shard_map`` (K % N == 0; callers pad
  with 0-budget trials), so each device runs a K/N-wide vmapped step and the
  whole population is still ONE compiled program.  There is no cross-trial
  communication, so sharding the K axis is embarrassingly parallel — the mesh
  only changes *where* each lane's compute lands.

Population state layout::

    {"inner":     vmapped train state (leading axis K),
     "diverged":  bool[K]   — latch; a NaN/inf loss freezes that trial,
     "last_loss": f32[K]    — loss at each trial's last *applied* step}

Semantics per jitted ``pop_step(pstate, batch, hp)``:

* a trial is **active** while ``opt.step < hp.total_steps`` and not diverged —
  ``hp.total_steps`` doubles as the per-trial step budget, so trials with
  different budgets (e.g. Hyperband rungs) coexist in one batch: exhausted
  trials freeze in place while the rest continue.  Because ``total_steps`` is
  a *traced* leaf, the driver may also shrink it **mid-flight** (in-flight
  early stopping — see ``repro.core.proposer.early_stop``) without recompiling;
* a retired lane can be **refilled** in place by a lane-lifecycle op (all
  compiled, cached, with ``shard_map`` twins): ``make_lane_init`` re-inits a
  masked subset of lanes from per-lane PRNG keys, ``make_lane_splice`` updates
  exactly ONE lane via ``dynamic_update_index_in_dim`` per leaf (one init, not
  K), and ``make_lane_clone`` copies a *donor* lane's weights + optimizer
  state across the population axis (PBT exploit without a host checkpoint).
  Either way the host loop swaps the next proposal into a freed lane while the
  rest of the population keeps training — still the same compiled step program;
* a non-finite loss at an active step sets the ``diverged`` latch and the
  update is *not* applied — the sick trial freezes, the batch lives on
  (vmapped divergence masking);
* ``last_loss`` records the loss of the most recent applied update, i.e. each
  trial's own final loss once it halts.

Batch layout: with ``per_trial_batch=False`` the ``batch`` is broadcast to
every trial (``in_axes=(0, None, 0)``) — the legacy shared-stream mode.  With
``per_trial_batch=True`` every batch leaf carries a leading K axis and trial
``i`` consumes its own independently seeded stream
(``SyntheticLM.make_population_batch``), matching the serial driver when it
folds the same per-trial stream id into its PRNG.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec

from ..configs.base import TrainConfig
from ..distributed.sharding import (
    population_mesh,
    population_specs,
    tp_gnorm_mask,
    tp_module_flags,
    tp_shard_context,
    tp_width_rules,
    two_level_pspecs,
    two_level_state_specs,
)
from ..optim.hparams import HParams
from .train_step import (
    init_train_state,
    make_hparam_train_step,
    static_step_key,
    train_state_specs,
)

PopState = Dict[str, Any]


def _per_trial(mask: jax.Array, new, old):
    m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def init_population_state(key, tc: TrainConfig, population: int) -> PopState:
    """Initialize K identical trials from one PRNG key.

    All trials start from the same weights (the serial driver inits every
    trial with the same seed); only their traced hyperparameters differ.
    Use ``init_population_state_from_keys`` for per-trial init seeds.
    """
    one = init_train_state(key, tc)
    inner = jax.tree.map(lambda x: jnp.broadcast_to(x, (population,) + x.shape), one)
    return _wrap(inner, population)


def init_population_state_from_keys(keys, tc: TrainConfig) -> PopState:
    """Initialize one trial per PRNG key (keys shape ``(K, 2)``)."""
    inner = jax.vmap(lambda k: init_train_state(k, tc))(keys)
    return _wrap(inner, int(keys.shape[0]))


def _wrap(inner, k: int) -> PopState:
    return {
        "inner": inner,
        "diverged": jnp.zeros((k,), bool),
        "last_loss": jnp.full((k,), jnp.inf, jnp.float32),
    }


# -- two-level (pop, model) mesh helpers ----------------------------------------
#
# On a two-level mesh the population axis holds ``rows`` lane rows and each
# row is ``width = mesh.size / rows`` devices of genuine tensor parallelism:
# the sharded engines shard_map over BOTH axes, partitioning every lane's
# attention heads / MLP ff / mamba channels over its own row per
# ``tp_width_rules`` with the psum seams in the model code (tp_enter /
# tp_reduce).  Width is layout, never math — a width-W program computes the
# same losses as width-1 up to fp reassociation of the seam reductions.


def _pop_rows(mesh: Mesh, axis: str = "pop") -> int:
    """Lane-row count of ``mesh`` (== device count on a 1-D population mesh)."""
    return int(dict(mesh.shape).get(axis, mesh.size))


def _mesh_width(mesh: Mesh, axis: str = "pop") -> int:
    """Model-parallel width per lane row (1 on a 1-D population mesh)."""
    return mesh.size // _pop_rows(mesh, axis)


def _mesh_cache_key(mesh: Mesh, axis: str) -> Tuple:
    # the mesh SHAPE is part of the key: the same 8 devices arranged (8,)
    # and (4, 2) compile different programs
    return (tuple(d.id for d in mesh.devices.flat), axis,
            tuple((n, int(s)) for n, s in mesh.shape.items()))


def _check_rows(population: int, mesh: Mesh, axis: str = "pop") -> None:
    rows = _pop_rows(mesh, axis)
    if population % rows:
        raise ValueError(
            f"population {population} does not divide over {rows} lane rows; "
            f"pad to {pad_population(population, mesh, axis=axis)} with "
            f"0-budget trials"
        )


def _population_state_shapes(tc: TrainConfig, population: int) -> PopState:
    return jax.eval_shape(
        lambda: init_population_state(jax.random.PRNGKey(0), tc, population))


def _state_logical_specs(tc: TrainConfig) -> Dict[str, Any]:
    return {"inner": train_state_specs(tc), "diverged": (), "last_loss": ()}


def _tp_rules_or_raise(tc: TrainConfig, width: int,
                       model_axis: str = "model"):
    rules = tp_width_rules(tc.model, width, model_axis)
    if not rules:
        raise ValueError(
            f"model-parallel width {width} shards nothing of "
            f"{tc.model.name} (heads={tc.model.n_heads}, "
            f"kv={tc.model.n_kv_heads}, ff={tc.model.d_ff}, "
            f"moe={tc.model.has_moe}) — pure replication contradicts "
            f"--model-parallel; pick a width dividing the module dims"
        )
    return rules


def _tp_state_pspecs(tc: TrainConfig, mesh: Mesh, axis: str,
                     model_axis: str = "model"):
    """(per-leaf PartitionSpec tree for the population state, width rules).

    The pspecs do not depend on the lane count (only trailing dims are
    inspected), so a placeholder K = rows is used for the shape walk."""
    width = _mesh_width(mesh, axis)
    rules = _tp_rules_or_raise(tc, width, model_axis)
    shapes = _population_state_shapes(tc, _pop_rows(mesh, axis))
    return two_level_pspecs(
        shapes, _state_logical_specs(tc), mesh, axis=axis, rules=rules), rules


def _fused_kernels_on(tc: TrainConfig) -> bool:
    """shard_map's static replication checker has no rule for pallas_call, so
    the width-1 sharded twins must drop to ``check_rep=False`` whenever a
    fused Pallas kernel rides inside the train step (the width>1 twins always
    do: the checker cannot see through the custom_vjp psum seams either)."""
    m = tc.model
    return bool(m.fused_rmsnorm or m.fused_attention or m.fused_ssm)


def _tp_body(fn: Callable, tc: TrainConfig, width: int,
             model_axis: str = "model") -> Callable:
    """Wrap a shard_map-local population fn so the TP seams are armed while
    it traces: module flags pick which seams fire, and the gnorm mask tells
    ``optim.adamw.global_norm`` which grad leaves are width-local shards."""
    flags = tp_module_flags(tc.model, width)
    rules = tp_width_rules(tc.model, width, model_axis)
    mask = tp_gnorm_mask(train_state_specs(tc)["params"], rules)

    def wrapped(*args):
        with tp_shard_context(model_axis, flags, gnorm_mask=mask):
            return fn(*args)

    return wrapped


def make_population_train_step(tc: TrainConfig, per_trial_batch: bool = False) -> Callable:
    """``(pstate, batch, hp) -> (pstate, metrics)`` over a leading K axis.

    ``hp`` is a stacked ``HParams`` (every leaf shape ``(K,)``); metrics come
    back per-trial (leading K) plus an ``active`` mask.  ``per_trial_batch``
    selects whether ``batch`` leaves carry a leading K axis (independent
    per-trial data streams) or are broadcast to every trial.
    """
    step = make_hparam_train_step(tc)
    vstep = jax.vmap(step, in_axes=(0, 0 if per_trial_batch else None, 0))

    def pop_step(pstate: PopState, batch, hp: HParams):
        inner = pstate["inner"]
        in_budget = inner["opt"]["step"].astype(jnp.float32) < hp.total_steps
        active = in_budget & ~pstate["diverged"]
        new_inner, metrics = vstep(inner, batch, hp)
        finite = jnp.isfinite(metrics["loss"])
        applied = active & finite
        merged = jax.tree.map(lambda n, o: _per_trial(applied, n, o), new_inner, inner)
        return {
            "inner": merged,
            "diverged": pstate["diverged"] | (active & ~finite),
            "last_loss": jnp.where(applied, metrics["loss"], pstate["last_loss"]),
        }, dict(metrics, active=active)

    return pop_step


# -- lane-lifecycle ops ---------------------------------------------------------
#
# A population lane cycles through its lifecycle inside ONE compiled flight:
# lease -> train -> retire -> refill.  The refill is a device op picked from
# this unified layer (each has a ``shard_map`` twin and a compile-once cache
# entry via ``get_compiled_lane_op``):
#
# * ``init``   (``make_lane_init``)   — re-init a masked subset of lanes from
#   per-lane PRNG keys (vmapped ``init_train_state``): the PR-3 reset, used
#   when several lanes refill at once;
# * ``clone``  (``make_lane_clone``)  — copy a *donor* lane's params AND
#   optimizer state across the population axis into the masked lanes: the
#   PBT/EAS exploit primitive (weight inheritance without a host checkpoint
#   round-trip).  Hyperparameters are not touched — they ride in the traced
#   ``HParams`` stack the host re-stacks per lease;
# * ``splice`` (``make_lane_splice``) — update ONE target lane via
#   ``dynamic_update_index_in_dim`` per leaf: a single ``init_train_state``
#   instead of vmap-initializing all K lanes and where-selecting, so splicing
#   one lane of a big model costs one lane's init, not K.


def make_lane_init(tc: TrainConfig) -> Callable:
    """``(pstate, mask, keys) -> pstate`` with masked lanes re-initialized.

    The in-place lane *refill* primitive: when the host loop retires a lane
    (budget exhausted, rung-truncated, or diverged) it can splice the next
    proposal into that lane **without leaving the compiled program** — the
    reset re-inits the lane's inner train state (params, optimizer moments,
    step counter) from its own PRNG key via a vmapped ``init_train_state``,
    clears the divergence latch, and restores the ``last_loss`` sentinel.
    ``mask`` is ``bool[K]`` (True = reset this lane); ``keys`` is ``(K, 2)``
    per-lane init keys, so a refilled lane starts from exactly the weights a
    fresh serial trial with the same key would — unmasked lanes keep training
    state untouched.
    """

    def reset(pstate: PopState, mask: jax.Array, keys: jax.Array) -> PopState:
        fresh = jax.vmap(lambda k: init_train_state(k, tc))(keys)
        inner = jax.tree.map(
            lambda f, o: _per_trial(mask, f, o), fresh, pstate["inner"]
        )
        return {
            "inner": inner,
            "diverged": jnp.where(mask, False, pstate["diverged"]),
            "last_loss": jnp.where(mask, jnp.float32(jnp.inf), pstate["last_loss"]),
        }

    return reset


# PR-3 name: the masked from-keys reset predates the unified lifecycle layer.
make_reset_lanes = make_lane_init


def make_lane_clone(tc: TrainConfig) -> Callable:
    """``(pstate, mask, donor_idx) -> pstate`` cloning donor lanes in place.

    For every masked lane ``i``, the whole inner train state (params, AdamW
    moments, master copy, step counter) becomes a copy of lane
    ``donor_idx[i]``, the divergence latch and ``last_loss`` are copied from
    the donor too, and unmasked lanes are untouched.  ``donor_idx`` is
    ``int32[K]`` (unmasked entries are ignored; pass the identity to be safe).
    This is the exploit half of Population-Based Training as a *device* op:
    a losing member inherits the winner's weights and optimizer state without
    the weights ever visiting the host.
    """

    def clone(pstate: PopState, mask: jax.Array, donor_idx: jax.Array) -> PopState:
        take = lambda x: jnp.take(x, donor_idx, axis=0)
        donated = jax.tree.map(take, pstate["inner"])
        inner = jax.tree.map(
            lambda d, o: _per_trial(mask, d, o), donated, pstate["inner"]
        )
        return {
            "inner": inner,
            "diverged": jnp.where(mask, take(pstate["diverged"]), pstate["diverged"]),
            "last_loss": jnp.where(mask, take(pstate["last_loss"]), pstate["last_loss"]),
        }

    return clone


def make_lane_splice(tc: TrainConfig) -> Callable:
    """``(pstate, lane, key) -> pstate`` re-initializing exactly one lane.

    Unlike ``make_lane_init`` — which vmap-inits all K lanes and
    where-selects the masked ones — the splice runs ONE ``init_train_state``
    and writes it into the target lane with ``dynamic_update_index_in_dim``
    per leaf.  ``lane`` is a *traced* int32 scalar, so one compiled program
    serves every lane; on a big model this is the difference between paying K
    inits and paying one.
    """

    def splice(pstate: PopState, lane: jax.Array, key: jax.Array) -> PopState:
        fresh = init_train_state(key, tc)
        inner = jax.tree.map(
            lambda o, f: jax.lax.dynamic_update_index_in_dim(
                o, f.astype(o.dtype), lane, 0
            ),
            pstate["inner"], fresh,
        )
        return {
            "inner": inner,
            "diverged": jax.lax.dynamic_update_index_in_dim(
                pstate["diverged"], jnp.asarray(False), lane, 0
            ),
            "last_loss": jax.lax.dynamic_update_index_in_dim(
                pstate["last_loss"], jnp.float32(jnp.inf), lane, 0
            ),
        }

    return splice


def make_lane_snapshot(tc: TrainConfig) -> Callable:
    """``(pstate, lane) -> lane_state`` harvesting ONE lane's full train state.

    The inverse of ``make_lane_splice``: instead of writing a fresh init into
    a lane, it reads the lane's complete state — params, optimizer moments,
    master copy, step counter, divergence latch and ``last_loss`` — as an
    unbatched pytree via ``dynamic_index_in_dim`` per leaf.  ``lane`` is a
    *traced* int32 scalar, so one compiled program snapshots any lane.  The
    caller ``device_get``s the result to host; together with the lane's
    stream word and host cursors this is everything needed to resurrect the
    trial in a fresh flight (``make_lane_restore``) — crash-safe streaming.

    Unlike the mutating lifecycle ops this one must NOT donate its input:
    the flight keeps training on ``pstate`` after the harvest.
    """

    def snapshot(pstate: PopState, lane: jax.Array):
        take = lambda x: jax.lax.dynamic_index_in_dim(x, lane, 0, keepdims=False)
        return {
            "inner": jax.tree.map(take, pstate["inner"]),
            "diverged": take(pstate["diverged"]),
            "last_loss": take(pstate["last_loss"]),
        }

    return snapshot


def make_lane_restore(tc: TrainConfig) -> Callable:
    """``(pstate, lane, snap) -> pstate`` splicing a harvested snapshot back.

    The write half of the snapshot/restore pair: like ``make_lane_splice``
    but the spliced state comes from a previously harvested lane snapshot
    (``make_lane_snapshot``) instead of a fresh ``init_train_state`` — one
    ``dynamic_update_index_in_dim`` per leaf, including the divergence latch,
    ``last_loss`` and the optimizer step counter, so the restored lane is
    bit-identical to the lane that was harvested.  ``lane`` is traced: a
    snapshot taken from lane i of a dead flight can land in any lane j of
    the new one.
    """

    def restore(pstate: PopState, lane: jax.Array, snap) -> PopState:
        put = lambda o, f: jax.lax.dynamic_update_index_in_dim(
            o, f.astype(o.dtype), lane, 0)
        return {
            "inner": jax.tree.map(put, pstate["inner"], snap["inner"]),
            "diverged": put(pstate["diverged"], snap["diverged"]),
            "last_loss": put(pstate["last_loss"], snap["last_loss"]),
        }

    return restore


def make_lane_regrid(tc: TrainConfig) -> Callable:
    """``(pstate, survivors) -> pstate'`` — the sixth lane-lifecycle op.

    At a rung boundary the cut lanes are dead weight: the flight keeps
    stepping K lanes while only the survivors still train.  The regrid
    gathers the survivors' FULL train state (params, optimizer moments,
    master copy, step counter, divergence latch, ``last_loss``) into a
    compact K' = len(survivors) population — ``jnp.take`` on the lane axis
    per leaf, the whole-population generalization of the single-lane
    snapshot/restore pair.  ``survivors`` is int32[K'] of surviving lane
    indices in ascending order (order preservation is what keeps the
    staggered rule's lane-order appends identical across regrids); callers
    pad it by repeating a survivor whose padded copy gets a 0-step budget.

    Resharding changes layout, never math: the compact state is the same
    bits the survivors held at K lanes, and ``regrid_population_state``
    then ``device_put``s it onto a new (fewer-lanes x wider) two-level mesh
    so later rungs train fewer trials wider instead of idling freed devices.
    Like ``snapshot`` this op must NOT donate: K' differs from K, so the
    input buffers are never reusable, and the driver drops the old state.
    """

    def regrid(pstate: PopState, survivors: jax.Array) -> PopState:
        take = lambda x: jnp.take(x, survivors, axis=0)
        return jax.tree.map(take, pstate)

    return regrid


def make_sharded_lane_regrid(tc: TrainConfig, mesh: Mesh, axis: str = "pop") -> Callable:
    """Mesh twin of the regrid gather.  The output lane count K' differs
    from K and survivors cross lane blocks, so there is no ``shard_map``
    formulation — the jitted gather runs under GSPMD (which lowers the
    cross-device ``take``), and the caller re-lays the compact state out on
    the *new* mesh with ``device_put`` (``regrid_population_state``)."""
    return make_lane_regrid(tc)


def plan_regrid(n_devices: int, n_survivors: int) -> Tuple[int, int, int]:
    """``(rows, width, lanes)`` geometry for S survivors over N devices.

    ``rows`` is the largest divisor of N such that laying the survivors out
    contiguously (``ceil(S / rows)`` lanes per row, padding at the tail)
    leaves **no device row idle** — the full-occupancy invariant the elastic
    engine maintains after every cut.  ``width = N / rows`` devices then
    serve each lane row, and ``lanes = rows * ceil(S / rows)`` is the padded
    population size (padding lanes carry a 0-step budget)."""
    n = max(1, int(n_devices))
    s = max(1, int(n_survivors))
    for rows in sorted((d for d in range(1, n + 1) if n % d == 0),
                       reverse=True):
        per = -(-s // rows)
        if rows <= s and (rows - 1) * per < s:
            return rows, n // rows, rows * per
    return 1, n, s  # unreachable: rows=1 always satisfies the invariant


def place_two_level(pstate: PopState, tc: TrainConfig, mesh: Mesh,
                    axis: str = "pop") -> PopState:
    """``device_put`` a population state onto a two-level ``(pop, model)``
    mesh: the lane axis spreads over ``axis`` and each lane's parameter /
    optimizer leaves shard over its own device row through the per-leaf
    composed specs (``two_level_state_specs`` x ``train_state_specs``).

    The width rules are the *module-coherent* ``tp_width_rules`` — the same
    partitioning the tensor-parallel step computes on — so a regrid onto a
    wider mesh genuinely re-partitions survivor state (optimizer memory per
    device drops ~1/W) instead of replicating it."""
    width = _mesh_width(mesh, axis)
    rules = tp_width_rules(tc.model, width) if width > 1 else None
    return jax.device_put(
        pstate, two_level_state_specs(
            pstate, _state_logical_specs(tc), mesh, axis=axis, rules=rules))


def regrid_population_state(
    pstate: PopState,
    survivors,
    tc: TrainConfig,
    mesh: Optional[Mesh] = None,
    axis: str = "pop",
    pad_to: Optional[int] = None,
) -> PopState:
    """Gather ``survivors`` into a compact K' population and (optionally)
    re-lay it out on a new two-level mesh.

    The gather is the compiled ``regrid`` lane op (cached like every other
    lifecycle op); ``pad_to`` pads the survivor list to a fixed K' by
    repeating the first survivor (padding copies get 0-step budgets from the
    caller's hparam restack, so they freeze immediately and their scores are
    never read).  With ``mesh`` the compact state is ``device_put`` onto the
    new lane-row layout — resharding changes layout, never math."""
    k = int(pstate["diverged"].shape[0])
    idx = [int(i) for i in survivors]
    k2 = max(int(pad_to) if pad_to else len(idx), 1)
    idx = (idx + [idx[0] if idx else 0] * k2)[:k2]
    fn = get_compiled_lane_op(tc, k, "regrid")
    compact = fn(pstate, jnp.asarray(idx, jnp.int32))
    if mesh is not None:
        compact = place_two_level(compact, tc, mesh, axis=axis)
    return compact


def make_sharded_lane_init(tc: TrainConfig, mesh: Mesh, axis: str = "pop") -> Callable:
    """Lane reset with the K axis split over ``mesh`` (mirrors the sharded
    population step): each device re-inits only its own K/N block of lanes."""
    from jax.experimental.shard_map import shard_map

    reset = make_lane_init(tc)
    pop = PartitionSpec(axis)
    return shard_map(reset, mesh=mesh, in_specs=(pop, pop, pop), out_specs=pop)


make_sharded_reset_lanes = make_sharded_lane_init


def make_sharded_lane_clone(tc: TrainConfig, mesh: Mesh, axis: str = "pop") -> Callable:
    """Donor clone with the K axis split over ``mesh``.

    ``donor_idx`` holds *global* lane ids, so a clone may cross a mesh
    boundary.  Instead of ``all_gather``-ing the population axis (which
    materializes the full K-lane state on every device — O(K) peak memory for
    a copy that only ever needs one lane), the donor states travel
    **point-to-point around a ring of ``ppermute``s**: round ``r`` rotates
    each device's K/N lane block one hop, and a device whose donor lives
    ``r`` hops upstream selects its donor's lane out of the passing block.
    Peak extra memory is ONE block (K/N lanes) regardless of mesh size, total
    wire traffic is the same N-1 blocks the gather moved, and the copied
    values are bit-identical to the vmapped clone's.
    """
    from jax.experimental.shard_map import shard_map

    n = int(mesh.shape[axis])

    def clone(pstate: PopState, mask: jax.Array, donor_idx: jax.Array) -> PopState:
        blk = pstate["diverged"].shape[0]  # local lanes per device
        me = jax.lax.axis_index(axis)
        owner = donor_idx // blk           # device holding each lane's donor
        local = donor_idx % blk            # donor's index inside that block
        take = lambda t: jax.tree.map(lambda x: jnp.take(x, local, axis=0), t)
        perm = [(i, (i + 1) % n) for i in range(n)]

        buf = pstate                       # after r hops: block of device me-r
        donated = take(buf)                # r = 0: donors on this device
        for r in range(1, n):
            buf = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, perm), buf)
            src = (me - r) % n
            cand = take(buf)
            donated = jax.tree.map(
                lambda d, c: _per_trial(owner == src, c, d), donated, cand)

        inner = jax.tree.map(
            lambda d, o: _per_trial(mask, d, o), donated["inner"], pstate["inner"]
        )
        return {
            "inner": inner,
            "diverged": jnp.where(mask, donated["diverged"], pstate["diverged"]),
            "last_loss": jnp.where(mask, donated["last_loss"], pstate["last_loss"]),
        }

    pop = PartitionSpec(axis)
    return shard_map(clone, mesh=mesh, in_specs=(pop, pop, pop), out_specs=pop)


def make_sharded_lane_splice(tc: TrainConfig, mesh: Mesh, axis: str = "pop") -> Callable:
    """Single-lane splice with the K axis split over ``mesh``.

    ``lane`` is a global id; every device runs the (cheap, replicated) fresh
    init but only the owner of the target lane writes it into its local
    block — the rest keep their block bit-identical.
    """
    from jax.experimental.shard_map import shard_map

    def splice(pstate: PopState, lane: jax.Array, key: jax.Array) -> PopState:
        blk = pstate["diverged"].shape[0]  # local lanes per device
        off = jax.lax.axis_index(axis) * blk
        local = jnp.clip(lane - off, 0, blk - 1)
        owns = (lane >= off) & (lane < off + blk)
        fresh = init_train_state(key, tc)

        def upd(o, f):
            new = jax.lax.dynamic_update_index_in_dim(o, f.astype(o.dtype), local, 0)
            return jnp.where(owns, new, o)

        inner = jax.tree.map(upd, pstate["inner"], fresh)
        div = jax.lax.dynamic_update_index_in_dim(
            pstate["diverged"], jnp.asarray(False), local, 0
        )
        last = jax.lax.dynamic_update_index_in_dim(
            pstate["last_loss"], jnp.float32(jnp.inf), local, 0
        )
        return {
            "inner": inner,
            "diverged": jnp.where(owns, div, pstate["diverged"]),
            "last_loss": jnp.where(owns, last, pstate["last_loss"]),
        }

    pop = PartitionSpec(axis)
    return shard_map(
        splice, mesh=mesh,
        in_specs=(pop, PartitionSpec(), PartitionSpec()),
        out_specs=pop,
    )


def make_sharded_lane_snapshot(tc: TrainConfig, mesh: Mesh, axis: str = "pop") -> Callable:
    """Single-lane snapshot with the K axis split over ``mesh``.

    ``lane`` is a global id.  The owning device indexes the lane out of its
    local block; every other device contributes zeros, and a ``psum`` over
    the population axis replicates the harvested lane state to all devices
    (the output carries no lane axis, so it cannot be partitioned on one) —
    peak extra memory is one lane, never a gather of the population.  Bool
    leaves ride the sum as int32 (a masked sum of one contribution, so the
    round-trip is exact).
    """
    from jax.experimental.shard_map import shard_map

    def snapshot(pstate: PopState, lane: jax.Array):
        blk = pstate["diverged"].shape[0]  # local lanes per device
        off = jax.lax.axis_index(axis) * blk
        local = jnp.clip(lane - off, 0, blk - 1)
        owns = (lane >= off) & (lane < off + blk)

        def harvest(x):
            v = jax.lax.dynamic_index_in_dim(x, local, 0, keepdims=False)
            summed = jax.lax.psum(
                jnp.where(owns, v.astype(jnp.int32), 0) if v.dtype == jnp.bool_
                else jnp.where(owns, v, jnp.zeros_like(v)),
                axis,
            )
            return summed.astype(bool) if v.dtype == jnp.bool_ else summed

        return {
            "inner": jax.tree.map(harvest, pstate["inner"]),
            "diverged": harvest(pstate["diverged"]),
            "last_loss": harvest(pstate["last_loss"]),
        }

    pop = PartitionSpec(axis)
    return shard_map(
        snapshot, mesh=mesh,
        in_specs=(pop, PartitionSpec()),
        out_specs=PartitionSpec(),  # replicated: the one harvested lane
    )


def make_sharded_lane_restore(tc: TrainConfig, mesh: Mesh, axis: str = "pop") -> Callable:
    """Snapshot restore with the K axis split over ``mesh``.

    ``lane`` is a global id and ``snap`` is replicated; only the owner of the
    target lane writes the snapshot into its local block (mirrors the sharded
    splice), so the other devices' blocks stay bit-identical.
    """
    from jax.experimental.shard_map import shard_map

    def restore(pstate: PopState, lane: jax.Array, snap) -> PopState:
        blk = pstate["diverged"].shape[0]
        off = jax.lax.axis_index(axis) * blk
        local = jnp.clip(lane - off, 0, blk - 1)
        owns = (lane >= off) & (lane < off + blk)

        def put(o, f):
            new = jax.lax.dynamic_update_index_in_dim(o, f.astype(o.dtype), local, 0)
            return jnp.where(owns, new, o)

        return {
            "inner": jax.tree.map(put, pstate["inner"], snap["inner"]),
            "diverged": put(pstate["diverged"], snap["diverged"]),
            "last_loss": put(pstate["last_loss"], snap["last_loss"]),
        }

    pop = PartitionSpec(axis)
    return shard_map(
        restore, mesh=mesh,
        in_specs=(pop, PartitionSpec(), PartitionSpec()),
        out_specs=pop,
    )


# -- fused multi-step scan (chunked execution) ----------------------------------
#
# The per-step drivers pay one host dispatch AND one host-built batch per
# training step.  ``make_population_scan_step`` fuses T steps into ONE device
# program: a ``jax.lax.scan`` over the population step whose batches are
# synthesized *inside* the scan from per-lane stream words and a traced step
# counter (``repro.data.pipeline.synth_population_batch`` — bit-identical to
# the host's ``make_batch`` by construction, so the fused engine reproduces
# the per-step loop exactly).  The host only re-enters at *event* steps
# (rung boundaries, retirements, PBT rounds, the divergence poll), so chunk
# boundaries are aligned to events by the drivers and T host dispatches
# collapse to one per chunk.


def make_population_scan_step(
    tc: TrainConfig, data, chunk: int, per_trial_batch: bool = True
) -> Callable:
    """``(pstate, hp, steps0, stream_lo, stream_hi) -> (pstate, metrics)``
    advancing every lane ``chunk`` steps in one program.

    ``data`` is the ``SyntheticLM`` stream spec (baked in — the compiled
    program *is* the data pipeline for these lanes); ``steps0`` is each
    lane's data cursor at the chunk start (int32[K], or a scalar in
    shared-stream mode) and ``stream_lo``/``stream_hi`` are the per-lane
    stream words from ``split_streams`` (uint32[K], scalars in shared-stream
    mode).  Step ``t`` of the chunk consumes exactly the batch the host loop
    would build at cursor ``steps0 + t``; budget/divergence masking is the
    ordinary population-step semantics, so a lane whose budget ends (or that
    diverges) mid-chunk freezes in place and the chunk remains safe to run
    past it.  ``metrics`` come back stacked with a leading ``(chunk,)`` axis.
    """
    from ..data.pipeline import synth_population_batch, synth_tokens, tokens_to_batch

    step = make_population_train_step(tc, per_trial_batch=per_trial_batch)

    def scan_chunk(pstate: PopState, hp: HParams, steps0, stream_lo, stream_hi):
        def body(carry, t):
            if per_trial_batch:
                batch = synth_population_batch(
                    data, stream_lo, stream_hi, steps0 + t, xp=jnp)
            else:
                toks = synth_tokens(
                    jnp, data, (data.global_batch,), steps0 + t,
                    stream_lo, stream_hi)
                batch = tokens_to_batch(jnp, data, toks)
            new, metrics = step(carry, batch, hp)
            return new, metrics

        return jax.lax.scan(
            body, pstate, jnp.arange(int(chunk), dtype=jnp.int32))

    return scan_chunk


def make_sharded_population_scan_step(
    tc: TrainConfig,
    mesh: Mesh,
    data,
    chunk: int,
    per_trial_batch: bool = True,
    axis: str = "pop",
) -> Callable:
    """``shard_map`` twin of the fused scan: each device runs the T-step scan
    over its own K/N lane block, synthesizing only its own lanes' batches on
    device.  Stacked metrics come back partitioned on their lane axis
    (leading axis is the chunk).

    On a two-level mesh each lane row's scan is width-W tensor parallel (see
    ``make_sharded_population_step``); the in-scan batch synthesis replicates
    across the row (same lanes, same streams), which is exactly the TP batch
    contract."""
    from jax.experimental.shard_map import shard_map

    fn = make_population_scan_step(
        tc, data, chunk, per_trial_batch=per_trial_batch)
    pop = PartitionSpec(axis)
    rep = PartitionSpec()
    lane = pop if per_trial_batch else rep
    width = _mesh_width(mesh, axis)
    if width > 1:
        state_ps, _ = _tp_state_pspecs(tc, mesh, axis)
        return shard_map(
            _tp_body(fn, tc, width),
            mesh=mesh,
            in_specs=(state_ps, pop, lane, lane, lane),
            out_specs=(state_ps, PartitionSpec(None, axis)),
            check_rep=False,
        )
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(pop, pop, lane, lane, lane),
        out_specs=(pop, PartitionSpec(None, axis)),
        check_rep=not _fused_kernels_on(tc),
    )


def make_population_ring_scan_step(
    tc: TrainConfig, data, chunk: int, capacity: int
) -> Callable:
    """``(pstate, hp, ring, slot0) -> (pstate, metrics)``: the fused scan fed
    from a device-resident prefetch ring instead of in-scan synthesis.

    ``ring`` is the ``repro.data.ring.PrefetchRing`` device array —
    ``(capacity, K, batch, seq_len+1)`` int32 token slabs, one slab per
    global step, host-filled ahead of the scan.  Step ``t`` of the chunk
    reads slot ``(slot0 + t) % capacity`` with ``lax.dynamic_index_in_dim``
    (``slot0`` is the dispatch step's slot, traced so one program serves
    every ring phase) and splits it into the batch dict on device; the train
    step itself — budget/divergence masking included — is identical to the
    in-scan-synth path, so a ring filled by the host synth adapter reproduces
    that engine bit-for-bit.  The ring argument is read-only: only the
    population state donates.
    """
    from ..data.pipeline import tokens_to_batch

    step = make_population_train_step(tc, per_trial_batch=True)
    cap = int(capacity)

    def scan_chunk(pstate: PopState, hp: HParams, ring, slot0):
        def body(carry, t):
            slab = jax.lax.dynamic_index_in_dim(
                ring, (slot0 + t) % cap, 0, keepdims=False)
            batch = tokens_to_batch(jnp, data, slab)
            new, metrics = step(carry, batch, hp)
            return new, metrics

        return jax.lax.scan(
            body, pstate, jnp.arange(int(chunk), dtype=jnp.int32))

    return scan_chunk


def make_sharded_population_ring_scan_step(
    tc: TrainConfig,
    mesh: Mesh,
    data,
    chunk: int,
    capacity: int,
    axis: str = "pop",
) -> Callable:
    """``shard_map`` twin of the ring scan: the ring's lane axis is placed on
    the ``pop`` mesh axis, so each device scans over its own K/N lane block
    reading only its own lanes' slabs (the host fill ``device_put``s slabs
    with the same sharding — no gather)."""
    from jax.experimental.shard_map import shard_map

    fn = make_population_ring_scan_step(tc, data, chunk, capacity)
    pop = PartitionSpec(axis)
    width = _mesh_width(mesh, axis)
    if width > 1:
        state_ps, _ = _tp_state_pspecs(tc, mesh, axis)
        return shard_map(
            _tp_body(fn, tc, width),
            mesh=mesh,
            in_specs=(state_ps, pop, PartitionSpec(None, axis), PartitionSpec()),
            out_specs=(state_ps, PartitionSpec(None, axis)),
            check_rep=False,
        )
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(pop, pop, PartitionSpec(None, axis), PartitionSpec()),
        out_specs=(pop, PartitionSpec(None, axis)),
        check_rep=not _fused_kernels_on(tc),
    )


def make_sharded_population_step(
    tc: TrainConfig,
    mesh: Mesh,
    per_trial_batch: bool = False,
    axis: str = "pop",
) -> Callable:
    """Population step with the K axis split over ``mesh``'s ``axis``.

    Wraps the vmapped step in ``shard_map``: each of the N devices advances a
    contiguous K/N block of trials, every argument/output with a leading K
    axis is partitioned on ``axis``, and the (shared-stream) batch replicates.
    K must be divisible by N — ``pad_population`` gives the padded size and
    callers top up with 0-budget trials that freeze immediately.

    On a two-level ``(pop, model)`` mesh the step shard_maps over BOTH axes:
    each lane row runs a width-W tensor-parallel program (heads / ff / mamba
    channels width-local per ``tp_width_rules``, psums at the model-code
    seams), so the model axis carries compute instead of replicas.
    """
    from jax.experimental.shard_map import shard_map

    step = make_population_train_step(tc, per_trial_batch=per_trial_batch)
    pop = PartitionSpec(axis)
    batch_spec = pop if per_trial_batch else PartitionSpec()
    width = _mesh_width(mesh, axis)
    if width > 1:
        state_ps, _ = _tp_state_pspecs(tc, mesh, axis)
        # check_rep=False: activations/metrics ARE replicated across each lane
        # row (the seam psums make them so), but the static replication
        # checker cannot see through custom_vjp seams
        return shard_map(
            _tp_body(step, tc, width),
            mesh=mesh,
            in_specs=(state_ps, batch_spec, pop),
            out_specs=(state_ps, pop),
            check_rep=False,
        )
    return shard_map(
        step,
        mesh=mesh,
        in_specs=(pop, batch_spec, pop),
        out_specs=(pop, pop),
        check_rep=not _fused_kernels_on(tc),
    )


# -- device-side decision rules -------------------------------------------------
#
# The fused scan above still returns to the host at every *event* step (rung
# boundary, retirement, PBT round), which caps the chunk length at the event
# gap.  The rule-carrying scan below removes that cap: the early-stop rung
# rules (``repro.core.proposer.early_stop``) and the PBT sliding-window
# quantile are re-expressed as pure vectorized functions of scan-carried
# state, evaluated after every fused step.  A lane whose budget a rule
# truncates freezes at the very next step *inside* the scan (its traced
# ``total_steps`` is rebuilt from the carried budgets each step), so a whole
# ASHA ladder runs as ONE dispatch and the host only harvests retirements
# from the emitted per-step budget log afterwards.
#
# Rule-state layout (a flat dict carried through the scan next to the
# population state; per-lane leaves shard on the population axis, history /
# window leaves replicate):
#
#   common      budgets f32[K] (lane-local step budget; absolute in the
#               batch driver where base == 0), base f32[K] (each lane's
#               applied-step offset: ``total_steps = base + budgets``),
#               local0 i32[K] (lane-local wall step at chunk start)
#   cohort      boundaries f32[B], eta f32[] — the synchronized-flight rule
#               (``InFlightSuccessiveHalving.__call__``)
#   staggered   boundaries, eta, hist f32[B, C] (+inf padded per-rung loss
#               history), counts i32[B] — the asynchronous-SHA rule
#               (``InFlightSuccessiveHalving.observe``)
#   pbt         quantile f32[], wscore f32[W] (score ring), wcount i32[],
#               vbottom/vready bool[K], vlo/vhi f32[K] — the sliding-window
#               quantile; verdicts latch per lane at its round-end step
#               (``PBTLifecycle.decide`` consumes them on the host)


def cohort_rule_update(rules, losses, diverged, local):
    """In-scan twin of ``InFlightSuccessiveHalving.__call__``.

    ``local`` is the cohort's wall step (i32[K], all lanes equal — the batch
    driver's synchronized flights).  A no-op except at rung boundaries, where
    diverged lanes' dead budgets are reclaimed and ranked lanes below the
    ``1/eta`` cut are truncated to the boundary step.  The O(K^2) pairwise
    rank reproduces ``np.argsort``'s stable ascending order (ties keep the
    lower lane index first), so the cut set is bit-identical to the host
    rule's.
    """
    budgets = rules["budgets"]
    boundaries = rules["boundaries"]
    eta = rules["eta"]
    k = budgets.shape[0]
    idx = jnp.arange(k)
    sf = local[0].astype(jnp.float32)
    at = jnp.any(boundaries == sf)
    dead = diverged & (budgets > sf)
    b2 = jnp.where(dead, sf, budgets)
    ranked = (b2 >= sf) & (b2 > 0) & ~diverged & jnp.isfinite(losses)
    n_ranked = ranked.sum()
    n_keep = jnp.ceil(n_ranked.astype(jnp.float32) / eta).astype(jnp.int32)
    lower = ((losses[None, :] < losses[:, None]) & ranked[None, :]).sum(1)
    ties = (
        (losses[None, :] == losses[:, None]) & ranked[None, :]
        & (idx[None, :] < idx[:, None])
    ).sum(1)
    rank = lower + ties
    noop = (n_ranked <= 1) | (n_keep >= n_ranked)
    cut = ranked & (rank >= n_keep) & (b2 > sf) & ~noop
    nb = jnp.where(cut, sf, b2)
    return dict(rules, budgets=jnp.where(at, nb, budgets))


def staggered_rule_update(rules, losses, diverged, local):
    """In-scan twin of ``InFlightSuccessiveHalving.observe`` (async SHA).

    ``local`` is each lane's own wall step (i32[K]) — refilled lanes sit at
    different steps.  A lane whose local step lands on a rung boundary (and
    that is live, finite and still inside its budget — a frozen lane's wall
    clock keeps ticking past retirement inside a long chunk) appends its loss
    to that rung's history and is truncated unless it ranks in the top
    ``1/eta`` of the history *including* its own entry.  Simultaneous hits
    append in lane order, reproducing the host rule's lane loop exactly.
    ``hist`` capacity must cover every possible append (the driver sizes it
    as current max count + K before each dispatch).
    """
    budgets = rules["budgets"]
    boundaries = rules["boundaries"]
    eta = rules["eta"]
    hist = rules["hist"]
    counts = rules["counts"]
    k = budgets.shape[0]
    n_rungs, cap = hist.shape
    idx = jnp.arange(k)
    lf = local.astype(jnp.float32)
    eq = lf[:, None] == boundaries[None, :]                      # [K, B]
    at = eq.any(1)
    bi = jnp.argmax(eq, 1)
    hit = (
        at & (budgets > 0) & ~diverged
        & jnp.isfinite(losses) & (lf <= budgets)
    )
    j_lt_i = idx[None, :] < idx[:, None]
    same = hit[None, :] & hit[:, None] & (bi[None, :] == bi[:, None])
    n_before = (same & j_lt_i).sum(1)
    new_len = counts[bi] + n_before + 1
    n_keep = jnp.ceil(new_len.astype(jnp.float32) / eta).astype(jnp.int32)
    col = jnp.arange(cap)
    rank_hist = (
        (hist[bi] < losses[:, None]) & (col[None, :] < counts[bi][:, None])
    ).sum(1)
    rank_same = (same & j_lt_i & (losses[None, :] < losses[:, None])).sum(1)
    rank = rank_hist + rank_same
    cut = hit & (rank >= n_keep) & (budgets > lf)
    new_budgets = jnp.where(cut, lf, budgets)
    slot = counts[bi] + n_before
    ok = hit & (slot < cap)
    flat = jnp.where(ok, bi * cap + slot, n_rungs * cap)         # last = dump
    padded = jnp.concatenate([hist.reshape(-1), jnp.zeros((1,), hist.dtype)])
    new_hist = padded.at[flat].set(losses)[: n_rungs * cap].reshape(n_rungs, cap)
    new_counts = counts + (ok[:, None] & eq).sum(0)
    return dict(rules, budgets=new_budgets, hist=new_hist, counts=new_counts)


def pbt_rule_update(rules, losses, diverged, local):
    """In-scan PBT sliding-window quantile (``PBTLifecycle``'s async rule).

    A lane hitting its round-end step (``local == budgets``; a diverged
    lane's wall clock still reaches it) appends its score to the ring window
    in lane order, then latches a per-lane verdict: ``vbottom`` (score at or
    below the low quantile of the updated window), the quantile values
    ``vlo``/``vhi``, and ``vready``.  The host harvest feeds the verdicts to
    ``PBTLifecycle.note_device_verdict`` — donor choice and hyperparameter
    perturbation stay host-side (they draw from the proposer's RNG).
    Budgets are never truncated here: PBT rounds end by budget.
    """
    budgets = rules["budgets"]
    wscore = rules["wscore"]
    wcount = rules["wcount"]
    quantile = rules["quantile"]
    from ..core.proposer.pbt import DIVERGED_SCORE, window_quantile

    k = budgets.shape[0]
    w = wscore.shape[0]
    idx = jnp.arange(k)
    lf = local.astype(jnp.float32)
    hit = (budgets > 0) & (lf == budgets)
    score = jnp.where(
        diverged | ~jnp.isfinite(losses), jnp.float32(DIVERGED_SCORE), -losses
    )
    n_before = (hit[None, :] & (idx[None, :] < idx[:, None])).sum(1)
    slot = (wcount + n_before) % w
    flat = jnp.where(hit, slot, w)                               # last = dump
    padded = jnp.concatenate([wscore, jnp.zeros((1,), wscore.dtype)])
    new_wscore = padded.at[flat].set(score)[:w]
    new_wcount = wcount + hit.sum()
    lo, hi = window_quantile(new_wscore, new_wcount, quantile, xp=jnp)
    return dict(
        rules,
        wscore=new_wscore,
        wcount=new_wcount,
        vbottom=jnp.where(hit, score <= lo, rules["vbottom"]),
        vready=rules["vready"] | hit,
        vlo=jnp.where(hit, lo, rules["vlo"]),
        vhi=jnp.where(hit, hi, rules["vhi"]),
    )


_RULE_UPDATES: Dict[str, Callable] = {
    "cohort": cohort_rule_update,
    "staggered": staggered_rule_update,
    "pbt": pbt_rule_update,
}
# per-lane rule-state leaves (shard on the population axis; the rest replicate)
_RULE_LANE_KEYS: Dict[str, frozenset] = {
    "cohort": frozenset({"budgets", "base", "local0"}),
    "staggered": frozenset({"budgets", "base", "local0"}),
    "pbt": frozenset({"budgets", "base", "local0",
                      "vbottom", "vready", "vlo", "vhi"}),
}


def cohort_rule_state(budgets, base, local0, boundaries, eta) -> Dict[str, Any]:
    return {
        "budgets": jnp.asarray(budgets, jnp.float32),
        "base": jnp.asarray(base, jnp.float32),
        "local0": jnp.asarray(local0, jnp.int32),
        "boundaries": jnp.asarray(boundaries, jnp.float32),
        "eta": jnp.asarray(eta, jnp.float32),
    }


def staggered_rule_state(
    budgets, base, local0, boundaries, eta, hist, counts
) -> Dict[str, Any]:
    state = cohort_rule_state(budgets, base, local0, boundaries, eta)
    state["hist"] = jnp.asarray(hist, jnp.float32)
    state["counts"] = jnp.asarray(counts, jnp.int32)
    return state


def pbt_rule_state(
    budgets, base, local0, quantile, wscore, wcount
) -> Dict[str, Any]:
    budgets = jnp.asarray(budgets, jnp.float32)
    k = budgets.shape[0]
    return {
        "budgets": budgets,
        "base": jnp.asarray(base, jnp.float32),
        "local0": jnp.asarray(local0, jnp.int32),
        "quantile": jnp.asarray(quantile, jnp.float32),
        "wscore": jnp.asarray(wscore, jnp.float32),
        "wcount": jnp.asarray(wcount, jnp.int32),
        "vbottom": jnp.zeros((k,), bool),
        "vready": jnp.zeros((k,), bool),
        "vlo": jnp.zeros((k,), jnp.float32),
        "vhi": jnp.zeros((k,), jnp.float32),
    }


def rule_state_specs(mode: str, axis: str = "pop") -> Dict[str, PartitionSpec]:
    """PartitionSpecs for a rule-state dict on the population mesh."""
    pop = PartitionSpec(axis)
    rep = PartitionSpec()
    lane_keys = _RULE_LANE_KEYS[mode]
    keys = {"budgets", "base", "local0"}
    if mode in ("cohort", "staggered"):
        keys |= {"boundaries", "eta"}
    if mode == "staggered":
        keys |= {"hist", "counts"}
    if mode == "pbt":
        keys |= {"quantile", "wscore", "wcount", "vbottom", "vready", "vlo", "vhi"}
    return {k: (pop if k in lane_keys else rep) for k in keys}


def _sharded_rule_update(mode: str, axis: str) -> Callable:
    """Wrap a rule update for a sharded scan: gather the K-length lane
    vectors (never the train state), evaluate the global rule identically on
    every device, and slice each device's lane block back out.  History /
    window / config leaves are replicated, so the global computation keeps
    them consistent across devices by construction."""
    update = _RULE_UPDATES[mode]
    lane_keys = _RULE_LANE_KEYS[mode]

    def upd(rules, losses, diverged, local):
        blk = losses.shape[0]
        me = jax.lax.axis_index(axis)
        gather = lambda x: jax.lax.all_gather(x, axis, tiled=True)
        grules = {k: (gather(v) if k in lane_keys else v) for k, v in rules.items()}
        gnew = update(grules, gather(losses), gather(diverged), gather(local))
        return {
            k: (jax.lax.dynamic_slice_in_dim(v, me * blk, blk)
                if k in lane_keys else v)
            for k, v in gnew.items()
        }

    return upd


def make_population_rule_scan_step(
    tc: TrainConfig,
    data,
    chunk: int,
    mode: str,
    per_trial_batch: bool = True,
    rule_update: Optional[Callable] = None,
) -> Callable:
    """``(pstate, hp, steps0, stream_lo, stream_hi, rules)
    -> ((pstate, rules), metrics)`` — the fused scan with an in-scan
    decision rule.

    Like ``make_population_scan_step`` but each step rebuilds the traced
    ``hp.total_steps`` from the carried rule state (``base + budgets``) and
    then applies ``mode``'s rule update to the post-step losses, so a rung
    cut (or PBT verdict) lands at exactly the step the host loop would have
    applied it — without leaving the device.  ``metrics`` gains a
    ``budgets`` log (``[chunk, K]``): the emitted event trace the host
    harvests retirements from.
    """
    from ..data.pipeline import synth_population_batch, synth_tokens, tokens_to_batch

    step = make_population_train_step(tc, per_trial_batch=per_trial_batch)
    update = _RULE_UPDATES[mode] if rule_update is None else rule_update

    def scan_chunk(pstate: PopState, hp: HParams, steps0, stream_lo, stream_hi,
                   rules):
        def body(carry, t):
            pst, rl = carry
            hp_t = dataclasses.replace(hp, total_steps=rl["base"] + rl["budgets"])
            if per_trial_batch:
                batch = synth_population_batch(
                    data, stream_lo, stream_hi, steps0 + t, xp=jnp)
            else:
                toks = synth_tokens(
                    jnp, data, (data.global_batch,), steps0 + t,
                    stream_lo, stream_hi)
                batch = tokens_to_batch(jnp, data, toks)
            new, metrics = step(pst, batch, hp_t)
            local = rl["local0"] + t + 1
            new_rl = update(rl, new["last_loss"], new["diverged"], local)
            return (new, new_rl), dict(metrics, budgets=new_rl["budgets"])

        return jax.lax.scan(
            body, (pstate, rules), jnp.arange(int(chunk), dtype=jnp.int32))

    return scan_chunk


def make_sharded_population_rule_scan_step(
    tc: TrainConfig,
    mesh: Mesh,
    data,
    chunk: int,
    mode: str,
    per_trial_batch: bool = True,
    axis: str = "pop",
) -> Callable:
    """``shard_map`` twin of the rule-carrying scan.

    Training stays embarrassingly parallel (each device scans its own K/N
    lane block), but the decision rules are *global*: at each step the
    K-length loss/budget/latch vectors are ``all_gather``-ed (never the
    train state), every device evaluates the identical global rule, and each
    slices its own block of the new budgets back out — so the sharded cut
    set is bit-identical to the vmapped engine's by construction.
    """
    from jax.experimental.shard_map import shard_map

    fn = make_population_rule_scan_step(
        tc, data, chunk, mode, per_trial_batch=per_trial_batch,
        rule_update=_sharded_rule_update(mode, axis),
    )
    pop = PartitionSpec(axis)
    rep = PartitionSpec()
    lane = pop if per_trial_batch else rep
    rules_spec = rule_state_specs(mode, axis)
    # check_rep=False: the history/window leaves ARE replicated (every device
    # runs the identical global update on all_gather-ed inputs), but the
    # static replication checker cannot infer that through the gather
    width = _mesh_width(mesh, axis)
    if width > 1:
        # two-level mesh: training is width-W tensor parallel per lane row;
        # the rule update still all_gathers over the pop axis only — devices
        # in one row hold identical (replicated) losses, so every device
        # evaluates the same global rule and the cut set stays width-invariant
        state_ps, _ = _tp_state_pspecs(tc, mesh, axis)
        return shard_map(
            _tp_body(fn, tc, width),
            mesh=mesh,
            in_specs=(state_ps, pop, lane, lane, lane, rules_spec),
            out_specs=((state_ps, rules_spec), PartitionSpec(None, axis)),
            check_rep=False,
        )
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(pop, pop, lane, lane, lane, rules_spec),
        out_specs=((pop, rules_spec), PartitionSpec(None, axis)),
        check_rep=False,
    )


def pad_population(k: int, mesh: Optional[Mesh], axis: str = "pop") -> int:
    """Smallest population size >= k that divides evenly over ``mesh``'s lane
    rows (on a two-level mesh that is the pop-axis size, NOT the device
    count: a width-W row serves ONE lane block W-wide)."""
    n = 1 if mesh is None else _pop_rows(mesh, axis)
    return ((max(k, 1) + n - 1) // n) * n


def shard_population_state(
    pstate: PopState, mesh: Mesh, axis: str = "pop",
    tc: Optional[TrainConfig] = None,
) -> PopState:
    """Place a freshly initialized population state on the mesh (leading K dim
    on ``axis``) so the first sharded step does not pay an input reshard.
    On a two-level mesh pass ``tc`` so each lane's parameter/optimizer leaves
    land width-partitioned per ``tp_width_rules`` (matching what the TP step
    computes on) instead of row-replicated."""
    if tc is not None and _mesh_width(mesh, axis) > 1:
        return place_two_level(pstate, tc, mesh, axis=axis)
    return jax.device_put(pstate, population_specs(pstate, mesh, axis))


# -- compile-once caches --------------------------------------------------------
#
# vmapped: one entry per (static config, population size, batch mode);
# sharded: additionally keyed on the mesh's device set and axis name.

_POP_CACHE: Dict[Tuple, Any] = {}
_POP_CACHE_LOCK = threading.Lock()


def get_compiled_population_step(
    tc: TrainConfig, population: int, per_trial_batch: bool = False
):
    """Memoized ``jax.jit`` of the population step with donated state."""
    key = (static_step_key(tc), int(population), bool(per_trial_batch))
    with _POP_CACHE_LOCK:
        fn = _POP_CACHE.get(key)
        if fn is None:
            fn = jax.jit(
                make_population_train_step(tc, per_trial_batch=per_trial_batch),
                donate_argnums=0,
            )
            _POP_CACHE[key] = fn
    return fn


def get_compiled_sharded_population_step(
    tc: TrainConfig,
    population: int,
    mesh: Optional[Mesh] = None,
    per_trial_batch: bool = False,
    axis: str = "pop",
):
    """Memoized jitted ``shard_map`` population step over ``mesh`` (default: a
    1-D mesh over every local device).  Raises if K does not divide over the
    mesh — pad with ``pad_population`` first."""
    mesh = mesh if mesh is not None else population_mesh(axis=axis)
    _check_rows(population, mesh, axis)
    key = (
        static_step_key(tc), int(population), bool(per_trial_batch),
    ) + _mesh_cache_key(mesh, axis)
    with _POP_CACHE_LOCK:
        fn = _POP_CACHE.get(key)
        if fn is None:
            fn = jax.jit(
                make_sharded_population_step(
                    tc, mesh, per_trial_batch=per_trial_batch, axis=axis
                ),
                donate_argnums=0,
            )
            _POP_CACHE[key] = fn
    return fn


def get_compiled_population_scan_step(
    tc: TrainConfig,
    population: int,
    data,
    chunk: int,
    mesh: Optional[Mesh] = None,
    per_trial_batch: bool = True,
    axis: str = "pop",
):
    """Memoized jitted fused-scan step (optionally the ``shard_map`` twin).

    Keyed like the per-step programs plus the chunk length and the data
    stream spec (``data.spec_key`` — the program bakes the batch synthesis
    in).  Drivers dispatch power-of-two chunk sizes, so an experiment
    compiles at most ``log2(chunk_steps) + 1`` scan programs per engine.
    ``clear_population_cache()`` covers these entries too.
    """
    if mesh is not None:
        _check_rows(population, mesh, axis)
    key = (
        static_step_key(tc), int(population), bool(per_trial_batch),
        "scan", int(chunk), data.spec_key,
    ) + (_mesh_cache_key(mesh, axis) if mesh is not None else ())
    with _POP_CACHE_LOCK:
        fn = _POP_CACHE.get(key)
        if fn is None:
            if mesh is None:
                built = make_population_scan_step(
                    tc, data, chunk, per_trial_batch=per_trial_batch)
            else:
                built = make_sharded_population_scan_step(
                    tc, mesh, data, chunk,
                    per_trial_batch=per_trial_batch, axis=axis)
            fn = jax.jit(built, donate_argnums=0)
            _POP_CACHE[key] = fn
    return fn


def get_compiled_population_ring_scan_step(
    tc: TrainConfig,
    population: int,
    data,
    chunk: int,
    capacity: int,
    mesh: Optional[Mesh] = None,
    axis: str = "pop",
):
    """Memoized jitted ring-fed fused scan (``--data-ring``) — the seventh
    entry in the compiled-program family.

    Keyed like the in-scan-synth programs plus the ring capacity (the slot
    modulus is baked in) under the ``"ringscan"`` marker.  Only the
    population state donates — the ring buffer is owned and rotated by the
    fill thread, never by the scan.
    """
    if mesh is not None:
        _check_rows(population, mesh, axis)
    key = (
        static_step_key(tc), int(population), "ringscan", int(chunk),
        int(capacity), data.spec_key,
    ) + (_mesh_cache_key(mesh, axis) if mesh is not None else ())
    with _POP_CACHE_LOCK:
        fn = _POP_CACHE.get(key)
        if fn is None:
            if mesh is None:
                built = make_population_ring_scan_step(
                    tc, data, chunk, capacity)
            else:
                built = make_sharded_population_ring_scan_step(
                    tc, mesh, data, chunk, capacity, axis=axis)
            fn = jax.jit(built, donate_argnums=0)
            _POP_CACHE[key] = fn
    return fn


def get_compiled_population_rule_scan_step(
    tc: TrainConfig,
    population: int,
    data,
    chunk: int,
    mode: str,
    mesh: Optional[Mesh] = None,
    per_trial_batch: bool = True,
    axis: str = "pop",
):
    """Memoized jitted rule-carrying fused scan (``--device-rules``).

    Keyed like the plain scan programs plus the rule ``mode`` — the rule
    update is baked into the scan body.  The staggered mode's history
    capacity and the PBT mode's window length are *shapes* of the rules
    pytree, not part of the key: ``jax.jit`` specializes on them internally,
    and drivers size them to powers of two so an experiment compiles a
    bounded program set.
    """
    if mesh is not None:
        _check_rows(population, mesh, axis)
    key = (
        static_step_key(tc), int(population), bool(per_trial_batch),
        "rulescan", str(mode), int(chunk), data.spec_key,
    ) + (_mesh_cache_key(mesh, axis) if mesh is not None else ())
    with _POP_CACHE_LOCK:
        fn = _POP_CACHE.get(key)
        if fn is None:
            if mesh is None:
                built = make_population_rule_scan_step(
                    tc, data, chunk, mode, per_trial_batch=per_trial_batch)
            else:
                built = make_sharded_population_rule_scan_step(
                    tc, mesh, data, chunk, mode,
                    per_trial_batch=per_trial_batch, axis=axis)
            fn = jax.jit(built, donate_argnums=0)
            _POP_CACHE[key] = fn
    return fn


# one builder table for the lifecycle layer: op -> (vmapped, shard_map twin).
# "snapshot" is the one READ-ONLY op: it must not donate the population state
# (the flight keeps training on it after the harvest), so the jit wrapper
# below keys donation off this table too.
_LANE_OPS: Dict[str, Tuple[Callable, Callable]] = {
    "init": (make_lane_init, make_sharded_lane_init),
    "clone": (make_lane_clone, make_sharded_lane_clone),
    "splice": (make_lane_splice, make_sharded_lane_splice),
    "snapshot": (make_lane_snapshot, make_sharded_lane_snapshot),
    "restore": (make_lane_restore, make_sharded_lane_restore),
    "regrid": (make_lane_regrid, make_sharded_lane_regrid),
}
# snapshot reads the state the flight keeps training on; regrid's output has
# a different lane count than its input, so the buffers are never reusable —
# neither may donate.
_READONLY_LANE_OPS = frozenset({"snapshot", "regrid"})


def get_compiled_lane_op(
    tc: TrainConfig,
    population: int,
    op: str,
    mesh: Optional[Mesh] = None,
    axis: str = "pop",
):
    """Memoized ``jax.jit`` of a lane-lifecycle op.

    ``op`` is one of ``init`` / ``clone`` / ``splice`` / ``snapshot`` /
    ``restore`` / ``regrid``; with ``mesh`` the ``shard_map`` twin is compiled
    instead
    (keyed like the sharded population step, so a streaming flight compiles
    each op it uses exactly once).  Mutating ops donate the population state;
    ``snapshot`` reads it and leaves the flight state alive.

    On a two-level (width>1) mesh the hand-written shard_map twins do not
    apply — state leaves are width-partitioned per lane row, not merely
    lane-blocked — so the vmapped op runs under GSPMD with
    ``out_shardings`` pinned to the TP layout (``two_level_state_specs`` x
    ``tp_width_rules``).  Lifecycle ops fire at event boundaries, not every
    step, so letting XLA partition them costs nothing on the hot path and
    keeps them bit-identical to the vmapped originals by construction.
    """
    if op not in _LANE_OPS:
        raise KeyError(f"unknown lane op {op!r}; available: {sorted(_LANE_OPS)}")
    if mesh is not None:
        _check_rows(population, mesh, axis)
    key = (static_step_key(tc), int(population), f"lane-{op}") + (
        _mesh_cache_key(mesh, axis) if mesh is not None else ()
    )
    with _POP_CACHE_LOCK:
        fn = _POP_CACHE.get(key)
        if fn is None:
            vmapped, sharded = _LANE_OPS[op]
            width = _mesh_width(mesh, axis) if mesh is not None else 1
            if mesh is None:
                built = vmapped(tc)
            elif width > 1 and op not in _READONLY_LANE_OPS:
                rules = _tp_rules_or_raise(tc, width)
                out_sh = two_level_state_specs(
                    _population_state_shapes(tc, int(population)),
                    _state_logical_specs(tc), mesh, axis=axis, rules=rules)
                fn = jax.jit(vmapped(tc), donate_argnums=0,
                             out_shardings=out_sh)
                _POP_CACHE[key] = fn
                return fn
            elif width > 1:
                # snapshot/regrid: GSPMD, output layout decided by the caller
                # (snapshot is host-harvested; regrid re-lays out via
                # place_two_level on the NEW mesh)
                fn = jax.jit(vmapped(tc))
                _POP_CACHE[key] = fn
                return fn
            else:
                built = sharded(tc, mesh, axis=axis)
            if op in _READONLY_LANE_OPS:
                fn = jax.jit(built)
            else:
                fn = jax.jit(built, donate_argnums=0)
            _POP_CACHE[key] = fn
    return fn


def get_compiled_reset_lanes(tc: TrainConfig, population: int):
    """Memoized ``jax.jit`` of the lane-refill reset with donated state."""
    return get_compiled_lane_op(tc, population, "init")


def get_compiled_sharded_reset_lanes(
    tc: TrainConfig,
    population: int,
    mesh: Optional[Mesh] = None,
    axis: str = "pop",
):
    """Memoized jitted ``shard_map`` lane reset over ``mesh`` (keyed like the
    sharded population step, so one refill flight compiles exactly two
    programs: step + reset)."""
    mesh = mesh if mesh is not None else population_mesh(axis=axis)
    return get_compiled_lane_op(tc, population, "init", mesh=mesh, axis=axis)


def clear_population_cache() -> None:
    with _POP_CACHE_LOCK:
        _POP_CACHE.clear()


def count_model_axis_collectives(
    tc: TrainConfig,
    population: int,
    mesh: Mesh,
    data,
    per_trial_batch: bool = False,
    axis: str = "pop",
) -> int:
    """All-reduce count in the lowered population step — the static witness
    that the model axis carries compute.

    The per-step twin has NO population-axis collectives (lanes are
    embarrassingly parallel; the rule twins' all_gathers live in other
    programs), so every all-reduce in its HLO is a model-axis psum from the
    TP seams.  Width 1 must lower to exactly zero.  Abstract (eval_shape)
    arguments only — nothing is allocated.
    """
    from ..launch.hlo_stats import parse_collectives
    from ..optim.hparams import hparams_from_config

    k = int(population)
    step = get_compiled_sharded_population_step(
        tc, k, mesh=mesh, per_trial_batch=per_trial_batch, axis=axis)
    pstate = _population_state_shapes(tc, k)
    bshape = (k, data.global_batch) if per_trial_batch else (data.global_batch,)
    batch = {
        "tokens": jax.ShapeDtypeStruct(bshape + (data.seq_len,), jnp.int32),
        "targets": jax.ShapeDtypeStruct(bshape + (data.seq_len,), jnp.int32),
        "mask": jax.ShapeDtypeStruct(bshape + (data.seq_len,), jnp.float32),
    }
    hp = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((k,), jnp.asarray(x).dtype),
        hparams_from_config(tc))
    txt = step.lower(pstate, batch, hp).compile().as_text()
    width = _mesh_width(mesh, axis)
    stats = parse_collectives(txt, default_group=max(width, 1))
    return int(stats.per_op.get("all-reduce", {}).get("count", 0))


def population_scores(pstate: PopState, diverged_score: float = -1e9):
    """HPO convention: score = -final_loss, with a sentinel for diverged trials.

    Trials that never applied a step (budget 0) also get the sentinel.
    """
    last = pstate["last_loss"]
    ok = ~pstate["diverged"] & jnp.isfinite(last)
    return jnp.where(ok, -last, jnp.float32(diverged_score))
