"""Train / serve step builders.

``make_train_step(tc)`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with explicit shardings.  Features:

* microbatched gradient accumulation (``parallel.microbatch``) via lax.scan,
  with fp32 accumulators and per-microbatch grads cast to
  ``grad_allreduce_dtype`` (bf16 wire compression — the cross-data-axis
  reduction happens at that dtype);
* remat policy forwarded to the scanned super-block;
* AdamW update with dtype-configurable sharded state.

State layout: ``{"params": ..., "opt": {"mu","nu","step"[,"master"]}}``.

**Compile-once HPO path**: ``make_hparam_train_step(tc)`` takes the tunable
hyperparameters (lr / wd / b2 / grad_clip / schedule) as a traced ``HParams``
argument instead of closing over them, and ``get_compiled_train_step(tc)``
memoizes the jitted step on the *static* parts of ``tc`` only — so an HPO
experiment over N trials of one architecture compiles exactly once instead of
N times.  ``donate_argnums=0`` donates the train state buffer.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig
from ..models import transformer as T
from ..models.layers import dtype_of
from ..optim.adamw import adamw_update, init_opt_state
from ..optim.hparams import HParams, hparams_from_config
from ..optim.schedule import warmup_cosine


def init_train_state(key, tc: TrainConfig) -> Dict[str, Any]:
    params = T.init_params(key, tc.model)
    return {"params": params, "opt": init_opt_state(params, tc)}


def train_state_specs(tc: TrainConfig) -> Dict[str, Any]:
    p = T.param_specs(tc.model)
    opt: Dict[str, Any] = {"mu": p, "nu": p, "step": ()}
    if tc.parallel.master_dtype is not None:
        opt["master"] = p
    return {"params": p, "opt": opt}


def _loss_fn(params, batch, tc: TrainConfig):
    cfg = tc.model
    from .loss import cross_entropy

    logits, aux = T.forward(
        params,
        batch.get("tokens"),
        cfg,
        inputs_embeds=batch.get("embeds"),
        remat=tc.parallel.remat,
    )
    loss, metrics = cross_entropy(logits, batch["targets"], batch["mask"], z_loss=tc.z_loss)
    if cfg.has_moe:
        loss = loss + cfg.aux_loss_weight * aux
        metrics["aux_loss"] = aux
    metrics["loss"] = loss
    return loss, metrics


def make_hparam_train_step(tc: TrainConfig) -> Callable:
    """``(state, batch, hp: HParams) -> (state, metrics)`` with traced hparams.

    Only the static parts of ``tc`` (model, parallel, b1, eps, z_loss) are
    closed over; lr / wd / b2 / grad_clip / schedule ride in ``hp`` so one
    compilation serves every trial of the architecture.
    """
    mb = tc.parallel.microbatch
    acc_dt = dtype_of(tc.parallel.grad_allreduce_dtype)

    def train_step(state, batch, hp: HParams):
        params = state["params"]

        if mb and mb > 0:
            gb = next(iter(batch.values())).shape[0]
            assert gb % mb == 0, (gb, mb)
            n_mb = gb // mb
            split = jax.tree.map(lambda a: a.reshape((n_mb, mb) + a.shape[1:]), batch)

            def micro(carry, mb_batch):
                g_acc, m_acc = carry
                (_, metrics), grads = jax.value_and_grad(
                    lambda p: _loss_fn(p, mb_batch, tc), has_aux=True
                )(params)
                grads = jax.tree.map(lambda g: g.astype(acc_dt), grads)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                m_acc = jax.tree.map(lambda a, m: a + m.astype(jnp.float32), m_acc, metrics)
                return (g_acc, m_acc), None

            keys = {"ce_loss", "accuracy", "loss"}
            if tc.model.has_moe:
                keys.add("aux_loss")
            if tc.z_loss > 0:
                keys.add("z_loss")
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {k: jnp.zeros((), jnp.float32) for k in keys}
            (grads, metrics), _ = jax.lax.scan(micro, (g0, m0), split)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            metrics = jax.tree.map(lambda m: m / n_mb, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(
                lambda p: _loss_fn(p, batch, tc), has_aux=True
            )(params)
            grads = jax.tree.map(lambda g: g.astype(acc_dt), grads)

        lr = warmup_cosine(
            state["opt"]["step"],
            peak_lr=hp.learning_rate,
            warmup_steps=hp.warmup_steps,
            total_steps=hp.total_steps,
        )
        new_params, new_opt, om = adamw_update(grads, params, state["opt"], lr, tc, hp=hp)
        metrics = dict(metrics, **om, lr=lr)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_train_step(tc: TrainConfig) -> Callable:
    """Back-compat ``(state, batch) -> (state, metrics)``: hparams from ``tc``."""
    step = make_hparam_train_step(tc)
    hp = hparams_from_config(tc)

    def train_step(state, batch):
        return step(state, batch, hp)

    return train_step


# -- compile-once cache ---------------------------------------------------------
#
# Keyed on the static parts of TrainConfig only (frozen dataclasses hash by
# value).  Distinct trials of one architecture share a single jitted step; the
# per-trial knobs arrive as the traced HParams argument.

_STEP_CACHE: Dict[Tuple, Any] = {}
_STEP_CACHE_LOCK = threading.Lock()


def static_step_key(tc: TrainConfig) -> Tuple:
    """The compile-cache key: everything a trial may NOT vary per-proposal."""
    return (tc.model, tc.parallel, tc.b1, tc.eps, tc.z_loss)


def get_compiled_train_step(tc: TrainConfig):
    """Memoized ``jax.jit(make_hparam_train_step(tc), donate_argnums=0)``.

    Call ``fn._cache_size()`` (or compare ``id(fn)`` across trials) to verify
    the compile-once property; ``clear_step_cache()`` resets between tests.
    Thread-safe: trials running on resource-manager worker threads share one
    jitted callable per static config.
    """
    key = static_step_key(tc)
    with _STEP_CACHE_LOCK:
        fn = _STEP_CACHE.get(key)
        if fn is None:
            fn = jax.jit(make_hparam_train_step(tc), donate_argnums=0)
            _STEP_CACHE[key] = fn
    return fn


def clear_step_cache() -> None:
    with _STEP_CACHE_LOCK:
        _STEP_CACHE.clear()


def make_eval_step(tc: TrainConfig) -> Callable:
    def eval_step(params, batch):
        _, metrics = _loss_fn(params, batch, tc)
        return metrics

    return eval_step
