from .train_step import (
    get_compiled_train_step,
    init_train_state,
    make_eval_step,
    make_hparam_train_step,
    make_train_step,
    train_state_specs,
)
from .population import (
    get_compiled_population_step,
    init_population_state,
    make_population_train_step,
    population_scores,
)
from .serve_step import greedy_generate, make_serve_step
from .loss import cross_entropy
