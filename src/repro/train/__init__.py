from .train_step import init_train_state, make_eval_step, make_train_step, train_state_specs
from .serve_step import greedy_generate, make_serve_step
from .loss import cross_entropy
