"""Serving: batched single-token decode (greedy or temperature sampling)."""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer as T


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0) -> Callable:
    """(params, cache, tokens (B,1), pos, [key]) -> (next_tokens (B,1), cache)."""

    def serve_step(params, cache, tokens, pos, key=None):
        logits, cache = T.decode_step(params, cache, tokens, pos, cfg)
        last = logits[:, -1]
        if temperature > 0.0 and key is not None:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return serve_step


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array, n_new: int, max_seq: int):
    """Tiny reference generation loop (examples / tests)."""
    B, P = prompt.shape
    cache = T.init_cache(cfg, B, max_seq, dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg))
    tok = prompt[:, :1]
    out = [tok]
    for i in range(P + n_new - 1):
        nxt, cache = step(params, cache, tok, i)
        tok = prompt[:, i + 1 : i + 2] if i + 1 < P else nxt
        out.append(tok)
    return jnp.concatenate(out, axis=1)
