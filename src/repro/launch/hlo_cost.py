"""Trip-count-aware cost analysis of post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation **once** — a
``lax.scan`` lowered to ``while`` has its body counted a single time, so
FLOPs/bytes/collectives of scanned-layer models are undercounted by the trip
count (30-88x here).  Fortunately the CPU/TPU compilers annotate every while
with ``backend_config={"known_trip_count":{"n":...}}``; this module walks the
computation graph from ENTRY, multiplying each called computation by how many
times it actually runs:

    flops       2 x result_elems x contracted_elems per ``dot``
                (+ convolutions; elementwise/transcendental flops are ignored —
                 they are O(1/100) of dot flops for these models)
    bytes       operands + result per instruction, at fusion *boundaries*
                (internals of a fusion never touch HBM), skipping pure
                bookkeeping ops (tuple/gte/parameter/constant/bitcast)
    collectives operand bytes + modeled wire bytes per op (see hlo_stats);
                ops inside loops are multiplied by trip count.  ``tpu_wire``
                re-costs f32 collectives at bf16 width: XLA-CPU's float
                normalization promotes the logically-bf16 params/grads/
                activations this program moves to f32, which a TPU build
                would not.

This is a *model*, not ground truth — but unlike the built-in analysis it is
consistent across architectures and shapes, which is what roofline
comparisons need.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from .hlo_stats import COLLECTIVES, _group_size, _wire_factor, shape_bytes

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(r"true_computation=%?([\w.\-]+), false_computation=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_SHAPE_DIMS_RE = re.compile(r"[a-z0-9]+\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")


@dataclasses.dataclass
class Instr:
    name: str       # instruction name (no leading %)
    opcode: str
    result: str     # result type text
    operands: str   # operand region text
    attrs: str      # attributes after the operand parens


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_bf16eq: float = 0.0
    # portion attributed to jax.named_scope("kernel_*") regions — tensors a
    # TPU Pallas kernel keeps in VMEM and never writes to HBM
    kernel_flops: float = 0.0
    kernel_bytes: float = 0.0
    kernel_bytes_bf16eq: float = 0.0
    coll_operand: float = 0.0
    coll_wire: float = 0.0
    coll_tpu_wire: float = 0.0
    per_op: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_bf16eq += other.bytes_bf16eq * mult
        self.kernel_flops += other.kernel_flops * mult
        self.kernel_bytes += other.kernel_bytes * mult
        self.kernel_bytes_bf16eq += other.kernel_bytes_bf16eq * mult
        self.coll_operand += other.coll_operand * mult
        self.coll_wire += other.coll_wire * mult
        self.coll_tpu_wire += other.coll_tpu_wire * mult
        for op, d in other.per_op.items():
            mine = self.per_op.setdefault(
                op, {"count": 0.0, "operand_bytes": 0.0, "wire_bytes": 0.0, "tpu_wire_bytes": 0.0}
            )
            for k in mine:
                mine[k] += d[k] * mult


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _split_instr(line: str) -> Optional[Instr]:
    eq = line.find(" = ")
    if eq < 0:
        return None
    nm = _NAME_RE.match(line)
    name = nm.group(1) if nm else ""
    rhs = line[eq + 3 :].lstrip()
    if rhs.startswith("("):  # tuple result type — skip balanced parens
        depth = 0
        j = 0
        for j, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    break
        result = rhs[: j + 1]
        rest = rhs[j + 1 :].lstrip()
        k = rest.find("(")
        if k < 0:
            return None
        opcode = rest[:k].strip()
        tail = rest[k:]
    else:
        k = rhs.find("(")
        if k < 0:
            return None
        head = rhs[:k].rstrip()
        sp = head.rsplit(" ", 1)
        if len(sp) == 2:
            result, opcode = sp
        else:
            result, opcode = "", sp[0]
        tail = rhs[k:]
    # operand region: balanced parens from tail[0]
    depth = 0
    j = 0
    for j, c in enumerate(tail):
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                break
    operands = tail[1:j]
    attrs = tail[j + 1 :]
    return Instr(name=name, opcode=opcode, result=result, operands=operands, attrs=attrs)


def parse_computations(hlo_text: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in hlo_text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(raw)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if raw.startswith("}"):
            cur = None
            continue
        ins = _split_instr(raw)
        if ins is not None:
            comps[cur].append(ins)
    return comps, entry


def _dims_of(type_text: str) -> List[int]:
    m = _SHAPE_DIMS_RE.search(type_text)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",")] if m.group(1) else []


def _operand_entries(operands: str) -> List[str]:
    """Split an operand region on top-level commas."""
    out, depth, cur = [], 0, []
    for c in operands:
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        if c == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(c)
    if cur:
        out.append("".join(cur).strip())
    return [e for e in out if e]


def _operand_bytes(ins: Instr, types: Dict[str, str], f32_as_bf16: bool = False) -> int:
    """Bytes of all operands, resolving name-only references via ``types``."""
    total = 0
    for entry in _operand_entries(ins.operands):
        if "[" in entry:
            total += shape_bytes(entry, f32_as_bf16)
            continue
        m = _OPERAND_NAME_RE.search(entry)
        if m and m.group(1) in types:
            total += shape_bytes(types[m.group(1)], f32_as_bf16)
    return total


def _dot_flops(ins: Instr, types: Dict[str, str]) -> float:
    out = 1
    for d in _dims_of(ins.result):
        out *= d
    # contracted size from lhs operand shape + lhs_contracting_dims
    entries = _operand_entries(ins.operands)
    lhs_dims: List[int] = []
    if entries:
        e = entries[0]
        if "[" in e:
            lhs_dims = _dims_of(e)
        else:
            m = _OPERAND_NAME_RE.search(e)
            if m and m.group(1) in types:
                lhs_dims = _dims_of(types[m.group(1)])
    mc = _LHS_CONTRACT_RE.search(ins.attrs)
    contracted = 1
    if lhs_dims and mc and mc.group(1):
        for ci in mc.group(1).split(","):
            i = int(ci)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * out * contracted


def _conv_flops(ins: Instr, types: Dict[str, str]) -> float:
    # 2 x output elems x (kernel spatial x in_channels): derive from rhs shape
    entries = _operand_entries(ins.operands)
    out = 1
    for d in _dims_of(ins.result):
        out *= d
    rhs: List[int] = []
    if len(entries) >= 2:
        e = entries[1]
        if "[" in e:
            rhs = _dims_of(e)
        else:
            m = _OPERAND_NAME_RE.search(e)
            if m and m.group(1) in types:
                rhs = _dims_of(types[m.group(1)])
    k = 1
    for d in rhs:
        k *= d
    # rhs = kernel; one of its dims is out_channels (already in `out`)
    if rhs:
        k //= max(rhs[-1], 1)  # heuristic: last dim = output feature dim
    return 2.0 * out * k


def analyse_hlo(hlo_text: str, default_group: int = 1) -> Totals:
    comps, entry = parse_computations(hlo_text)
    # per-computation name -> result-type map for operand shape resolution
    type_maps: Dict[str, Dict[str, str]] = {
        cname: {i.name: i.result for i in instrs if i.name}
        for cname, instrs in comps.items()
    }
    memo: Dict[Tuple[str, bool], Totals] = {}
    fusion_flops_memo: Dict[str, float] = {}

    def _scoped(ins: Instr) -> bool:
        return "kernel_" in ins.attrs

    def fusion_flops(name: str) -> float:
        """dots/convs inside a fusion computation (flops only; bytes stay at boundary)."""
        if name in fusion_flops_memo:
            return fusion_flops_memo[name]
        total = 0.0
        types = type_maps.get(name, {})
        for ins in comps.get(name, []):
            if ins.opcode == "dot":
                total += _dot_flops(ins, types)
            elif ins.opcode == "convolution":
                total += _conv_flops(ins, types)
            elif ins.opcode == "fusion":
                m = _CALL_ATTR_RE.search(ins.attrs)
                if m:
                    total += fusion_flops(m.group(1))
        fusion_flops_memo[name] = total
        return total

    def walk(name: str, in_scope: bool = False) -> Totals:
        key = (name, in_scope)
        if key in memo:
            return memo[key]
        memo[key] = Totals()  # guard (recursion shouldn't happen, but be safe)
        t = Totals()
        types = type_maps.get(name, {})

        def io_bytes(ins, eq=False):
            return _operand_bytes(ins, types, eq) + shape_bytes(ins.result, eq)

        def account(ins, flops=0.0):
            b = io_bytes(ins)
            beq = io_bytes(ins, True)
            t.flops += flops
            t.bytes += b
            t.bytes_bf16eq += beq
            if in_scope or _scoped(ins):
                t.kernel_flops += flops
                t.kernel_bytes += b
                t.kernel_bytes_bf16eq += beq

        for ins in comps.get(name, []):
            op = ins.opcode
            base_op = op[:-6] if op.endswith("-start") else op
            scoped = in_scope or _scoped(ins)
            if op == "while":
                m = _COND_BODY_RE.search(ins.attrs)
                trip = 1
                mt = _TRIP_RE.search(ins.attrs)
                if mt:
                    trip = int(mt.group(1))
                if m:
                    t.add(walk(m.group(2), scoped), trip)   # body
                    t.add(walk(m.group(1), scoped), trip)   # cond
                continue
            if op in ("call", "async-start") or op.startswith("async"):
                m = _CALL_ATTR_RE.search(ins.attrs)
                if m:
                    t.add(walk(m.group(1), scoped))
                continue
            if op == "custom-call":
                m = _CALL_ATTR_RE.search(ins.attrs)
                if m:
                    t.add(walk(m.group(1), scoped))
                account(ins)
                continue
            if op == "conditional":
                names = []
                mb = _BRANCHES_RE.search(ins.attrs)
                if mb:
                    names = [x.strip().lstrip("%") for x in mb.group(1).split(",")]
                else:
                    mtf = _TRUE_FALSE_RE.search(ins.attrs)
                    if mtf:
                        names = [mtf.group(1), mtf.group(2)]
                if names:
                    branches = [walk(n, scoped) for n in names]
                    # max-cost branch (upper bound)
                    t.add(max(branches, key=lambda b: b.flops + b.bytes))
                continue
            if op == "fusion":
                m = _CALL_ATTR_RE.search(ins.attrs)
                account(ins, fusion_flops(m.group(1)) if m else 0.0)
                continue
            if op == "dot":
                account(ins, _dot_flops(ins, types))
                continue
            if op == "convolution":
                account(ins, _conv_flops(ins, types))
                continue
            if base_op in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                obytes = _operand_bytes(ins, types)
                n = _group_size(ins.attrs, default_group)
                wf = _wire_factor(base_op, n)
                wire = obytes * wf
                # f32 on the wire that is logically bf16 on TPU
                obytes_eq = _operand_bytes(ins, types, True)
                tpu = wire * (obytes_eq / obytes if obytes else 1.0)
                d = t.per_op.setdefault(
                    base_op,
                    {"count": 0.0, "operand_bytes": 0.0, "wire_bytes": 0.0, "tpu_wire_bytes": 0.0},
                )
                d["count"] += 1
                d["operand_bytes"] += obytes
                d["wire_bytes"] += wire
                d["tpu_wire_bytes"] += tpu
                t.coll_operand += obytes
                t.coll_wire += wire
                t.coll_tpu_wire += tpu
                t.bytes += obytes  # data still moves through HBM
                t.bytes_bf16eq += obytes_eq
                continue
            if op in _SKIP_BYTES_OPS or op.endswith("-done"):
                continue
            account(ins)
        memo[key] = t
        return t

    if entry is None:
        return Totals()
    return walk(entry)
