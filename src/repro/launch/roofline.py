"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

    compute    = HLO_FLOPs_per_device        / 197e12   [s]
    memory     = HBM_bytes_per_device        / 819e9    [s]
    collective = wire_bytes_per_device       / 50e9     [s]

Inputs come from ``hlo_cost.analyse_hlo`` (trip-count-aware walk of the
post-SPMD per-device program — chip count is already divided out):

* ``bytes``: counted at bf16-equivalent width (XLA-CPU promotes logically-bf16
  tensors to f32) and **kernel-adjusted** — bytes inside
  ``jax.named_scope("kernel_*")`` regions (flash-attention blocks, SSM scan
  chunks, fused norms) stay in VMEM on the TPU target and are subtracted;
  the unadjusted figure is kept alongside.
* ``wire bytes``: ring-model wire traffic per collective (see hlo_stats),
  f32->bf16-corrected, over one 50 GB/s ICI link (conservative).

MODEL_FLOPS uses the standard estimates (6·N·D for a train step over D
tokens, 2·N_active·D for prefill/decode), divided by chip count; the
useful-FLOP ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute,
causal-mask waste, and dispatch overhead.  ``roofline_fraction`` =
(MODEL_FLOPS / peak) / max(term) — the fraction of the binding roofline
bound spent on useful model math; this is the score §Perf hillclimbs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

KIND_TO_FLOP_FACTOR = {"train": 6.0, "prefill": 2.0, "decode": 2.0}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float              # kernel-adjusted bf16eq bytes / HBM_BW
    collective_s: float          # tpu-corrected wire bytes / ICI_BW
    memory_unadjusted_s: float   # without the kernel-VMEM adjustment
    flops_dev: float
    bytes_dev: float             # bf16eq, kernel-adjusted
    bytes_dev_raw: float         # as-compiled (f32-promoted), unadjusted
    kernel_bytes_dev: float      # bytes inside kernel_* scopes (stay in VMEM on TPU)
    wire_bytes_dev: float
    model_flops_dev: float
    useful_ratio: float
    bottleneck: str
    roofline_fraction: float

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def analyse(
    *,
    flops_dev: float,
    bytes_bf16eq_dev: float,
    kernel_bytes_bf16eq_dev: float,
    bytes_raw_dev: float,
    wire_bytes_dev: float,
    n_params_active: float,
    tokens_global: float,
    kind: str,
    n_chips: int,
) -> Roofline:
    bytes_adj = max(bytes_bf16eq_dev - kernel_bytes_bf16eq_dev, 0.0)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_adj / HBM_BW
    collective_s = wire_bytes_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    model_flops = KIND_TO_FLOP_FACTOR[kind] * n_params_active * tokens_global / n_chips
    useful = model_flops / flops_dev if flops_dev else 0.0
    dominant = max(terms.values())
    frac = (model_flops / PEAK_FLOPS) / dominant if dominant > 0 else 0.0
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        memory_unadjusted_s=bytes_bf16eq_dev / HBM_BW,
        flops_dev=flops_dev,
        bytes_dev=bytes_adj,
        bytes_dev_raw=bytes_raw_dev,
        kernel_bytes_dev=kernel_bytes_bf16eq_dev,
        wire_bytes_dev=wire_bytes_dev,
        model_flops_dev=model_flops,
        useful_ratio=useful,
        bottleneck=bottleneck,
        roofline_fraction=min(frac, 1.0),
    )
