"""End-to-end training driver.

Runs a real (CPU-sized or pod-sized) training loop with the production code
path: sharded train_step under a mesh, synthetic deterministic data,
atomic async checkpointing, auto-resume, and optional fault injection to
exercise the restart path.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --smoke \\
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
    # kill it mid-run; rerun the same command -> resumes from the last step.

``--smoke`` selects the reduced config (CPU-trainable); omit it on a real pod
to train the full architecture.  ``--fail-at N`` simulates a crash at step N
(exercises checkpoint/restart in tests and demos).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import sys
import time

import jax
import numpy as np


def build(args):
    import jax.numpy as jnp

    from ..configs import ARCH_IDS, get_config, get_smoke_config
    from ..configs.base import ParallelConfig, TrainConfig
    from ..data.pipeline import SyntheticLM
    from ..distributed.sharding import build_sharding, make_rules, sharding_context
    from ..train.train_step import init_train_state, make_train_step, train_state_specs
    from .mesh import make_trial_mesh

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.vocab)
    n_dev = min(args.devices or len(jax.devices()), len(jax.devices()))
    mesh = make_trial_mesh(n_dev)
    pc = ParallelConfig(
        mesh_shape=tuple(mesh.devices.shape),
        mesh_axes=tuple(mesh.axis_names),
        microbatch=args.microbatch,
        remat=args.remat,
    )
    tc = TrainConfig(
        model=cfg,
        parallel=pc,
        learning_rate=args.lr,
        warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
        seed=args.seed,
    )
    rules = make_rules(pc.mesh_axes)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    step_fn = make_train_step(tc)

    def fn(state, batch):
        with sharding_context(mesh, rules):
            return step_fn(state, batch)

    state_shapes = jax.eval_shape(
        functools.partial(init_train_state, tc=tc), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    state_sh = build_sharding(state_shapes, train_state_specs(tc), rules, mesh)
    jitted = jax.jit(fn, in_shardings=(state_sh, None), out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    return tc, mesh, data, jitted, state_sh


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--smoke", action="store_true", help="reduced config (CPU-trainable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--vocab", type=int, default=0, help="override vocab (0 = config)")
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--microbatch", type=int, default=0)
    p.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    p.add_argument("--devices", type=int, default=0, help="devices for the trial mesh")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--fail-at", type=int, default=0, help="simulate a crash at this step")
    args = p.parse_args(argv)

    from ..checkpoint.checkpointer import Checkpointer

    tc, mesh, data, jitted, state_sh = build(args)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    state = None
    if ckpt is not None and ckpt.latest_step() is not None:
        restored, manifest = ckpt.restore()
        state = jax.device_put(restored, state_sh)
        start = int(manifest["step"])
        print(f"resumed from checkpoint at step {start}")
    if state is None:
        from ..train.train_step import init_train_state

        state = jax.device_put(
            init_train_state(jax.random.PRNGKey(args.seed), tc), state_sh
        )

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        if args.fail_at and step == args.fail_at:
            print(f"simulated failure at step {step}", file=sys.stderr)
            return 17  # distinct exit code: "injected failure"
        batch = {k: np.asarray(v) for k, v in data.make_batch(step).items()}
        state, metrics = jitted(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            print(f"step {step:5d}  loss {loss:.4f}  lr {float(metrics['lr']):.2e}  "
                  f"acc {float(metrics.get('accuracy', 0.0)):.3f}  [{dt:.1f}s]", flush=True)
        if ckpt is not None and step > 0 and step % args.ckpt_every == 0:
            ckpt.save_async(step, state, {"loss": float(metrics["loss"])})
    if ckpt is not None:
        ckpt.wait()
        ckpt.save(args.steps, state)
    print(json.dumps({"final_loss": losses[-1] if losses else None,
                      "first_loss": losses[0] if losses else None,
                      "steps": args.steps, "seconds": round(time.time() - t0, 1)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
