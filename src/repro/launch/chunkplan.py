"""Chunk-dispatch / event-horizon planning shared by the HPO drivers.

The three ``launch.hpo`` drivers (batch, batch-with-device-rules, streaming)
all advance a population between *host events* — rung boundaries, budget ends,
the divergence/snapshot poll — and cover the gap with fused multi-step scans
whose sizes are power-of-two quantized so an experiment compiles at most
``log2(chunk_steps)+1`` scan programs.  That planning logic used to be
duplicated across the drivers; ``ChunkPlanner`` is its single home, so an
engine change (e.g. the elastic-regrid boundary decision) lands in exactly
one place.

The module-level functions are the primitive forms; the class packages the
per-flight constants (chunk size, poll cadence, rung boundaries).
"""
from __future__ import annotations

from typing import Sequence


def pow2_floor(n: int) -> int:
    """Largest power of two <= max(n, 1) — chunk sizes come from here, so an
    experiment compiles at most log2(chunk_steps)+1 fused-scan programs."""
    return 1 << (max(int(n), 1).bit_length() - 1)


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= max(n, 1) — device-rule history capacities
    and elastic-regrid lane counts come from here, so array shapes (and thus
    compiled programs) stay bounded as histories grow / populations shrink."""
    return 1 << (max(int(n), 1) - 1).bit_length()


def poll_anchor(s: int, cadence: int) -> int:
    """Next divergence/snapshot poll step strictly after ``s``: polls anchor
    to an ABSOLUTE cadence (the next multiple), not a window sliding with
    ``s`` — a sliding window recomputed every pass never comes due, which
    both starved the capped divergence poll at chunk_steps=1 and left
    snapshot harvests with no mid-flight event to run at."""
    return (s // cadence + 1) * cadence


def next_event_step(s: int, cadence: int, starts, budgets, live,
                    boundaries: Sequence[int] = ()) -> int:
    """The streaming engine's next host event at-or-after ``s``: the poll
    anchor, each live lane's budget end, and the next rung boundary each lane
    can still reach (``local < b <= budget`` — completers feed the rung
    history too).  An event due AT ``s`` (e.g. a freshly leased zero-budget
    job) returns ``s`` itself so the driver re-runs the event pass instead of
    burning a dispatch on steps nobody needs."""
    ev = poll_anchor(s, cadence)
    for lane in live:
        local = s - starts[lane]
        ev = min(ev, int(starts[lane] + budgets[lane]))
        for b in boundaries:
            if local < b <= budgets[lane]:
                ev = min(ev, int(starts[lane] + b))
                break
    return max(ev, int(s))


def device_dispatch_horizon(s: int, cadence: int, starts, budgets,
                            live) -> int:
    """--device-rules chunk horizon: rung boundaries and individual budget
    ends are handled INSIDE the scan, so the host only stops at the
    divergence/snapshot poll anchor or once every live lane's budget is over
    (the scan would be all no-ops past that)."""
    ev = poll_anchor(s, cadence)
    ends = [int(starts[lane] + budgets[lane]) for lane in live]
    if ends:
        ev = min(ev, max(ends))
    return max(ev, int(s))


class ChunkPlanner:
    """One flight's dispatch plan: where the next host event is, and how many
    fused steps to scan toward it.

    ``chunk_steps`` caps the fused-scan length (1 = the per-step loop,
    bit-for-bit); ``cadence`` is the divergence/snapshot poll cadence
    (defaults to ``max(8, chunk_steps)`` — chunk-granular, so big chunks are
    not split by the poll); ``boundaries`` are the rung rule's cut steps
    (lane-local for the streaming staggered rule, global for the batch cohort
    rule).
    """

    def __init__(self, chunk_steps: int = 1, cadence: int = 0,
                 boundaries: Sequence[int] = ()):
        self.chunk = max(1, int(chunk_steps))
        self.cadence = int(cadence) if cadence else max(8, self.chunk)
        self.boundaries = tuple(int(b) for b in boundaries)

    # -- event horizons ---------------------------------------------------------
    def next_cohort_event(self, s: int, max_budget: int) -> int:
        """Batch protocol: the first rung boundary in ``(s, max_budget]``,
        else the flight end — the step the cohort rule next fires at."""
        nxt = int(max_budget)
        for b in self.boundaries:
            if s < b <= max_budget:
                return min(nxt, b)
        return nxt

    def next_stream_event(self, s: int, starts, budgets, live) -> int:
        """Streaming protocol with host rules: see ``next_event_step``."""
        return next_event_step(s, self.cadence, starts, budgets, live,
                               self.boundaries)

    def device_horizon(self, s: int, starts, budgets, live) -> int:
        """Streaming protocol with in-scan rules: see
        ``device_dispatch_horizon``."""
        return device_dispatch_horizon(s, self.cadence, starts, budgets, live)

    # -- chunk sizing -----------------------------------------------------------
    def chunk_to(self, s: int, event: int) -> int:
        """Fused-scan length covering ``(s, event]``: power-of-two quantized,
        capped by ``chunk_steps``; 1 when chunking is off."""
        if self.chunk <= 1:
            return 1
        return pow2_floor(min(int(event) - int(s), self.chunk))
