"""HPO driver: the paper's Experiment loop over model-training trials.

This is Auptimizer's headline use-case on the training substrate: pick an
architecture (reduced config on CPU), define a search space over training
hyperparameters, and let any proposer drive trials through a resource
manager.  Switching HPO algorithms is exactly one flag (--proposer), the
paper's flexibility claim.

    PYTHONPATH=src python -m repro.launch.hpo --arch starcoder2-3b \\
        --proposer random --n-samples 8 --n-parallel 2 --steps 30

Each trial trains the smoke config for --steps on the deterministic
synthetic stream and reports -final_loss as the score.  All proposals and
results land in the tracking DB (--db) for post-hoc analysis / resume.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import numpy as np


def make_trial(arch: str, steps: int, batch: int, seq: int, seed: int):
    """A trial callable: config dict -> score (higher = better)."""

    def trial(config: dict) -> float:
        import jax

        from ..configs import get_smoke_config
        from ..configs.base import ParallelConfig, TrainConfig
        from ..data.pipeline import SyntheticLM
        from ..train.train_step import init_train_state, make_train_step

        cfg = get_smoke_config(arch)
        n_steps = int(config.get("n_iterations", 1) * steps)
        tc = TrainConfig(
            model=cfg,
            parallel=ParallelConfig(remat="none"),
            learning_rate=float(config["learning_rate"]),
            warmup_steps=max(1, int(config.get("warmup_frac", 0.1) * n_steps)),
            total_steps=n_steps,
            weight_decay=float(config.get("weight_decay", 0.1)),
            b2=float(config.get("b2", 0.95)),
            grad_clip=float(config.get("grad_clip", 1.0)),
            seed=seed,
        )
        data = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)
        state = init_train_state(jax.random.PRNGKey(seed), tc)
        step_fn = jax.jit(make_train_step(tc))
        loss = float("inf")
        for s in range(n_steps):
            state, metrics = step_fn(state, data.make_batch(s))
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                return -1e9  # diverged
        return -loss

    return trial


SPACE = [
    {"name": "learning_rate", "type": "float", "range": [1e-4, 3e-2], "scale": "log"},
    {"name": "warmup_frac", "type": "float", "range": [0.02, 0.5]},
    {"name": "weight_decay", "type": "float", "range": [0.0, 0.3]},
    {"name": "b2", "type": "float", "range": [0.9, 0.999]},
    {"name": "grad_clip", "type": "choice", "range": [0.5, 1.0, 2.0]},
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--proposer", default="random",
                   help="random | grid | gp | tpe | hyperband | bohb | asha | pbt")
    p.add_argument("--n-samples", type=int, default=8)
    p.add_argument("--n-parallel", type=int, default=2)
    p.add_argument("--steps", type=int, default=30, help="train steps per unit budget")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--db", default="", help="sqlite path ('' = in-memory)")
    p.add_argument("--deadline", type=float, default=0.0, help="per-job seconds (straggler kill)")
    args = p.parse_args(argv)

    from ..core.experiment import Experiment

    exp_cfg = {
        "proposer": args.proposer,
        "parameter_config": SPACE,
        "n_samples": args.n_samples,
        "n_parallel": args.n_parallel,
        "target": "max",
        "random_seed": args.seed,
        "resource": "local",
    }
    if args.db:
        exp_cfg["db_path"] = args.db
    if args.deadline:
        exp_cfg["job_deadline_s"] = args.deadline

    trial = make_trial(args.arch, args.steps, args.batch, args.seq, args.seed)
    t0 = time.time()
    exp = Experiment(exp_cfg, trial)
    best = exp.run()
    dt = time.time() - t0
    print(json.dumps({
        "proposer": args.proposer,
        "arch": args.arch,
        "best_score": best["score"],
        "best_config": {k: v for k, v in best["config"].items()
                        if not k.startswith(("hb_", "asha_", "pbt_")) and k != "job_id"},
        "n_jobs": best.get("n_jobs"),
        "seconds": round(dt, 1),
    }, default=float, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
