"""HPO driver: the paper's Experiment loop over model-training trials.

This is Auptimizer's headline use-case on the training substrate: pick an
architecture (reduced config on CPU), define a search space over training
hyperparameters, and let any proposer drive trials through a resource
manager.  Switching HPO algorithms is exactly one flag (--proposer), the
paper's flexibility claim.

    PYTHONPATH=src python -m repro.launch.hpo --arch starcoder2-3b \\
        --proposer random --n-samples 8 --n-parallel 2 --steps 30

Each trial trains the smoke config for --steps on the deterministic
synthetic stream and reports -final_loss as the score.  All proposals and
results land in the tracking DB (--db) for post-hoc analysis / resume.

**Execution engines** (the HParams-as-traced-input contract):

* default (``--vectorize 0``) — compile-once serial: per-trial hyperparameters
  (lr / weight_decay / b2 / grad_clip / warmup / total steps) ride in a traced
  ``HParams`` pytree, so all trials of the architecture share ONE compiled
  step (``repro.train.train_step.get_compiled_train_step``) instead of paying
  an XLA recompile each (the pre-refactor behavior survives as
  ``make_trial`` / ``--legacy-recompile`` for benchmarking);
* ``--vectorize K`` — population mode: K slots are presented to the loop by
  ``VectorizedResourceManager``, the proposer is drained in batches
  (``get_params``), and each batch trains as one ``jax.vmap``-ed jitted
  program (``repro.train.population``) with divergence masking — a NaN trial
  freezes and reports the sentinel score, the batch lives on.  Partial
  batches are padded to K (padding trials get a 0-step budget) so the whole
  experiment still compiles exactly once per (architecture, K);
* ``--vectorize K --shard-population`` — the K-trial population axis is
  additionally split over every local device (``shard_map`` on a 1-D
  population mesh via ``ShardedPopulationResourceManager``): K/N trials per
  device, still ONE compiled program, no cross-trial communication.

Population trials consume **independent per-trial data streams** by default:
each trial's stream id (its ``job_id``, or an explicit ``stream`` config key)
is folded into the batch PRNG, in serial and population modes alike — so the
engines stay score-equivalent trial-for-trial.  ``--shared-stream`` restores
the legacy behavior where every trial sees the same seeded sequence.

With ``--inflight-stop`` and a rung proposer (asha / hyperband / bohb), the
proposer's successive-halving rule also runs *inside* each population flight:
at every rung boundary, losing lanes get their traced step budget truncated
mid-flight, the flush returns as soon as the survivors finish, and the freed
lanes immediately take the next batch of proposals.

Vectorized/sharded mode is only valid when every proposal varies *traced*
knobs: all trials must share the architecture and batch geometry.  Per-trial
architecture params (d_model, n_layers, ... — e.g. the NAS/EAS space) change
the compiled program shape and MUST use serial mode.  Per-trial budgets
(``n_iterations`` from Hyperband/ASHA) are fine: ``hp.total_steps`` doubles
as a step budget and exhausted trials freeze in place.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def make_trial(arch: str, steps: int, batch: int, seq: int, seed: int):
    """Legacy trial callable: config dict -> score, recompiling per trial.

    Bakes the proposal into the TrainConfig closure, so every call pays a
    full XLA compile — kept as the baseline ``benchmarks/hpo_throughput.py``
    measures against.  Use ``PopulationTrial`` for real runs.
    """

    def trial(config: dict) -> float:
        import jax

        from ..configs import get_smoke_config
        from ..configs.base import ParallelConfig, TrainConfig
        from ..data.pipeline import SyntheticLM
        from ..train.train_step import init_train_state, make_train_step

        cfg = get_smoke_config(arch)
        n_steps = int(config.get("n_iterations", 1) * steps)
        tc = TrainConfig(
            model=cfg,
            parallel=ParallelConfig(remat="none"),
            learning_rate=float(config["learning_rate"]),
            warmup_steps=max(1, int(config.get("warmup_frac", 0.1) * n_steps)),
            total_steps=n_steps,
            weight_decay=float(config.get("weight_decay", 0.1)),
            b2=float(config.get("b2", 0.95)),
            grad_clip=float(config.get("grad_clip", 1.0)),
            seed=seed,
        )
        data = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)
        state = init_train_state(jax.random.PRNGKey(seed), tc)
        step_fn = jax.jit(make_train_step(tc))
        loss = float("inf")
        for s in range(n_steps):
            state, metrics = step_fn(state, data.make_batch(s))
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                return -1e9  # diverged
        return -loss

    return trial


class PopulationTrial:
    """Compile-once trial executor for one architecture.

    ``__call__(config)`` is the scalar protocol (local/subprocess managers);
    ``run_population(configs, mesh=None)`` is the batch protocol the
    vectorized/sharded managers use — K trials advance in one vmapped jitted
    program, split over ``mesh``'s population axis when one is given.  Either
    way the proposal's hyperparameters are *traced* inputs, so the experiment
    compiles once per (architecture, population size, mesh), not once per
    trial.

    ``per_trial_streams`` (default on) folds each trial's stream id — the
    ``stream`` config key, else its ``job_id``, else its lane position — into
    the batch PRNG, in the scalar and batch protocols alike, so every trial
    trains on its own independent data sequence and the engines remain
    score-equivalent trial-for-trial.

    ``early_stop`` may hold an in-flight hook (see
    ``repro.core.proposer.early_stop``): between population steps, at the
    hook's rung boundaries, losing lanes get their traced step budget
    truncated so the flight ends as soon as the surviving lanes finish.
    """

    DIVERGED_SCORE = -1e9

    def __init__(self, arch: str, steps: int, batch: int, seq: int, seed: int,
                 population: int = 0, per_trial_streams: bool = True,
                 early_stop=None):
        self.arch = arch
        self.steps = int(steps)
        self.batch = int(batch)
        self.seq = int(seq)
        self.seed = int(seed)
        self.population = int(population)  # >0: pad batches to this fixed K
        self.per_trial_streams = bool(per_trial_streams)
        self.early_stop = early_stop
        self._tc = None
        self._data = None
        import threading

        self._setup_lock = threading.Lock()

    # lazy so the Experiment can be constructed without importing jax; locked
    # because local resource managers call trials from worker threads
    def _setup(self):
        with self._setup_lock:
            if self._tc is None:
                from ..configs import get_smoke_config
                from ..configs.base import ParallelConfig, TrainConfig
                from ..data.pipeline import SyntheticLM

                cfg = get_smoke_config(self.arch)
                self._data = SyntheticLM(cfg.vocab_size, self.seq, self.batch,
                                         seed=self.seed)
                self._tc = TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                                       seed=self.seed)
            return self._tc, self._data

    def _hparams(self, config: dict, n_steps: int):
        from ..optim.hparams import hparams_from_dict

        tc, _ = self._setup()
        return hparams_from_dict(dict(config, total_steps=n_steps), tc)

    def _n_steps(self, config: dict) -> int:
        return int(config.get("n_iterations", 1) * self.steps)

    def _stream_of(self, config: dict, fallback: int) -> int:
        """Per-trial data stream id: explicit ``stream`` key, else the job id
        (stable across serial vs population engines for the same proposal),
        else ``fallback`` (lane position / 0)."""
        if not self.per_trial_streams:
            return 0
        return int(config.get("stream", config.get("job_id", fallback)))

    def __call__(self, config: dict) -> float:
        """Serial protocol, sharing the process-wide compiled step."""
        import jax

        from ..train.train_step import get_compiled_train_step, init_train_state

        tc, data = self._setup()
        n_steps = self._n_steps(config)
        stream = self._stream_of(config, 0)
        hp = self._hparams(config, n_steps)
        step_fn = get_compiled_train_step(tc)
        state = init_train_state(jax.random.PRNGKey(self.seed), tc)
        loss = float("inf")
        for s in range(n_steps):
            state, metrics = step_fn(state, data.make_batch(s, stream=stream), hp)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                return self.DIVERGED_SCORE
        return -loss

    def run_population(self, configs, mesh=None) -> list:
        """Batch protocol: K trials in one vmapped (optionally sharded) device
        program.  With ``mesh`` the population axis splits over its devices;
        K is padded so it divides evenly (padding lanes get a 0-step budget).
        """
        import dataclasses

        import jax
        import jax.numpy as jnp

        from ..optim.hparams import stack_hparams
        from ..train.population import (
            get_compiled_population_step,
            get_compiled_sharded_population_step,
            init_population_state,
            pad_population,
            population_scores,
            shard_population_state,
        )

        tc, data = self._setup()
        budgets = np.array([float(self._n_steps(c)) for c in configs])
        streams = [self._stream_of(c, i) for i, c in enumerate(configs)]
        hps = [self._hparams(c, int(n)) for c, n in zip(configs, budgets)]
        k = pad_population(max(self.population, len(hps)), mesh)
        # pad partial batches to the fixed population size with 0-budget
        # trials (they freeze immediately) so K — and thus the compiled
        # program — never varies across batches
        while len(hps) < k:
            hps.append(self._hparams({}, 0))
        streams += [0] * (k - len(streams))
        budgets = np.concatenate([budgets, np.zeros(k - len(budgets))])
        php = stack_hparams(hps)
        if mesh is not None:
            pstep = get_compiled_sharded_population_step(
                tc, k, mesh=mesh, per_trial_batch=self.per_trial_streams)
        else:
            pstep = get_compiled_population_step(
                tc, k, per_trial_batch=self.per_trial_streams)
        pstate = init_population_state(jax.random.PRNGKey(self.seed), tc, k)
        if mesh is not None:
            pstate = shard_population_state(pstate, mesh)
        hook = self.early_stop
        s = 0
        while s < int(budgets.max()):
            if self.per_trial_streams:
                batch = data.make_population_batch(s, streams)
            else:
                batch = data.make_batch(s)
            pstate, _ = pstep(pstate, batch, php)
            s += 1
            if hook is not None and s in hook.boundaries:
                new_budgets = hook(
                    s,
                    np.asarray(pstate["last_loss"]),
                    budgets,
                    np.asarray(pstate["diverged"]),
                )
                if (new_budgets != budgets).any():
                    # the budget is a *traced* leaf: truncating it freezes the
                    # losing lanes on the next step without a recompile
                    budgets = new_budgets
                    php = dataclasses.replace(
                        php, total_steps=jnp.asarray(budgets, jnp.float32))
        # telemetry: how long the flight actually ran (in-flight stops shrink it)
        self.last_flight_steps = s
        scores = np.asarray(population_scores(pstate, self.DIVERGED_SCORE))
        return [float(x) for x in scores[: len(configs)]]


SPACE = [
    {"name": "learning_rate", "type": "float", "range": [1e-4, 3e-2], "scale": "log"},
    {"name": "warmup_frac", "type": "float", "range": [0.02, 0.5]},
    {"name": "weight_decay", "type": "float", "range": [0.0, 0.3]},
    {"name": "b2", "type": "float", "range": [0.9, 0.999]},
    {"name": "grad_clip", "type": "choice", "range": [0.5, 1.0, 2.0]},
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--proposer", default="random",
                   help="random | grid | gp | tpe | hyperband | bohb | asha | pbt")
    p.add_argument("--n-samples", type=int, default=8)
    p.add_argument("--n-parallel", type=int, default=2)
    p.add_argument("--steps", type=int, default=30, help="train steps per unit budget")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--db", default="", help="sqlite path ('' = in-memory)")
    p.add_argument("--deadline", type=float, default=0.0, help="per-job seconds (straggler kill)")
    p.add_argument("--vectorize", type=int, default=0, metavar="K",
                   help="train K trials as one vmapped program (0 = serial compile-once)")
    p.add_argument("--shard-population", action="store_true",
                   help="with --vectorize: split the K-trial population axis over "
                        "all local devices (shard_map; K is padded to a multiple "
                        "of the device count)")
    p.add_argument("--shared-stream", action="store_true",
                   help="legacy data mode: every trial consumes the same seeded "
                        "batch stream (default: independent per-trial streams)")
    p.add_argument("--inflight-stop", action="store_true",
                   help="with --vectorize and asha/hyperband/bohb: apply the "
                        "rung rule mid-flight, truncating losing lanes' budgets "
                        "so they free up before the batch ends")
    p.add_argument("--legacy-recompile", action="store_true",
                   help="pre-refactor baseline: bake hparams into the closure, recompile per trial")
    args = p.parse_args(argv)

    from ..core.experiment import Experiment

    exp_cfg = {
        "proposer": args.proposer,
        "parameter_config": SPACE,
        "n_samples": args.n_samples,
        "n_parallel": args.n_parallel,
        "target": "max",
        "random_seed": args.seed,
        "resource": "local",
    }
    if args.db:
        exp_cfg["db_path"] = args.db
    if args.deadline:
        exp_cfg["job_deadline_s"] = args.deadline

    if args.vectorize <= 0 and (args.shard_population or args.inflight_stop):
        p.error("--shard-population/--inflight-stop require --vectorize K "
                "(they act on the population engines)")
    per_trial_streams = not args.shared_stream
    if args.vectorize > 0:
        exp_cfg["resource"] = "sharded" if args.shard_population else "vectorized"
        exp_cfg["n_parallel"] = args.vectorize
        trial = PopulationTrial(args.arch, args.steps, args.batch, args.seq,
                                args.seed, population=args.vectorize,
                                per_trial_streams=per_trial_streams)
    elif args.legacy_recompile:
        trial = make_trial(args.arch, args.steps, args.batch, args.seq, args.seed)
    else:
        trial = PopulationTrial(args.arch, args.steps, args.batch, args.seq,
                                args.seed, per_trial_streams=per_trial_streams)
    t0 = time.time()
    exp = Experiment(exp_cfg, trial)
    if args.inflight_stop:
        hook_factory = getattr(exp.proposer, "inflight_hook", None)
        if hook_factory is None:
            p.error(f"--inflight-stop needs a rung proposer (asha/hyperband/bohb), "
                    f"got {args.proposer!r}")
        trial.early_stop = hook_factory(steps_per_unit=args.steps)
    best = exp.run()
    dt = time.time() - t0
    engine = ("legacy-recompile" if args.legacy_recompile else
              "serial" if args.vectorize == 0 else
              "sharded" if args.shard_population else "vmapped")
    out = {
        "proposer": args.proposer,
        "arch": args.arch,
        "engine": engine,
        "vectorize": args.vectorize,
    }
    if getattr(trial, "early_stop", None) is not None:
        out["inflight_truncated_lanes"] = trial.early_stop.n_truncated
        out["inflight_reclaimed_diverged_lanes"] = trial.early_stop.n_reclaimed
    print(json.dumps(dict(out, **{
        "best_score": best["score"],
        "best_config": {k: v for k, v in best["config"].items()
                        if not k.startswith(("hb_", "asha_", "pbt_")) and k != "job_id"},
        "n_jobs": best.get("n_jobs"),
        "seconds": round(dt, 1),
    }), default=float, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
