"""HPO driver: the paper's Experiment loop over model-training trials.

This is Auptimizer's headline use-case on the training substrate: pick an
architecture (reduced config on CPU), define a search space over training
hyperparameters, and let any proposer drive trials through a resource
manager.  Switching HPO algorithms is exactly one flag (--proposer), the
paper's flexibility claim.

    PYTHONPATH=src python -m repro.launch.hpo --arch starcoder2-3b \\
        --proposer random --n-samples 8 --n-parallel 2 --steps 30

Each trial trains the smoke config for --steps on the deterministic
synthetic stream and reports -final_loss as the score.  All proposals and
results land in the tracking DB (--db) for post-hoc analysis / resume.

**Execution engines** (the HParams-as-traced-input contract):

* default (``--vectorize 0``) — compile-once serial: per-trial hyperparameters
  (lr / weight_decay / b2 / grad_clip / warmup / total steps) ride in a traced
  ``HParams`` pytree, so all trials of the architecture share ONE compiled
  step (``repro.train.train_step.get_compiled_train_step``) instead of paying
  an XLA recompile each (the pre-refactor behavior survives as
  ``make_trial`` / ``--legacy-recompile`` for benchmarking);
* ``--vectorize K`` — population mode: K slots are presented to the loop by
  ``VectorizedResourceManager``, the proposer is drained in batches
  (``get_params``), and each batch trains as one ``jax.vmap``-ed jitted
  program (``repro.train.population``) with divergence masking — a NaN trial
  freezes and reports the sentinel score, the batch lives on.  Partial
  batches are padded to K (padding trials get a 0-step budget) so the whole
  experiment still compiles exactly once per (architecture, K);
* ``--vectorize K --shard-population`` — the K-trial population axis is
  additionally split over every local device (``shard_map`` on a 1-D
  population mesh via ``ShardedPopulationResourceManager``): K/N trials per
  device, still ONE compiled program, no cross-trial communication.

Population trials consume **independent per-trial data streams** by default:
each trial's stream id (its ``job_id``, or an explicit ``stream`` config key)
is folded into the batch PRNG, in serial and population modes alike — so the
engines stay score-equivalent trial-for-trial.  ``--shared-stream`` restores
the legacy behavior where every trial sees the same seeded sequence.

With ``--inflight-stop`` and a rung proposer (asha / hyperband / bohb), the
proposer's successive-halving rule also runs *inside* each population flight:
at every rung boundary, losing lanes get their traced step budget truncated
mid-flight, the flush returns as soon as the survivors finish, and the freed
lanes immediately take the next batch of proposals.

``--lane-refill`` goes further: the flight never has to end for a freed lane
to be reused.  A retired lane (budget exhausted, rung-truncated, or diverged)
streams its result out immediately and is reset *in place* — a traced
per-lane mask re-inits its weights inside the compiled program — so the next
proposal starts training while the rest of the population keeps running.
This is Auptimizer Algorithm 1's every-resource-busy invariant enforced down
to individual population lanes: one continuous flight per experiment instead
of batch-synchronous flushes.  ``--per-trial-init`` additionally gives every
trial its own init weights (stream id folded into the init key, identically
in serial and population modes).

``--pbt-streaming`` puts Population-Based Training on the same streaming
engine (implies ``--lane-refill``): each PBT member owns a lane, trains one
round per job, and its next job carries a lane-lifecycle directive — ``keep``
(continue in place, no device op) or ``clone`` (the lane inherits a donor
lane's weights AND optimizer state through the compiled ``make_lane_clone``
op).  Exploit/explore runs as a quantile rule over a sliding member-score
window; by default rounds are gated so decisions match the generation-
barriered serial driver (``run_pbt_serial``) decision-for-decision, while
``--pbt-async`` unlocks the fully staggered rule.  Either way the weights
never visit the host — no ``pbt_ckpt`` checkpoint round-trip, no generation
bubble (``pbt_host_ckpt_roundtrips`` stays 0 in the CLI telemetry).

``--chunk-steps T`` fuses the innermost loop itself: instead of one host
dispatch (and one host-built batch) per population step, the engines scan up
to T steps inside one compiled program, synthesizing each step's batches *on
device* from the per-lane stream ids and a traced step counter
(``repro.data.pipeline.synth_batch`` runs bit-identically under NumPy and
XLA).  Chunk boundaries always land on host-known event steps — rung
boundaries, retirements, PBT round ends — and the divergence poll becomes
chunk-granular, so ``--chunk-steps 1`` reproduces the per-step loop
bit-for-bit while larger T trades divergence-reclaim latency for a ~T-fold
cut in host dispatches.

Vectorized/sharded mode is only valid when every proposal varies *traced*
knobs: all trials must share the architecture and batch geometry.  Per-trial
architecture params (d_model, n_layers, ... — e.g. the NAS/EAS space) change
the compiled program shape and MUST use serial mode.  Per-trial budgets
(``n_iterations`` from Hyperband/ASHA) are fine: ``hp.total_steps`` doubles
as a step budget and exhausted trials freeze in place.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from .chunkplan import (
    ChunkPlanner,
    device_dispatch_horizon as _device_dispatch_horizon,
    next_event_step as _next_event_step,
    poll_anchor as _poll_anchor,
    pow2_ceil as _pow2_ceil,
    pow2_floor as _pow2_floor,
)


def make_trial(arch: str, steps: int, batch: int, seq: int, seed: int):
    """Legacy trial callable: config dict -> score, recompiling per trial.

    Bakes the proposal into the TrainConfig closure, so every call pays a
    full XLA compile — kept as the baseline ``benchmarks/hpo_throughput.py``
    measures against.  Use ``PopulationTrial`` for real runs.
    """

    def trial(config: dict) -> float:
        import jax

        from ..configs import get_smoke_config
        from ..configs.base import ParallelConfig, TrainConfig
        from ..data.pipeline import HostPrefetcher, SyntheticLM
        from ..train.train_step import init_train_state, make_train_step

        cfg = get_smoke_config(arch)
        n_steps = int(config.get("n_iterations", 1) * steps)
        tc = TrainConfig(
            model=cfg,
            parallel=ParallelConfig(remat="none"),
            learning_rate=float(config["learning_rate"]),
            warmup_steps=max(1, int(config.get("warmup_frac", 0.1) * n_steps)),
            total_steps=n_steps,
            weight_decay=float(config.get("weight_decay", 0.1)),
            b2=float(config.get("b2", 0.95)),
            grad_clip=float(config.get("grad_clip", 1.0)),
            seed=seed,
        )
        data = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)
        state = init_train_state(jax.random.PRNGKey(seed), tc)
        step_fn = jax.jit(make_train_step(tc))
        # prefetch-ahead host feed: batch s+1 is built and device_put while
        # the (async-dispatched) step s still runs, BEFORE the blocking loss
        # read — same bytes as the direct make_batch path, less device idle
        feed = HostPrefetcher(data.make_batch)
        loss = float("inf")
        for s in range(n_steps):
            state, metrics = step_fn(state, feed.pop(s))
            if s + 1 < n_steps:
                feed.prefetch(s + 1)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                return -1e9  # diverged
        return -loss

    return trial


class PopulationTrial:
    """Compile-once trial executor for one architecture.

    ``__call__(config)`` is the scalar protocol (local/subprocess managers);
    ``run_population(configs, mesh=None)`` is the batch protocol the
    vectorized/sharded managers use — K trials advance in one vmapped jitted
    program, split over ``mesh``'s population axis when one is given.  Either
    way the proposal's hyperparameters are *traced* inputs, so the experiment
    compiles once per (architecture, population size, mesh), not once per
    trial.

    ``per_trial_streams`` (default on) folds each trial's stream id — the
    ``stream`` config key, else its ``job_id``, else its lane position — into
    the batch PRNG, in the scalar and batch protocols alike, so every trial
    trains on its own independent data sequence and the engines remain
    score-equivalent trial-for-trial.

    ``early_stop`` may hold an in-flight hook (see
    ``repro.core.proposer.early_stop``): between population steps, at the
    hook's rung boundaries, losing lanes get their traced step budget
    truncated so the flight ends as soon as the surviving lanes finish.

    ``per_trial_init`` folds each trial's stream id into its *init* PRNG key
    as well, so every trial starts from its own weights — in serial and
    population modes alike (the engines stay score-equivalent).  Default off:
    the legacy behavior inits every trial from ``PRNGKey(seed)``.

    ``run_population(configs=[], scheduler=...)`` is the **streaming** (lane
    refill) protocol: instead of a positional batch, the engine leases jobs
    from the scheduler into freed lanes mid-flight (resetting the lane's
    train state inside the compiled program) and streams each job's result
    back the moment its lane retires.  See ``_run_streaming``.
    """

    DIVERGED_SCORE = -1e9

    def __init__(self, arch: str, steps: int, batch: int, seq: int, seed: int,
                 population: int = 0, per_trial_streams: bool = True,
                 early_stop=None, per_trial_init: bool = False,
                 refill_idle_grace_s: float = 0.25, lifecycle=None,
                 chunk_steps: int = 1, snapshot_every: int = 0,
                 snapshots=None, device_rules: bool = False,
                 elastic_regrid: bool = False, data_ring: bool = False,
                 ring_windows: int = 2, fused_rmsnorm: bool = False,
                 fused_attention: bool = False, fused_ssm: bool = False,
                 model_parallel: int = 1, model_overrides=None):
        self.arch = arch
        self.steps = int(steps)
        self.batch = int(batch)
        self.seq = int(seq)
        self.seed = int(seed)
        self.population = int(population)  # >0: pad batches to this fixed K
        self.per_trial_streams = bool(per_trial_streams)
        self.per_trial_init = bool(per_trial_init)
        self.early_stop = early_stop
        # fused multi-step dispatch: population engines advance up to this
        # many steps per device call (a lax.scan with on-device batch
        # synthesis), re-entering the host only at event steps.  1 = the
        # per-step loop, bit-for-bit.
        self.chunk_steps = max(1, int(chunk_steps))
        # --device-rules: evaluate the rung rule / PBT window quantile INSIDE
        # the fused scan (rule state carried by lax.scan), so chunk boundaries
        # no longer clamp to event-step gaps and the host only harvests
        # retirements from the scan's emitted event log
        self.device_rules = bool(device_rules)
        # --elastic-regrid: at rung boundaries (batch) / once the feed drains
        # (streaming), gather the surviving lanes into a smaller population
        # and re-lay it out over the freed devices (two-level (pop, model)
        # mesh when a lane pool is attached; plain lane-count shrink on the
        # single-device vmapped engine).  Resharding changes layout, never
        # math: scores reproduce the fixed-width run.
        self.elastic_regrid = bool(elastic_regrid)
        # --data-ring: feed the fused scan from a device-resident prefetch
        # ring host-filled ahead of the consumer (repro.data.ring) instead of
        # in-scan synthesis — the path real datasets take into the chunked
        # engine.  The synth-backed host adapter reproduces the in-scan
        # engine bit-for-bit.
        self.data_ring = bool(data_ring)
        self.ring_windows = max(2, int(ring_windows))
        self.host_dataset = None    # HostDataset override (default: synth)
        self.ring_fill_wait_s = 0.0   # device time spent waiting on host fill
        self.ring_fill_busy_s = 0.0   # host time spent producing windows
        self.ring_overlap_frac = 1.0  # fraction of fill hidden behind compute
        self.n_ring_fills = 0
        self.n_ring_invalidations = 0
        # --fused-rmsnorm: run the Pallas rmsnorm kernel (interpret mode off
        # TPU) inside the population train step instead of the reference norm
        self.fused_rmsnorm = bool(fused_rmsnorm)
        # --fused-attention / --fused-ssm: the rest of the Pallas kernel bank
        # (flash attention, chunked selective scan), same static-field keying
        self.fused_attention = bool(fused_attention)
        self.fused_ssm = bool(fused_ssm)
        # --model-parallel W: each lane's tensors split over a W-wide model
        # axis (two-level (pop, model) mesh) — width is layout, never math
        self.model_parallel = max(1, int(model_parallel))
        # static ModelConfig field replacements applied on top of the smoke
        # config (e.g. a head geometry whose dims divide a TP width) — part
        # of the compile-cache key like every other static model field
        self.model_overrides = dict(model_overrides or {})
        # wall-clock per train step between consecutive rung boundaries,
        # [[boundary_step, steps, s_per_step], ...] — the elastic/TP speedup
        # telemetry: later rungs should get *cheaper* per step
        self.per_rung_step_time_s: list = []
        self.model_axis_collectives = None  # per-step model-axis all-reduces
        self.n_regrids = 0          # lane-geometry changes executed
        self.lane_width_history: list = []  # [lanes, devices-per-lane] per regrid
        self.n_dispatches = 0       # device calls issued (steps + lane ops)
        self.n_train_steps = 0      # population steps those calls advanced
        # lane-lifecycle hook (streaming PBT): maps retire->refill directives
        # (keep / clone / init) onto compiled lane ops; wired by the
        # Experiment from the proposer's lifecycle_hook()
        self.lifecycle = lifecycle
        # how long an empty streaming flight lingers for late proposals before
        # returning its lanes (0 for self-contained feeds, e.g. benchmarks)
        self.refill_idle_grace_s = float(refill_idle_grace_s)
        # crash-safe streaming: harvest each live lane's full train state to
        # the snapshot store every N-th event boundary (0 = off); a lease
        # whose stream has a stored snapshot restores from it instead of
        # starting at step 0 (after a supervised restart or a --resume)
        self.snapshot_every = max(0, int(snapshot_every))
        self.snapshots = snapshots      # checkpoint.LaneSnapshotStore
        self.journal = None             # tracking.FlightJournal, wired by Experiment
        self.n_snapshots = 0            # lane snapshots harvested to host
        self.n_lane_restores = 0        # leases resumed from a snapshot
        self.resumed_from_steps: list = []  # lane-local step of each restore
        self._event_seq = 0             # streaming event boundaries, all flights
        # device dispatches from first-flight start to the first retirement
        # harvest — "the ladder": with --device-rules a whole multi-rung
        # cohort collapses to 1 (the headline claim CI gates on); host-rule
        # paths pay the init op plus one dispatch per event gap
        self.ladder_dispatches = None
        self.n_refills = 0          # lanes reused within a streaming flight
        self.n_clones = 0           # donor-clone lane ops executed on device
        self.n_splices = 0          # single-lane splice inits executed
        self.n_donor_waits = 0      # leases parked waiting on a busy donor lane
        self.n_lineage_resets = 0   # keep/clone downgraded to init (state lost)
        self.n_host_ckpt_roundtrips = 0  # weights ever pulled to host (serial PBT only)
        self._flight_epoch = 0
        self._tc = None
        self._data = None
        self._serial_seq = 0  # fallback stream counter for anonymous configs
        import threading

        self._setup_lock = threading.Lock()

    # lazy so the Experiment can be constructed without importing jax; locked
    # because local resource managers call trials from worker threads
    def _setup(self):
        with self._setup_lock:
            if self._tc is None:
                from ..configs import get_smoke_config
                from ..configs.base import ParallelConfig, TrainConfig
                from ..data.pipeline import SyntheticLM

                import dataclasses

                cfg = get_smoke_config(self.arch)
                if self.fused_rmsnorm:
                    # a *static* model field: the compile caches key on it via
                    # static_step_key, so fused and reference programs never mix
                    cfg = dataclasses.replace(cfg, fused_rmsnorm=True)
                if self.fused_attention:
                    cfg = dataclasses.replace(cfg, fused_attention=True)
                if self.fused_ssm:
                    cfg = dataclasses.replace(cfg, fused_ssm=True)
                if self.model_overrides:
                    cfg = dataclasses.replace(cfg, **self.model_overrides)
                self._data = SyntheticLM(cfg.vocab_size, self.seq, self.batch,
                                         seed=self.seed)
                self._tc = TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                                       seed=self.seed)
            return self._tc, self._data

    def _hparams(self, config: dict, n_steps: int):
        from ..optim.hparams import hparams_from_dict

        tc, _ = self._setup()
        return hparams_from_dict(dict(config, total_steps=n_steps), tc)

    def _n_steps(self, config: dict) -> int:
        return int(config.get("n_iterations", 1) * self.steps)

    def _stream_of(self, config: dict, fallback: int) -> int:
        """Per-trial data stream id: explicit ``stream`` key, else the job id
        (stable across serial vs population engines for the same proposal),
        else ``fallback`` (lane position / serial call order)."""
        if not self.per_trial_streams:
            return 0
        return int(config.get("stream", config.get("job_id", fallback)))

    def _serial_stream_of(self, config: dict) -> int:
        """Stream id for a serial call or a streaming lease.  Anonymous
        configs — no ``stream`` and no ``job_id`` — get distinct streams by
        call/lease order instead of all colliding on stream 0 (or on a reused
        lane's index), which silently re-shared data across trials despite
        ``per_trial_streams=True``."""
        if not self.per_trial_streams:
            return 0
        if "stream" in config or "job_id" in config:
            return self._stream_of(config, 0)
        with self._setup_lock:
            sid = self._serial_seq
            self._serial_seq += 1
        return sid

    def _init_key(self, stream: int):
        """Init PRNG key for a trial: the shared ``PRNGKey(seed)`` by default,
        or — with ``per_trial_init`` — the trial's stream id folded in, so the
        serial driver and every population engine derive the *same* per-trial
        weights (masked to uint32: sentinel streams are negative)."""
        import jax

        base = jax.random.PRNGKey(self.seed)
        if not self.per_trial_init:
            return base
        return jax.random.fold_in(base, int(stream) & 0xFFFFFFFF)

    def _make_ring(self, data, k: int, chunk: int, mesh=None):
        """Build the device-resident prefetch ring for a flight
        (``--data-ring``): ``ring_windows`` chunk-windows of per-lane token
        slabs, host-filled from ``host_dataset`` (default: the synth adapter
        — the bit-equality oracle for the in-scan engine).  On a mesh the
        lane axis shards over ``pop`` so each device holds only its own
        lanes' slabs."""
        from ..data.pipeline import SynthHostDataset
        from ..data.ring import PrefetchRing

        sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            sharding = NamedSharding(
                mesh, PartitionSpec(None, "pop", None, None))
        ds = self.host_dataset if self.host_dataset is not None \
            else SynthHostDataset(data)
        return PrefetchRing(ds, population=k, win_steps=chunk,
                            windows=self.ring_windows, sharding=sharding)

    def _absorb_ring(self, ring) -> None:
        """Stop a flight's ring and roll its telemetry into the trial."""
        ring.stop()
        self.ring_fill_wait_s += ring.fill_wait_s
        self.ring_fill_busy_s += ring.fill_busy_s
        self.n_ring_fills += ring.n_fills
        self.n_ring_invalidations += ring.n_invalidations
        if self.ring_fill_busy_s > 0.0:
            self.ring_overlap_frac = max(0.0, min(
                1.0, 1.0 - self.ring_fill_wait_s / self.ring_fill_busy_s))

    def __call__(self, config: dict) -> float:
        """Serial protocol, sharing the process-wide compiled step."""
        return self.serial_score_at(config, None)

    def serial_score_at(self, config: dict, steps=None) -> float:
        """Serial driver score measured after ``steps`` applied steps (default:
        the config's full budget).  The LR schedule still spans the config's
        own total budget — so ``steps < budget`` reproduces exactly what a
        rung-truncated population lane reports: the ordinary trajectory, cut
        at the truncation step."""
        from ..data.pipeline import HostPrefetcher
        from ..train.train_step import get_compiled_train_step, init_train_state

        tc, data = self._setup()
        n_steps = self._n_steps(config)
        run_steps = n_steps if steps is None else min(int(steps), n_steps)
        stream = self._serial_stream_of(config)
        hp = self._hparams(config, n_steps)
        step_fn = get_compiled_train_step(tc)
        state = init_train_state(self._init_key(stream), tc)
        feed = HostPrefetcher(lambda t: data.make_batch(t, stream=stream))
        loss = float("inf")
        for s in range(run_steps):
            state, metrics = step_fn(state, feed.pop(s), hp)
            if s + 1 < run_steps:
                feed.prefetch(s + 1)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                return self.DIVERGED_SCORE
        return -loss

    def run_population(self, configs, mesh=None, scheduler=None,
                       elastic=None) -> list:
        """Batch protocol: K trials in one vmapped (optionally sharded) device
        program.  With ``mesh`` the population axis splits over its devices;
        K is padded so it divides evenly (padding lanes get a 0-step budget).
        With ``scheduler`` the call switches to the streaming lane-refill
        protocol (``configs`` must be empty — jobs arrive via ``lease()`` and
        results leave via ``complete()``).  ``elastic`` is the sharded
        manager's ``ElasticLanePool`` (``--elastic-regrid``): rung survivors
        regrid onto wider lanes through its scale-out/in lease protocol.
        """
        import dataclasses

        import jax
        import jax.numpy as jnp

        from ..data.pipeline import split_stream, split_streams
        from ..optim.hparams import stack_hparams
        from ..train.population import (
            get_compiled_population_scan_step,
            get_compiled_population_step,
            get_compiled_sharded_population_step,
            init_population_state,
            init_population_state_from_keys,
            pad_population,
            population_scores,
            shard_population_state,
        )

        if scheduler is not None:
            if configs:
                raise ValueError(
                    "streaming mode: seed proposals through the scheduler, not configs"
                )
            return self._run_streaming(mesh, scheduler, elastic=elastic)

        tc, data = self._setup()
        budgets = np.array([float(self._n_steps(c)) for c in configs])
        streams = [self._stream_of(c, i) for i, c in enumerate(configs)]
        hps = [self._hparams(c, int(n)) for c, n in zip(configs, budgets)]
        k = pad_population(max(self.population, len(hps)), mesh)
        # pad partial batches to the fixed population size with 0-budget
        # trials (they freeze immediately) so K — and thus the compiled
        # program — never varies across batches; padding lanes get distinct
        # negative *sentinel* streams instead of all duplicating stream 0
        while len(hps) < k:
            hps.append(self._hparams({}, 0))
        streams += [-(i + 1) for i in range(len(streams), k)]
        budgets = np.concatenate([budgets, np.zeros(k - len(budgets))])
        php = stack_hparams(hps)
        elastic_on = elastic is not None or self.elastic_regrid
        if elastic_on and self.device_rules:
            raise ValueError(
                "--elastic-regrid and --device-rules are mutually exclusive: "
                "in-scan rule state is K-shaped, a regrid changes K mid-flight")
        if self.per_trial_init:
            keys = jnp.stack([self._init_key(s) for s in streams])
            pstate = init_population_state_from_keys(keys, tc)
        else:
            pstate = init_population_state(jax.random.PRNGKey(self.seed), tc, k)
        if elastic_on:
            scores = self._run_batch_elastic(
                tc, data, k, pstate, php, budgets, streams, hps,
                self.early_stop, elastic)
            return scores[: len(configs)]
        if mesh is not None:
            pstep = get_compiled_sharded_population_step(
                tc, k, mesh=mesh, per_trial_batch=self.per_trial_streams)
        else:
            pstep = get_compiled_population_step(
                tc, k, per_trial_batch=self.per_trial_streams)
        if mesh is not None:
            # tc routes width>1 meshes through the two-level placement so
            # width-sharded leaves land partitioned, not replicated
            pstate = shard_population_state(pstate, mesh, tc=tc)
        hook = self.early_stop
        if self.device_rules and hook is not None and hook.boundaries:
            scores = self._run_batch_device_rules(
                tc, data, k, mesh, pstate, php, budgets, streams, hook)
            return scores[: len(configs)]
        chunk = self.chunk_steps
        ring = None
        if chunk > 1:
            # fused dispatch: chunk boundaries align with the host-known event
            # steps (rung boundaries, flight end), so the rung rule below sees
            # exactly the state the per-step loop would at the same step
            if self.per_trial_streams:
                s_lo, s_hi = (jnp.asarray(w) for w in split_streams(streams))
            else:
                s_lo, s_hi = (jnp.uint32(w) for w in split_stream(0))

            def scan_of(t):
                return get_compiled_population_scan_step(
                    tc, k, data, t, mesh=mesh,
                    per_trial_batch=self.per_trial_streams)

            if self.data_ring:
                from ..train.population import \
                    get_compiled_population_ring_scan_step

                # every lane's data cursor IS the global step in the batch
                # protocol, so offsets are zero and lanes never re-key
                ring = self._make_ring(data, k, chunk, mesh=mesh)
                ring.set_lanes(streams, [0] * k, at_step=0)

                def ring_scan_of(t):
                    return get_compiled_population_ring_scan_step(
                        tc, k, data, t, ring.capacity, mesh=mesh)

        planner = ChunkPlanner(
            chunk_steps=chunk,
            boundaries=hook.boundaries if hook is not None else ())
        s = 0
        seg_t0, seg_s0 = time.perf_counter(), 0
        try:
            while s < int(budgets.max()):
                max_b = int(budgets.max())
                event = planner.next_cohort_event(s, max_b)
                t = planner.chunk_to(s, event)
                if ring is not None:
                    # chunk horizons stay capped to filled windows: block here
                    # until the host has staged exactly this chunk on device
                    # (counted as ring_fill_wait_s), so the dispatch sequence
                    # is identical to the in-scan engine's
                    ring.wait_filled(s, t)
                if t > 1 and ring is not None:
                    with ring.reserve() as slots:
                        pstate, _ = ring_scan_of(t)(
                            pstate, php, slots,
                            jnp.asarray(s % ring.capacity, jnp.int32))
                elif t > 1:
                    steps0 = (jnp.full((k,), s, jnp.int32)
                              if self.per_trial_streams
                              else jnp.asarray(s, jnp.int32))
                    pstate, _ = scan_of(t)(pstate, php, steps0, s_lo, s_hi)
                else:
                    if self.per_trial_streams:
                        batch = data.make_population_batch(s, streams)
                    else:
                        batch = data.make_batch(s)
                    pstate, _ = pstep(pstate, batch, php)
                self.n_dispatches += 1
                self.n_train_steps += t
                s += t
                if ring is not None:
                    ring.consume_to(s)
                if hook is not None and s in hook.boundaries:
                    new_budgets = hook(
                        s,
                        np.asarray(pstate["last_loss"]),
                        budgets,
                        np.asarray(pstate["diverged"]),
                    )
                    # the last_loss pull above synced the device, so this
                    # segment's wall-clock is honest: per-step time between
                    # consecutive rung boundaries
                    self.per_rung_step_time_s.append(
                        [int(s), int(s - seg_s0),
                         round((time.perf_counter() - seg_t0) / max(1, s - seg_s0), 6)])
                    seg_t0, seg_s0 = time.perf_counter(), s
                    if (new_budgets != budgets).any():
                        # the budget is a *traced* leaf: truncating it freezes
                        # the losing lanes on the next step without a recompile
                        budgets = new_budgets
                        php = dataclasses.replace(
                            php, total_steps=jnp.asarray(budgets, jnp.float32))
        finally:
            if ring is not None:
                self._absorb_ring(ring)
        # telemetry: how long the flight actually ran (in-flight stops shrink it)
        self.last_flight_steps = s
        scores = np.asarray(population_scores(pstate, self.DIVERGED_SCORE))
        if s > seg_s0:  # the tail past the last rung boundary (scores synced)
            self.per_rung_step_time_s.append(
                [int(s), int(s - seg_s0),
                 round((time.perf_counter() - seg_t0) / (s - seg_s0), 6)])
        return [float(x) for x in scores[: len(configs)]]

    def _run_batch_device_rules(self, tc, data, k, mesh, pstate, php, budgets,
                                streams, hook) -> list:
        """Batch-protocol flight with the cohort rung rule carried *in* the
        scan (``--device-rules``).

        The host loop no longer clamps chunks to rung boundaries or restacks
        hyperparameters after a cut: each scan step rebuilds the traced
        ``total_steps`` from the carried budgets and applies the cohort rule
        at boundaries on-device, so a whole ASHA ladder whose max budget fits
        one chunk is ONE dispatch.  Only the surviving budgets come back per
        dispatch (to bound the loop); the hook's truncation counters are
        reconstructed from the budget delta at the end.
        """
        import jax.numpy as jnp

        from ..data.pipeline import split_stream, split_streams
        from ..train.population import (
            cohort_rule_state,
            get_compiled_population_rule_scan_step,
            population_scores,
        )

        spec = hook.device_rule()
        chunk = self.chunk_steps
        # boundaries live in-scan: the planner only caps chunks at flight end
        planner = ChunkPlanner(chunk_steps=chunk)
        init_budgets = budgets.copy()
        if self.per_trial_streams:
            s_lo, s_hi = (jnp.asarray(w) for w in split_streams(streams))
        else:
            s_lo, s_hi = (jnp.uint32(w) for w in split_stream(0))
        s = 0
        while s < int(budgets.max()):
            t = planner.chunk_to(s, int(budgets.max()))
            rules = cohort_rule_state(
                budgets, np.zeros(k), np.full(k, s),
                spec.boundaries, spec.eta)
            steps0 = (jnp.full((k,), s, jnp.int32) if self.per_trial_streams
                      else jnp.asarray(s, jnp.int32))
            fn = get_compiled_population_rule_scan_step(
                tc, k, data, t, "cohort", mesh=mesh,
                per_trial_batch=self.per_trial_streams)
            (pstate, rout), _ = fn(pstate, php, steps0, s_lo, s_hi, rules)
            budgets = np.asarray(rout["budgets"], np.float64)
            self.n_dispatches += 1
            self.n_train_steps += t
            s += t
        spec.absorb_cuts(init_budgets, budgets, np.asarray(pstate["diverged"]))
        self.last_flight_steps = s
        scores = np.asarray(population_scores(pstate, self.DIVERGED_SCORE))
        return [float(x) for x in scores]

    def _run_batch_elastic(self, tc, data, k, pstate, php, budgets, streams,
                           hps, hook, pool) -> list:
        """Batch-protocol flight with elastic lane regrids
        (``--elastic-regrid``).

        At each rung boundary after the cohort rule fires the flight
        *regrids*: the surviving lanes' full train state is gathered into a
        smaller population via the ``regrid`` lane-lifecycle op, retired
        lanes' scores are harvested first, and — when a ``ElasticLanePool``
        is attached — the compact state is ``device_put`` onto a new
        two-level ``(pop, model)`` mesh whose lane rows are *wider*, so later
        rungs train fewer trials faster instead of stepping frozen lanes.
        Without a pool (single-device vectorized manager) the regrid still
        shrinks K to the next power of two, cutting the frozen lanes'
        dead compute.

        Engine choice per segment: width-1 rungs run the vmapped step on
        explicitly placed state (bit-identical to the fixed-width vmapped
        run).  Once a regrid widens the rows past 1, the segment switches to
        the tensor-parallel ``shard_map`` step on the pool's mesh — the same
        program ``--model-parallel`` pins — so each lane row computes its
        width-local parameter shards with explicit psum seams instead of
        GSPMD resharding replicated state every step.  That is what makes a
        regrid *shrink* later-rung wall-clock: the survivors' per-row compute
        drops with the width rather than being replicated W times.

        The invariant: resharding changes layout, never math.  Per-lane
        arithmetic is lane-independent under vmap, so survivor scores are
        bit-equal to the fixed-width run on the same engine family (and
        within 1e-6 across device placements, where cross-row reductions
        reassociate).
        """
        import dataclasses

        import jax
        import jax.numpy as jnp

        from ..data.pipeline import split_stream, split_streams
        from ..distributed.sharding import tp_module_flags
        from ..optim.hparams import stack_hparams
        from ..train.population import (
            get_compiled_population_scan_step,
            get_compiled_population_step,
            get_compiled_sharded_population_step,
            place_two_level,
            population_scores,
            regrid_population_state,
        )

        chunk = self.chunk_steps
        planner = ChunkPlanner(
            chunk_steps=chunk,
            boundaries=hook.boundaries if hook is not None else ())

        def _tp_mesh(m, w):
            # the pool mesh, when its rows genuinely tensor-parallel this
            # model (width > 1 and at least one module's dims divide) —
            # widths that shard nothing keep the vmapped engine
            if m is None or w <= 1:
                return None
            return m if any(tp_module_flags(tc.model, w).values()) else None

        tp_mesh = None
        if pool is not None:
            pstate = place_two_level(pstate, tc, pool.mesh())
            tp_mesh = _tp_mesh(pool.mesh(), pool.width)
        k0 = k
        orig = list(range(k))      # current lane -> original trial index
        final = np.full(k0, self.DIVERGED_SCORE, np.float64)
        budgets = np.asarray(budgets, np.float64)
        streams = list(streams)
        hps = list(hps)

        def splits():
            if self.per_trial_streams:
                return tuple(jnp.asarray(w) for w in split_streams(streams))
            return tuple(jnp.uint32(w) for w in split_stream(0))

        s = 0
        seg_t0, seg_s0 = time.perf_counter(), 0
        while len(budgets) and s < int(budgets.max()):
            t = planner.chunk_to(s, planner.next_cohort_event(
                s, int(budgets.max())))
            if t > 1:
                s_lo, s_hi = splits()
                steps0 = (jnp.full((k,), s, jnp.int32)
                          if self.per_trial_streams
                          else jnp.asarray(s, jnp.int32))
                scan = get_compiled_population_scan_step(
                    tc, k, data, t, mesh=tp_mesh,
                    per_trial_batch=self.per_trial_streams)
                pstate, _ = scan(pstate, php, steps0, s_lo, s_hi)
            else:
                batch = (data.make_population_batch(s, streams)
                         if self.per_trial_streams else data.make_batch(s))
                pstep = (get_compiled_sharded_population_step(
                             tc, k, mesh=tp_mesh,
                             per_trial_batch=self.per_trial_streams)
                         if tp_mesh is not None else
                         get_compiled_population_step(
                             tc, k, per_trial_batch=self.per_trial_streams))
                pstate, _ = pstep(pstate, batch, php)
            self.n_dispatches += 1
            self.n_train_steps += t
            s += t
            if hook is None or s not in hook.boundaries:
                continue
            new_budgets = np.asarray(hook(
                s, np.asarray(pstate["last_loss"]), budgets,
                np.asarray(pstate["diverged"])), np.float64)
            self.per_rung_step_time_s.append(
                [int(s), int(s - seg_s0),
                 round((time.perf_counter() - seg_t0) / max(1, s - seg_s0), 6)])
            seg_t0, seg_s0 = time.perf_counter(), s
            if (new_budgets != budgets).any():
                budgets = new_budgets
                php = dataclasses.replace(
                    php, total_steps=jnp.asarray(budgets, jnp.float32))
            # -- the regrid decision: can the survivors absorb freed lanes? --
            survivors = [i for i in range(k) if budgets[i] > s]
            if not 0 < len(survivors) < k:
                continue
            if pool is not None:
                _, width, k2 = pool.plan(len(survivors))
                shrink = k2 != k or width != pool.width
            else:
                width, k2 = 1, _pow2_ceil(len(survivors))
                shrink = k2 < k
            if not shrink:
                continue
            # harvest retired lanes' final scores BEFORE their state leaves
            # the population (their budgets froze them; the scores are final)
            cur = np.asarray(population_scores(pstate, self.DIVERGED_SCORE))
            live_set = set(survivors)
            for i in range(k):
                if i not in live_set:
                    final[orig[i]] = cur[i]
            mesh2 = None
            if pool is not None:
                _, mesh2 = pool.regrid(len(survivors))
            pstate = regrid_population_state(
                pstate, survivors, tc, mesh=mesh2, pad_to=k2)
            self.n_dispatches += 1
            pad = k2 - len(survivors)
            orig = [orig[i] for i in survivors] + [-1] * pad
            budgets = np.array([budgets[i] for i in survivors] + [0.0] * pad)
            streams = [streams[i] for i in survivors] \
                + [-(k0 + j + 1) for j in range(pad)]
            hps = [hps[i] for i in survivors] \
                + [self._hparams({}, 0) for _ in range(pad)]
            php = dataclasses.replace(
                stack_hparams(hps),
                total_steps=jnp.asarray(budgets, jnp.float32))
            k = k2
            tp_mesh = _tp_mesh(mesh2, width)
            self.n_regrids += 1
            self.lane_width_history.append([int(k2), int(width)])
        self.last_flight_steps = s
        cur = np.asarray(population_scores(pstate, self.DIVERGED_SCORE))
        if s > seg_s0:
            self.per_rung_step_time_s.append(
                [int(s), int(s - seg_s0),
                 round((time.perf_counter() - seg_t0) / (s - seg_s0), 6)])
        for j in range(k):
            if orig[j] >= 0:
                final[orig[j]] = cur[j]
        return [float(x) for x in final]

    def _run_streaming(self, mesh, scheduler, elastic=None) -> list:
        """Continuous lane-refill flight (Algorithm 1's busy-resource invariant
        *inside* one compiled program).

        Lane lifecycle: a lane **leases** a job from the scheduler, runs one
        lane-lifecycle op to take that trial's weights, trains on its own data
        stream, and **retires** when its budget runs out, the rung rule
        truncates it, or it diverges.  Retirement streams the job's result out
        immediately (``scheduler.complete``) and frees the lane for the next
        lease — so losing lanes hand their device time to fresh proposals
        mid-flight instead of idling until the whole batch drains.

        The lifecycle op per lease (all compiled, cached, never a host
        checkpoint round-trip):

        * default — **splice** (``make_lane_splice``): one fresh
          ``init_train_state`` written into exactly the target lane via
          ``dynamic_update_index_in_dim`` (not a K-wide vmap init);
        * ``pbt_lifecycle == "keep"`` — **no device op at all**: the member's
          lane keeps its weights + optimizer state; only the traced hparams /
          budget / data cursor advance to the next round;
        * ``pbt_lifecycle == "clone"`` — **donor clone**
          (``make_lane_clone``): the lane inherits the donor member's weights
          AND optimizer state across the population axis, with the proposer's
          perturbed hparams installed in the traced stack.  A clone whose
          donor lane is still mid-round is *parked* until the donor retires
          (donor lease pinning keeps the donor from starting its next round
          first), so the copy always reads round-boundary weights.

        Schedule/budget bases: a keep/clone lane's device step counter is
        cumulative across rounds, so its traced ``total_steps`` is (steps
        already applied in the inherited state) + (this round's budget), and
        its data cursor continues the member's own stream at
        ``round * round_steps``.

        The scheduler needs three things: ``lease() -> (handle, config) |
        None``, ``complete(handle, score, extra)``, and optionally a
        ``closed`` attribute (True = no more jobs are ever coming, skip the
        idle grace wait).  ``core.resource.vectorized.LaneScheduler`` is the
        Algorithm-1 adapter; benchmarks drive this with a plain queue.
        """
        import time as _time

        import jax
        import jax.numpy as jnp

        from ..data.pipeline import split_streams
        from ..optim.hparams import stack_hparams
        from ..train.population import (
            get_compiled_lane_op,
            get_compiled_population_rule_scan_step,
            get_compiled_population_scan_step,
            get_compiled_population_step,
            get_compiled_sharded_population_step,
            init_population_state_from_keys,
            pad_population,
            pbt_rule_state,
            shard_population_state,
            staggered_rule_state,
        )

        if not self.per_trial_streams:
            raise ValueError(
                "lane refill requires per-trial data streams: a refilled lane "
                "must replay its own stream from its own step 0 (drop "
                "--shared-stream)"
            )
        elastic_on = elastic is not None or self.elastic_regrid
        if elastic_on and self.device_rules:
            raise ValueError(
                "--elastic-regrid and --device-rules are mutually exclusive: "
                "in-scan rule state is K-shaped, a regrid changes K mid-flight")
        if elastic_on and self.lifecycle is not None:
            raise ValueError(
                "--elastic-regrid is incompatible with streaming PBT: "
                "keep/clone directives pin members to lanes a regrid reindexes")
        if elastic_on:
            # elastic flights run the vmapped engine with explicit placement
            # (the pool's two-level mesh); shard_map programs have a fixed K
            mesh = None
        tc, data = self._setup()
        k = pad_population(max(self.population, 1), mesh)

        def _ops(kk):
            """(Re)build the per-K compiled entry points — called once up
            front and again after every elastic regrid changes K."""
            ps = (get_compiled_sharded_population_step(
                      tc, kk, mesh=mesh, per_trial_batch=True)
                  if mesh is not None else
                  get_compiled_population_step(tc, kk, per_trial_batch=True))
            # single lane -> splice (one init, traced lane index); several
            # lanes in one round -> the masked from-keys reset (one dispatch)
            sp = get_compiled_lane_op(tc, kk, "splice", mesh=mesh)
            ini = get_compiled_lane_op(tc, kk, "init", mesh=mesh)
            # crash-safety pair: harvest a live lane to host / splice a
            # harvested snapshot back into a fresh flight's lane
            sn = rs = None
            if self.snapshots is not None:
                sn = get_compiled_lane_op(tc, kk, "snapshot", mesh=mesh)
                rs = get_compiled_lane_op(tc, kk, "restore", mesh=mesh)
            return ps, sp, ini, sn, rs

        pstep, splice_fn, init_fn, snap_fn, restore_fn = _ops(k)
        from ..core import faultinject
        fault_plan = faultinject.get_plan()
        chunk = self.chunk_steps

        def scan_of(t):
            return get_compiled_population_scan_step(tc, k, data, t, mesh=mesh)
        lifecycle = self.lifecycle
        clone_fn = (get_compiled_lane_op(tc, k, "clone", mesh=mesh)
                    if lifecycle is not None else None)
        self._flight_epoch += 1
        epoch = self._flight_epoch
        dispatches0 = self.n_dispatches

        # host-side lane table (lane-local: budgets/steps restart per lease;
        # lineage lanes additionally carry cumulative bases across rounds)
        handles: list = [None] * k
        used = [False] * k
        lineage: list = [None] * k           # member whose weights live here
        lane_round = [0] * k                 # pbt_round of the current lease
        rounds_done: dict = {}               # member -> rounds completed here
        starts = np.zeros(k, np.int64)       # global step of the lane's local 0
        base_data = np.zeros(k, np.int64)    # member data cursor at local 0
        applied0 = np.zeros(k, np.int64)     # device opt.step at lease time
        lane_applied = np.zeros(k, np.int64)  # device opt.step at last retire
        budgets = np.zeros(k, np.float64)    # this round's budget (lane-local)
        resumed_at = np.zeros(k, np.int64)   # lane-local step a restore resumed from
        streams = [-(i + 1) for i in range(k)]     # idle = sentinel stream
        hps = [self._hparams({}, 0) for _ in range(k)]
        lane_keys = [self._init_key(s) for s in streams]
        pstate = init_population_state_from_keys(jnp.stack(lane_keys), tc)
        if mesh is not None:
            pstate = shard_population_state(pstate, mesh, tc=tc)
        elif elastic is not None:
            from ..train.population import place_two_level

            pstate = place_two_level(pstate, tc, elastic.mesh())
        php = stack_hparams(hps)
        hook = self.early_stop
        # --device-rules: lower the rung rule (staggered/async-SHA) or the PBT
        # window quantile into the scan.  The host skips observe(), stops
        # clamping chunks to event-step gaps, and harvests retirements from
        # the scan's emitted budgets/verdicts instead of deciding them.
        device_spec = None
        if self.device_rules and hook is not None and hook.boundaries:
            device_spec = hook.device_rule()
        device_pbt = (self.device_rules and lifecycle is not None
                      and getattr(lifecycle, "device_rule_on", False))
        device_active = device_spec is not None or device_pbt
        batch_complete = (getattr(scheduler, "complete_retirements", None)
                          if device_active else None)
        ring = None
        if self.data_ring and chunk > 1 and not device_active \
                and not elastic_on:
            # host-fed fused scans: the ring re-keys at every lane-table
            # change (the php_dirty hook below) with each live lane's private
            # data-cursor offset, so refilled/restored lanes resume their own
            # stream mid-ring
            from ..train.population import \
                get_compiled_population_ring_scan_step

            ring = self._make_ring(data, k, chunk, mesh=mesh)

            def ring_scan_of(t):
                return get_compiled_population_ring_scan_step(
                    tc, k, data, t, ring.capacity, mesh=mesh)
        # device mode only: while True, pstate is still exactly its from-keys
        # init, so a first mass fill can rebuild it instead of dispatching a
        # masked reset — that free-ness is what lets a whole ladder be ONE call
        virgin = True

        def rule_scan_of(t, mode):
            return get_compiled_population_rule_scan_step(
                tc, k, data, t, mode, mesh=mesh)
        s = 0
        idle_deadline = None
        grace = self.refill_idle_grace_s
        if lifecycle is not None:
            # a lifecycle flight must survive the proposer's callback round
            # trip between rounds: losing the flight loses every member's
            # device state (keep/clone would degrade to re-inits)
            grace = max(grace, 2.0)
        parked: list = []   # leases that cannot run yet (busy donor / no lane)
        donor_waited: set = set()  # handles counted once, not per re-poll
        force_parked = False  # grace expired: degrade stuck directives to init
        # Retirements and rung boundaries happen at *host-known* global steps
        # (starts + budgets / starts + boundary), so the loop only materializes
        # device flags at those event steps instead of syncing every step —
        # between events it dispatches fused multi-step chunks (or, with
        # chunk_steps=1, compiled per-step programs back-to-back).
        # Divergence is the one async event; a capped gap bounds how long a
        # diverged (frozen, masked) lane can occupy its slot before reclaim.
        # Chunking makes that poll chunk-granular: the gap grows with the
        # chunk so big chunks are not split by it — the divergence-reclaim
        # latency is the price of fewer dispatches (shrink --chunk-steps if
        # your search space diverges a lot).
        planner = ChunkPlanner(
            chunk_steps=chunk,
            boundaries=hook.boundaries if hook is not None else ())
        next_event = 0
        s_lo, s_hi = (jnp.asarray(w) for w in split_streams(streams))

        def _next_event() -> int:
            live_now = [i for i in range(k) if handles[i] is not None]
            if device_active:
                # rung cuts and individual budget ends are in-scan events now;
                # the host only stops for the poll or the whole-flight drain
                return planner.device_horizon(s, starts, budgets, live_now)
            return planner.next_stream_event(s, starts, budgets, live_now)

        while True:
            live = [i for i in range(k) if handles[i] is not None]
            php_dirty = False
            if fault_plan is not None and live:
                # chaos hooks: raise@step (flight death -> the supervisor) and
                # nan@lane (set the divergence latch; the ordinary diverged-
                # lane retire path takes over)
                fault_plan.check("flight-step", step=s)
                poison = [i for i in fault_plan.poison_lanes(s) if i < k]
                if poison:
                    pmask = np.zeros(k, bool)
                    pmask[poison] = True
                    pstate = dict(pstate, diverged=jnp.logical_or(
                        pstate["diverged"], jnp.asarray(pmask)))
                    virgin = False
            # 1) at an event step: apply the rung rule, then retire lanes whose
            # budget is exhausted (incl. just-truncated) or that diverged
            if live and s >= next_event:
                self._event_seq += 1
                diverged = np.asarray(pstate["diverged"])
                last = np.asarray(pstate["last_loss"])
                # the device-side optimizer step counter is the exact number
                # of *applied* steps — a diverged lane froze there, however
                # late the capped divergence poll noticed it
                applied = np.asarray(pstate["inner"]["opt"]["step"])
                if (snap_fn is not None and self.snapshot_every
                        and self._event_seq % self.snapshot_every == 0):
                    # harvest BEFORE the retire/lease churn below: the journal
                    # row and the stored state describe this exact boundary.
                    # Diverged lanes are skipped (nothing worth resuming) and
                    # so are lifecycle (PBT) lanes — their keep/clone state is
                    # the proposer's, and a dead flight degrades them to the
                    # counted re-init path instead.
                    for lane in live:
                        local = int(s - starts[lane])
                        if (diverged[lane] or lineage[lane] is not None
                                or local <= 0 or local >= budgets[lane]):
                            continue
                        snap = jax.device_get(
                            snap_fn(pstate, jnp.asarray(lane, jnp.int32)))
                        self.n_dispatches += 1
                        self.n_snapshots += 1
                        self.snapshots.put(streams[lane], snap, {
                            "local": local,
                            "stream": int(streams[lane]),
                            "applied": int(applied[lane]),
                            "applied0": int(applied0[lane]),
                            "budget": float(budgets[lane]),
                            # the lane's data cursor at this boundary: a
                            # restored lease re-derives base_data from it so a
                            # ring-fed (or any host-fed) flight resumes the
                            # stream mid-window exactly
                            "data_cursor": int(base_data[lane] + local),
                        })
                        if self.journal is not None:
                            self.journal.append("snapshot", lane=lane, step=local,
                                                detail={"stream": int(streams[lane])})
                if fault_plan is not None:
                    # kill@event fires AFTER any due harvest: "crash at an
                    # arbitrary event boundary" with the snapshots on disk
                    fault_plan.check("event", event=self._event_seq)
                if hook is not None and device_spec is None:
                    local = np.array(
                        [s - starts[i] if handles[i] is not None else 0
                         for i in range(k)], np.float64)
                    budgets = np.asarray(
                        hook.observe(local, last, budgets, diverged), np.float64)
                retired: list = []  # device mode: one batch per event pass
                for lane in live:
                    local_s = int(s - starts[lane])
                    if diverged[lane] or local_s >= budgets[lane]:
                        bad = bool(diverged[lane]) or not np.isfinite(last[lane])
                        score = self.DIVERGED_SCORE if bad else -float(last[lane])
                        if (hook is not None and diverged[lane]
                                and budgets[lane] > applied[lane] - applied0[lane]):
                            # same telemetry the batch engine keeps: a diverged
                            # lane's remaining budget is dead weight reclaimed
                            hook.n_reclaimed += 1
                        extra = {
                            "steps": int(applied[lane] - applied0[lane]),
                            "total_steps": int(applied[lane]),
                            "diverged": bool(diverged[lane]),
                            "lane": lane,
                            "resumed_from_step": int(resumed_at[lane]),
                        }
                        if batch_complete is not None:
                            retired.append((handles[lane], score, extra))
                        else:
                            scheduler.complete(handles[lane], score, extra=extra)
                        if self.journal is not None:
                            self.journal.append(
                                "retire", lane=lane, step=local_s,
                                detail={"stream": int(streams[lane]),
                                        "score": score})
                        if self.snapshots is not None and lineage[lane] is None:
                            # the trial is done: its snapshots are dead weight
                            self.snapshots.forget(streams[lane])
                        resumed_at[lane] = 0
                        handles[lane] = None
                        budgets[lane] = 0.0
                        lane_applied[lane] = int(applied[lane])
                        if lineage[lane] is not None:
                            rounds_done[lineage[lane]] = lane_round[lane] + 1
                        if lineage[lane] is None:
                            streams[lane] = -(lane + 1)
                            hps[lane] = self._hparams({}, 0)
                            php_dirty = True  # restack: the retired lane freezes
                        # a lineage lane freezes without a restack: its device
                        # step counter equals its traced total_steps (or the
                        # divergence latch holds it) until the next directive
                if retired:
                    # the scan's emitted event log, settled in one call: the
                    # scheduler streams each result exactly as the host-rule
                    # path would, but with one host sync per dispatch
                    batch_complete(retired)
                retired_now = [i for i in range(k) if handles[i] is None
                               and i in live]
                if retired_now and self.ladder_dispatches is None:
                    self.ladder_dispatches = self.n_dispatches - dispatches0
                # the retire pass may have emptied the flight: recompute so the
                # loop idles/returns instead of dispatching a no-op step (or,
                # chunked, a whole no-op chunk) against all-frozen lanes
                live = [i for i in range(k) if handles[i] is not None]
            # 2) lease pending proposals (parked ones first) and dispatch each
            # through its lane-lifecycle op
            pending, parked = parked + self._drain_leases(scheduler), []
            if pending:
                # clones first: a clone must read its donor's round-boundary
                # weights, so it has to execute before a same-round keep
                # re-activates the donor lane (stable sort keeps arrival order
                # within each group)
                pending.sort(
                    key=lambda hc: hc[1].get("pbt_lifecycle") != "clone")
                free = [i for i in range(k)
                        if handles[i] is None and lineage[i] is None]
                clone_jobs: list = []   # (lane, donor_lane, cfg)
                splice_jobs: list = []  # lanes taking a fresh init
                for handle, cfg in pending:
                    directive = cfg.get("pbt_lifecycle")
                    member = cfg.get("pbt_member")
                    lane = donor_lane = None
                    if lifecycle is not None and directive in ("keep", "clone"):
                        lane = lifecycle.lane_of(member, epoch)
                        if force_parked:
                            if lane is not None and handles[lane] is not None:
                                # two stuck rounds of one member forced in the
                                # same pass: the first took the lane, the
                                # second waits for it (never overwrite a live
                                # lease's handle)
                                parked.append((handle, cfg))
                                continue
                            # the flight idled out with these leases stuck
                            # (dead-flight resume, a clone that will never
                            # arrive): degrade to a fresh init, loudly counted
                            self.n_lineage_resets += 1
                            if directive == "clone":
                                lifecycle.clone_done(cfg)
                            directive = "init" if lane is not None else None
                        else:
                            if lane is not None and handles[lane] is not None:
                                # async mode: member's lane is still mid-round
                                parked.append((handle, cfg))
                                continue
                            if int(cfg.get("pbt_round", 0)) \
                                    != rounds_done.get(member, 0):
                                # rounds run in round order: a later round
                                # offered early (raw feeds, resumes) waits for
                                # its predecessor instead of jumping the queue
                                parked.append((handle, cfg))
                                continue
                            if directive == "keep" and lane is not None \
                                    and lifecycle.pinned(member):
                                # donor lease pinning: a pending clone still
                                # needs this lane's weights — don't resume yet
                                if handle not in donor_waited:
                                    donor_waited.add(handle)
                                    self.n_donor_waits += 1
                                parked.append((handle, cfg))
                                continue
                            if directive == "clone" and lane is not None:
                                donor_lane = lifecycle.lane_of(
                                    cfg.get("pbt_donor"), epoch)
                                if donor_lane is not None and \
                                        handles[donor_lane] is not None:
                                    # donor mid-round: wait for its boundary so
                                    # the copy reads round-boundary weights
                                    if handle not in donor_waited:
                                        donor_waited.add(handle)
                                        self.n_donor_waits += 1
                                    parked.append((handle, cfg))
                                    continue
                                if donor_lane is None:
                                    # donor state lost (dead flight / resume):
                                    # degrade to a fresh init, loudly counted
                                    self.n_lineage_resets += 1
                                    lifecycle.clone_done(cfg)
                                    directive = "init"
                            if lane is None:
                                # keep/clone for a member whose state is gone
                                # (crash-resume): re-init it in a free lane
                                self.n_lineage_resets += 1
                                if directive == "clone":
                                    lifecycle.clone_done(cfg)
                                directive = None  # take the init path below
                    if lane is None:
                        if not free:
                            parked.append((handle, cfg))  # every lane is busy
                            continue
                        lane = free.pop(0)
                        directive = "init"
                        if lifecycle is not None and member is not None:
                            lifecycle.bind(member, lane, epoch)
                            lineage[lane] = member
                    # same resolution as the serial driver: explicit stream /
                    # job id, else a distinct lease-order stream — never the
                    # lane index, which repeats across refills of one lane
                    sid = self._serial_stream_of(cfg)
                    round_steps = int(self._n_steps(cfg))
                    handles[lane] = handle
                    starts[lane] = s
                    lane_round[lane] = int(cfg.get("pbt_round", 0))
                    base_data[lane] = lane_round[lane] * round_steps
                    budgets[lane] = float(round_steps)
                    streams[lane] = sid
                    if directive == "keep":
                        base_sched = int(lane_applied[lane])
                    elif directive == "clone":
                        base_sched = int(lane_applied[donor_lane])
                        clone_jobs.append((lane, donor_lane, cfg))
                    else:  # init / splice — or restore from a lane snapshot
                        stored = (self.snapshots.get(sid)
                                  if restore_fn is not None else None)
                        if stored is not None:
                            # this stream died mid-lane in an earlier flight
                            # (supervised restart or --resume): splice its
                            # harvested state back and continue from the
                            # snapshot's lane-local step instead of step 0
                            snap, meta = stored
                            local = int(meta["local"])
                            pstate = restore_fn(
                                pstate, jnp.asarray(lane, jnp.int32),
                                jax.device_put(snap))
                            virgin = False
                            self.n_dispatches += 1
                            self.n_lane_restores += 1
                            starts[lane] = s - local
                            resumed_at[lane] = local
                            self.resumed_from_steps.append(local)
                            if "data_cursor" in meta:
                                # restore the lane's data cursor too: the ring
                                # (and the in-scan cursors) replay the stream
                                # from exactly the snapshot's position
                                base_data[lane] = int(
                                    meta["data_cursor"]) - local
                            base_sched = int(meta.get("applied0", 0))
                            if self.journal is not None:
                                self.journal.append(
                                    "lane_restore", lane=lane, step=local,
                                    detail={"stream": sid})
                            if used[lane]:
                                self.n_refills += 1
                        else:
                            base_sched = 0
                            resumed_at[lane] = 0
                            lane_keys[lane] = self._init_key(sid)
                            splice_jobs.append(lane)
                            if used[lane]:
                                self.n_refills += 1
                    if directive == "clone" and used[lane]:
                        self.n_refills += 1
                    applied0[lane] = base_sched
                    used[lane] = True
                    hps[lane] = self._hparams(cfg, base_sched + round_steps)
                    php_dirty = True
                    if self.journal is not None:
                        self.journal.append(
                            "lease", job_id=cfg.get("job_id"), lane=lane,
                            step=int(s), detail={"stream": sid})
                # device ops: clones first (they read donor lanes, which are
                # never splice targets), then one splice per fresh-init lane
                if clone_jobs:
                    mask = np.zeros(k, bool)
                    donor_idx = np.arange(k)
                    for lane, donor_lane, _ in clone_jobs:
                        mask[lane] = True
                        donor_idx[lane] = donor_lane
                    pstate = clone_fn(pstate, jnp.asarray(mask),
                                      jnp.asarray(donor_idx, jnp.int32))
                    virgin = False
                    self.n_clones += len(clone_jobs)
                    self.n_dispatches += 1
                    for _, _, cfg in clone_jobs:
                        lifecycle.clone_done(cfg)
                if splice_jobs and virgin and device_active:
                    # first fill of a device-rule flight: nothing has trained
                    # yet, so rebuilding the whole population from the lane
                    # keys is bit-identical to the masked reset (idle lanes
                    # are exactly their sentinel-key inits) and costs no
                    # device dispatch — the ladder's single call stays single
                    pstate = init_population_state_from_keys(
                        jnp.stack(lane_keys), tc)
                    if mesh is not None:
                        pstate = shard_population_state(pstate, mesh)
                elif len(splice_jobs) == 1:
                    lane = splice_jobs[0]
                    pstate = splice_fn(
                        pstate, jnp.asarray(lane, jnp.int32), lane_keys[lane])
                    virgin = False
                    self.n_splices += 1
                    self.n_dispatches += 1
                elif splice_jobs:
                    # several lanes this round (initial fill, mass refill):
                    # one masked reset beats a dispatch per lane
                    reset_mask = np.zeros(k, bool)
                    reset_mask[splice_jobs] = True
                    pstate = init_fn(
                        pstate, jnp.asarray(reset_mask), jnp.stack(lane_keys))
                    virgin = False
                    self.n_dispatches += 1
                live = [i for i in range(k) if handles[i] is not None]
                force_parked = False
            # -- elastic regrid: once the feed has drained (scheduler closed,
            # nothing parked) and retirements have emptied at least half the
            # lanes, gather the survivors into a smaller population laid out
            # over the freed devices — later rungs train fewer trials wider
            # instead of stepping frozen lanes.  Ascending lane order is
            # preserved, so the staggered rule's history appends (and thus
            # every later cut) match the fixed-width run exactly.
            live = [i for i in range(k) if handles[i] is not None]
            if (elastic_on and live and not parked
                    and getattr(scheduler, "closed", False)
                    and len(live) <= k // 2):
                if elastic is not None:
                    _, width, k2 = elastic.plan(len(live))
                    shrink = k2 != k or width != elastic.width
                else:
                    width, k2 = 1, _pow2_ceil(len(live))
                    shrink = k2 < k
                if shrink:
                    mesh2 = None
                    if elastic is not None:
                        _, mesh2 = elastic.regrid(len(live))
                    from ..train.population import regrid_population_state

                    pstate = regrid_population_state(
                        pstate, live, tc, mesh=mesh2, pad_to=k2)
                    self.n_dispatches += 1
                    pad = k2 - len(live)

                    def _gather(seq, fill):
                        return [seq[i] for i in live] + \
                            [fill(j) for j in range(pad)]

                    def _garr(arr, dtype):
                        out = np.zeros(k2, dtype)
                        out[: len(live)] = [arr[i] for i in live]
                        return out

                    handles = _gather(handles, lambda j: None)
                    used = _gather(used, lambda j: True)
                    lineage = _gather(lineage, lambda j: None)
                    lane_round = _gather(lane_round, lambda j: 0)
                    hps = _gather(hps, lambda j: self._hparams({}, 0))
                    streams = _gather(
                        streams, lambda j: -(len(live) + j + 1))
                    lane_keys = _gather(
                        lane_keys,
                        lambda j: self._init_key(-(len(live) + j + 1)))
                    starts = _garr(starts, np.int64)
                    base_data = _garr(base_data, np.int64)
                    applied0 = _garr(applied0, np.int64)
                    lane_applied = _garr(lane_applied, np.int64)
                    budgets = _garr(budgets, np.float64)
                    resumed_at = _garr(resumed_at, np.int64)
                    k = k2
                    pstep, splice_fn, init_fn, snap_fn, restore_fn = _ops(k)
                    live = list(range(len(handles) - pad))
                    virgin = False
                    php_dirty = True
                    self.n_regrids += 1
                    self.lane_width_history.append([int(k2), int(width)])
            if php_dirty:
                php = stack_hparams(hps)
                s_lo, s_hi = (jnp.asarray(w) for w in split_streams(streams))
                if ring is not None:
                    # lane table changed: re-key the ring so lane i's slab at
                    # global step s' is its own stream at base_data + s' -
                    # starts (idle lanes fill from their sentinel stream —
                    # masked lanes never apply those batches)
                    offs = [int(base_data[i] - starts[i])
                            if handles[i] is not None else 0 for i in range(k)]
                    ring.set_lanes(streams, offs, at_step=s)
            if not live:
                # 3) flight idle: linger briefly for late proposals (Algorithm 1
                # may be mid-callback), then return the lanes
                if getattr(scheduler, "closed", False) and not parked:
                    break
                now = _time.time()
                if idle_deadline is None:
                    idle_deadline = now + grace
                if now >= idle_deadline:
                    if any(c.get("pbt_lifecycle") in ("keep", "clone")
                           for _, c in parked):
                        # stuck lifecycle leases (their predecessor/donor is
                        # never coming): re-init them instead of stranding
                        force_parked = True
                        idle_deadline = None
                        continue
                    break
                _time.sleep(0.002)
                continue
            idle_deadline = None
            next_event = _next_event()
            if next_event <= s:
                # an event is due NOW (e.g. a freshly leased zero-budget job):
                # loop back into the event pass instead of burning a dispatch
                # on steps nobody needs
                continue
            # 4) advance to the next event: lane i consumes ITS OWN stream at
            # ITS OWN cursor (a refilled lane replays from 0; a keep/clone
            # round continues the member's cursor at round * round_steps).
            # With --chunk-steps > 1 the gap is covered by fused scans whose
            # batches are synthesized on device — one dispatch per chunk
            # instead of one (plus K host-built batches) per step; chunk
            # boundaries land exactly on the event step.
            t = planner.chunk_to(s, next_event)
            if ring is not None:
                # chunk horizons stay capped to filled windows: block until
                # the host has staged exactly this chunk (counted as
                # ring_fill_wait_s) instead of shrinking the chunk — a
                # different chunk split would reorder result arrival under a
                # stateful proposer and break engine score-equivalence
                ring.wait_filled(s, t)
            if device_active:
                # rule-carrying scan (any t >= 1): budgets ride as scan state,
                # rung cuts / window verdicts land in-scan, and the emitted
                # rule state is the event log the host harvests from
                steps0 = np.zeros(k, np.int64)
                local0 = np.zeros(k, np.int64)
                for i in range(k):
                    if handles[i] is not None:
                        local0[i] = s - starts[i]
                        steps0[i] = base_data[i] + local0[i]
                if device_spec is not None:
                    counts_max = max((len(v) for v in
                                      hook._rung_history.values()), default=0)
                    cap = _pow2_ceil(counts_max + k)
                    hist, counts = device_spec.lower_history(cap)
                    rules = staggered_rule_state(
                        budgets, applied0, local0,
                        device_spec.boundaries, device_spec.eta, hist, counts)
                    mode = "staggered"
                else:
                    wentries = lifecycle.window_snapshot()
                    w = lifecycle.window.maxlen
                    wscore = np.zeros(w, np.float32)
                    for j, (_, sc, _) in enumerate(wentries):
                        wscore[j] = sc
                    rules = pbt_rule_state(
                        budgets, applied0, local0,
                        lifecycle.quantile, wscore, len(wentries))
                    mode = "pbt"
                (pstate, rout), _ = rule_scan_of(t, mode)(
                    pstate, php, jnp.asarray(steps0, jnp.int32), s_lo, s_hi,
                    rules)
                virgin = False
                if device_spec is not None:
                    new_budgets = np.asarray(rout["budgets"], np.float64)
                    # every device-side shrink here is a rung cut (the
                    # staggered rule skips diverged lanes; dead-budget reclaim
                    # stays with the host retire pass, counted there)
                    hook.n_truncated += int((new_budgets < budgets).sum())
                    device_spec.absorb_history(rout["hist"], rout["counts"])
                    budgets = new_budgets
                else:
                    vready = np.asarray(rout["vready"])
                    vbottom = np.asarray(rout["vbottom"])
                    vlo = np.asarray(rout["vlo"])
                    vhi = np.asarray(rout["vhi"])
                    for lane in range(k):
                        if vready[lane] and lineage[lane] is not None:
                            lifecycle.note_device_verdict(
                                lineage[lane], lane_round[lane],
                                bool(vbottom[lane]), float(vlo[lane]),
                                float(vhi[lane]))
            elif t > 1 and ring is not None:
                # ring-fed fused scan: slabs for steps [s, s+t) are already on
                # device (wait_filled capped t), so the per-lane cursors ride
                # in the ring contents, not in traced stream words
                with ring.reserve() as slots:
                    pstate, _ = ring_scan_of(t)(
                        pstate, php, slots,
                        jnp.asarray(s % ring.capacity, jnp.int32))
            elif t > 1:
                steps0 = np.zeros(k, np.int64)
                for i in range(k):
                    if handles[i] is not None:
                        steps0[i] = base_data[i] + s - starts[i]
                pstate, _ = scan_of(t)(
                    pstate, php, jnp.asarray(steps0, jnp.int32), s_lo, s_hi)
            else:
                # one vectorized synthesis call for all K lanes (idle lanes
                # consume their sentinel stream at step 0 — never applied)
                cursors = [int(base_data[i] + s - starts[i])
                           if handles[i] is not None else 0 for i in range(k)]
                batch = data.make_population_batch(cursors, streams)
                pstate, _ = pstep(pstate, batch, php)
            self.n_dispatches += 1
            self.n_train_steps += t
            s += t
            if ring is not None:
                ring.consume_to(s)
        if ring is not None:
            self._absorb_ring(ring)
        self.last_flight_steps = s
        return []

    @staticmethod
    def _drain_leases(scheduler) -> list:
        out = []
        while True:
            lease = scheduler.lease()
            if lease is None:
                return out
            out.append(lease)


class _ReplayJob:
    """Minimal duck-typed job for feeding a proposer outside Algorithm 1."""

    def __init__(self, cfg):
        self.config = cfg


def run_pbt_serial(trial: PopulationTrial, proposer) -> dict:
    """Generation-barriered serial PBT baseline (host checkpoint round-trips).

    Drives a *streaming-mode* ``PBTProposer`` with an explicit generation
    barrier: each pass collects one whole generation of member configs, runs
    every member's round serially (one trial at a time on the compile-once
    step), and takes weights according to the round's lifecycle directive
    from HOST checkpoints — ``keep`` reloads the member's own checkpoint,
    ``clone`` reloads the donor's (the pre-refactor ``pbt_ckpt`` protocol the
    streaming engine eliminates).  Every round costs two host round-trips
    (restore + checkpoint), counted in ``trial.n_host_ckpt_roundtrips``.

    Because the decision rule, RNG, per-member data streams, schedule bases
    and init keys are all shared with the streaming engine, a same-seed
    streaming run must reproduce these scores (this is the equivalence
    baseline the benchmarks and tests pin).  Returns ``{(member, round):
    score}``.
    """
    import jax

    from ..train.train_step import get_compiled_train_step, init_train_state

    tc, data = trial._setup()
    step_fn = get_compiled_train_step(tc)
    ckpts: dict = {}
    applied: dict = {}
    scores: dict = {}
    hook = proposer.lifecycle_hook()
    while not proposer.finished():
        gen = proposer.get_params(proposer.population)
        if not gen:
            break
        # exploit copies happen AT the barrier: a clone must read its donor's
        # end-of-previous-generation checkpoint, not a checkpoint the donor
        # already advanced while this generation ran member-by-member (the
        # streaming engine's donor pin enforces exactly this boundary)
        gen_ckpts, gen_applied = dict(ckpts), dict(applied)
        results = []
        for cfg in gen:
            m, r = int(cfg["pbt_member"]), int(cfg["pbt_round"])
            lc = cfg.get("pbt_lifecycle", "init")
            n_steps = trial._n_steps(cfg)
            stream = trial._stream_of(cfg, m)
            if lc == "keep":
                state = jax.device_put(ckpts[m])      # host -> device restore
                trial.n_host_ckpt_roundtrips += 1
                base_sched = applied[m]
            elif lc == "clone":
                donor = int(cfg["pbt_donor"])
                state = jax.device_put(gen_ckpts[donor])  # boundary snapshot
                trial.n_host_ckpt_roundtrips += 1
                base_sched = gen_applied[donor]
                if hook is not None:
                    hook.clone_done(cfg)  # pins are an engine concept
            else:
                state = init_train_state(trial._init_key(stream), tc)
                base_sched = 0
            hp = trial._hparams(cfg, base_sched + n_steps)
            base_data = r * n_steps
            from ..data.pipeline import HostPrefetcher

            feed = HostPrefetcher(
                lambda t: data.make_batch(base_data + t, stream=stream))
            loss, n_applied = float("inf"), 0
            for t in range(n_steps):
                state, metrics = step_fn(state, feed.pop(t), hp)
                if t + 1 < n_steps:
                    feed.prefetch(t + 1)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    break
                n_applied += 1
            score = -loss if n_applied == n_steps else trial.DIVERGED_SCORE
            ckpts[m] = jax.device_get(state)          # device -> host ckpt
            trial.n_host_ckpt_roundtrips += 1
            applied[m] = base_sched + n_applied
            scores[(m, r)] = score
            results.append((cfg, score))
        # the generation barrier: results feed back only when the whole
        # generation has run, in member order — the decision/RNG sequence the
        # synchronized streaming engine reproduces
        for cfg, score in results:
            proposer.update(score, _ReplayJob(cfg))
    return scores


SPACE = [
    {"name": "learning_rate", "type": "float", "range": [1e-4, 3e-2], "scale": "log"},
    {"name": "warmup_frac", "type": "float", "range": [0.02, 0.5]},
    {"name": "weight_decay", "type": "float", "range": [0.0, 0.3]},
    {"name": "b2", "type": "float", "range": [0.9, 0.999]},
    {"name": "grad_clip", "type": "choice", "range": [0.5, 1.0, 2.0]},
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--proposer", default="random",
                   help="random | grid | gp | tpe | hyperband | bohb | asha | pbt")
    p.add_argument("--n-samples", type=int, default=8)
    p.add_argument("--n-parallel", type=int, default=2)
    p.add_argument("--steps", type=int, default=30, help="train steps per unit budget")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--db", default="", help="sqlite path ('' = in-memory)")
    p.add_argument("--deadline", type=float, default=0.0, help="per-job seconds (straggler kill)")
    p.add_argument("--vectorize", type=int, default=0, metavar="K",
                   help="train K trials as one vmapped program (0 = serial compile-once)")
    p.add_argument("--shard-population", action="store_true",
                   help="with --vectorize: split the K-trial population axis over "
                        "all local devices (shard_map; K is padded to a multiple "
                        "of the device count)")
    p.add_argument("--shared-stream", action="store_true",
                   help="legacy data mode: every trial consumes the same seeded "
                        "batch stream (default: independent per-trial streams)")
    p.add_argument("--inflight-stop", action="store_true",
                   help="with --vectorize and asha/hyperband/bohb: apply the "
                        "rung rule mid-flight, truncating losing lanes' budgets "
                        "so they free up before the batch ends")
    p.add_argument("--lane-refill", action="store_true",
                   help="with --vectorize: continuous streaming flights — a "
                        "retired lane (budget done / rung-truncated / diverged) "
                        "is reset in place inside the compiled program and "
                        "immediately takes the next proposal; results stream "
                        "out per lane instead of at flight end")
    p.add_argument("--pbt-streaming", action="store_true",
                   help="with --proposer pbt and --vectorize K: run PBT on the "
                        "streaming lane engine (implies --lane-refill) — a "
                        "losing member's lane inherits a donor lane's weights "
                        "and optimizer state via a compiled clone op instead "
                        "of a pbt_ckpt host round-trip; no generation bubble")
    p.add_argument("--pbt-async", action="store_true",
                   help="with --pbt-streaming: drop the round gate so members "
                        "run fully asynchronously — exploit/explore decisions "
                        "come from the sliding member-score window alone "
                        "(default: rounds are gated, matching the "
                        "generation-barriered driver decision-for-decision)")
    p.add_argument("--pbt-perturb", type=float, default=1.2,
                   help="PBT explore factor: floats scale by this (or its "
                        "inverse) through the unit cube")
    p.add_argument("--pbt-quantile", type=float, default=0.25,
                   help="PBT exploit quantile: members in the bottom fraction "
                        "clone a top-fraction donor")
    p.add_argument("--pbt-window", type=int, default=0,
                   help="sliding member-score window for streaming PBT "
                        "decisions (0 = population size)")
    p.add_argument("--pbt-rounds", type=int, default=0,
                   help="training rounds per PBT member (0 = n-samples / "
                        "population)")
    p.add_argument("--chunk-steps", type=int, default=1, metavar="T",
                   help="with --vectorize: fuse up to T population steps into "
                        "one device dispatch (lax.scan with on-device batch "
                        "synthesis); chunk boundaries align with rung/"
                        "retirement/PBT-round event steps, and T=1 reproduces "
                        "the per-step loop bit-for-bit.  Larger T = fewer "
                        "host dispatches but coarser divergence polling")
    p.add_argument("--device-rules", action="store_true",
                   help="with --vectorize: evaluate the scheduling rules "
                        "INSIDE the fused scan — rung cuts (--inflight-stop) "
                        "and the PBT window quantile (--pbt-async) ride as "
                        "lax.scan carry state, so chunk boundaries stop "
                        "clamping to event-step gaps and a whole ASHA ladder "
                        "can run as ONE device dispatch; the host only "
                        "harvests retirements from the scan's emitted event "
                        "log")
    p.add_argument("--elastic-regrid", action="store_true",
                   help="with --vectorize and a rung rule (--inflight-stop): "
                        "at rung boundaries, gather the surviving lanes into "
                        "a smaller population laid out over the freed devices "
                        "— a two-level (pop, model) mesh with wider lane rows "
                        "under --shard-population, a lane-count shrink on the "
                        "single-device engine — so later rungs train fewer "
                        "trials faster instead of stepping frozen lanes; "
                        "streaming flights (--lane-refill) shrink the same "
                        "way once the proposal feed drains.  Resharding "
                        "changes layout, never math: per-trial scores "
                        "reproduce the fixed-width run")
    p.add_argument("--data-ring", action="store_true",
                   help="with --vectorize and --chunk-steps T > 1: feed the "
                        "fused scans from a device-resident prefetch ring "
                        "(repro.data.ring) host-filled ahead of the consumer "
                        "instead of in-scan batch synthesis — the path real "
                        "host datasets take into the chunked engine.  The "
                        "default synth-backed fill reproduces the in-scan "
                        "engine's scores bit-for-bit; telemetry lands in the "
                        "CLI JSON (ring_fill_wait_s, overlap_frac)")
    p.add_argument("--ring-windows", type=int, default=2, metavar="W",
                   help="with --data-ring: prefetch depth in chunk-windows "
                        "(>= 2; 2 = classic double buffering — one window "
                        "training, one filling)")
    p.add_argument("--fused-rmsnorm", action="store_true",
                   help="run the Pallas rmsnorm kernel (interpret mode off "
                        "TPU) inside the train step instead of the reference "
                        "norm — the kernel-revival path for the population "
                        "engines")
    p.add_argument("--fused-attention", action="store_true",
                   help="run the Pallas flash-attention kernel (interpret "
                        "mode off TPU) inside the train step instead of the "
                        "reference attention; decode/cached paths keep the "
                        "reference op")
    p.add_argument("--fused-ssm", action="store_true",
                   help="run the Pallas chunked selective-scan kernel "
                        "(interpret mode off TPU) inside the train step for "
                        "SSM/hybrid archs; the backward pass replays the "
                        "reference scan")
    p.add_argument("--model-parallel", type=int, default=1, metavar="W",
                   help="with --shard-population: fold the device grid into "
                        "a two-level (pop, model) mesh of W-device lane rows "
                        "— each lane's attention heads and MLP/SSM channels "
                        "split over its row (shard_map with explicit psum "
                        "seams), so the model axis carries compute instead "
                        "of replication and per-lane optimizer state shrinks "
                        "~1/W per device.  Width is layout, never math: "
                        "scores match the width-1 run on the same trials")
    p.add_argument("--per-trial-init", action="store_true",
                   help="fold each trial's stream/job id into its init PRNG "
                        "key so trials start from distinct weights (serial and "
                        "population engines fold identically; default: shared "
                        "init from --seed)")
    p.add_argument("--legacy-recompile", action="store_true",
                   help="pre-refactor baseline: bake hparams into the closure, recompile per trial")
    p.add_argument("--snapshot-every", type=int, default=0, metavar="N",
                   help="with --lane-refill: harvest every live lane's train "
                        "state to host at every N-th streaming event boundary "
                        "(persisted next to --db), so a crashed run resumes "
                        "each lane from its last snapshot instead of step 0 "
                        "(0 = off)")
    p.add_argument("--snapshot-dir", default="",
                   help="lane-snapshot directory (default: <db>.lanes)")
    p.add_argument("--resume", nargs="?", type=int, const=-1, default=None,
                   metavar="EXP_ID",
                   help="resume a crashed experiment from --db (no id = the "
                        "latest): replays finished jobs into the proposer, "
                        "re-queues the ones mid-flight at the crash, and "
                        "restores snapshotted lanes from --snapshot-dir")
    p.add_argument("--max-flight-restarts", type=int, default=2,
                   help="supervised streaming-flight restarts (with backoff) "
                        "before the survivors fail for good")
    p.add_argument("--fault-spec", default="",
                   help="deterministic fault injection, e.g. 'raise@step=20' "
                        "or 'kill@event=3' (see repro.core.faultinject; also "
                        "armable via REPRO_FAULT_SPEC)")
    args = p.parse_args(argv)

    from ..core import faultinject
    from ..core.experiment import Experiment
    from ..core.tracking.database import TrackingDB

    if args.fault_spec:
        faultinject.arm(args.fault_spec)

    resume_db = None
    resume_exp_id = None
    if args.resume is not None:
        if not args.db:
            p.error("--resume needs --db (the tracking DB to resume from)")
        resume_db = TrackingDB(args.db)
        resume_exp_id = (resume_db.latest_experiment_id()
                         if args.resume == -1 else args.resume)
        if resume_exp_id is None:
            p.error(f"--resume: no experiment found in {args.db!r}")
        row = resume_db.get_experiment(resume_exp_id)
        if row is None:
            p.error(f"--resume: experiment {resume_exp_id} not in {args.db!r}")
        # the stored CLI geometry wins: the trial must be rebuilt exactly as
        # the crashed run built it (arch / steps / engine / chunking / seed),
        # or the resumed lanes would not be score-equivalent
        for key, val in (row["exp_config"].get("cli") or {}).items():
            setattr(args, key, val)

    exp_cfg = {
        "proposer": args.proposer,
        "parameter_config": SPACE,
        "n_samples": args.n_samples,
        "n_parallel": args.n_parallel,
        "target": "max",
        "random_seed": args.seed,
        "resource": "local",
    }
    if args.db:
        exp_cfg["db_path"] = args.db
    if args.deadline:
        exp_cfg["job_deadline_s"] = args.deadline
    exp_cfg["max_flight_restarts"] = args.max_flight_restarts
    if args.snapshot_every:
        exp_cfg["snapshot_every"] = args.snapshot_every

    if args.pbt_streaming:
        if args.proposer != "pbt":
            p.error(f"--pbt-streaming needs --proposer pbt, got {args.proposer!r}")
        if args.vectorize <= 0:
            p.error("--pbt-streaming requires --vectorize K (members live in "
                    "population lanes)")
        args.lane_refill = True  # streaming PBT rides the lane-refill engine
        exp_cfg.update(
            streaming=True,
            sync_rounds=not args.pbt_async,
            population=args.vectorize,
            perturb=args.pbt_perturb,
            quantile=args.pbt_quantile,
            window=args.pbt_window,
        )
        if args.pbt_rounds:
            exp_cfg["n_generations"] = args.pbt_rounds
    elif args.pbt_async:
        p.error("--pbt-async only applies with --pbt-streaming")
    if args.vectorize <= 0 and (args.shard_population or args.inflight_stop
                                or args.lane_refill):
        p.error("--shard-population/--inflight-stop/--lane-refill require "
                "--vectorize K (they act on the population engines)")
    if args.lane_refill and args.shared_stream:
        p.error("--lane-refill needs per-trial data streams (a refilled lane "
                "replays its own stream from step 0); drop --shared-stream")
    if args.chunk_steps > 1 and args.vectorize <= 0:
        p.error("--chunk-steps acts on the population engines; it requires "
                "--vectorize K")
    if args.snapshot_every and not args.lane_refill:
        p.error("--snapshot-every snapshots streaming lanes; it requires "
                "--lane-refill")
    if args.device_rules:
        if args.vectorize <= 0:
            p.error("--device-rules acts on the population engines; it "
                    "requires --vectorize K")
        if not (args.inflight_stop or (args.pbt_streaming and args.pbt_async)):
            p.error("--device-rules needs an in-scan rule: --inflight-stop "
                    "(rung cuts) or --pbt-streaming with --pbt-async "
                    "(window-quantile verdicts)")
    if args.legacy_recompile and (args.fused_rmsnorm or args.fused_attention
                                  or args.fused_ssm):
        p.error("--fused-rmsnorm/--fused-attention/--fused-ssm act on the "
                "compile-once train step; the --legacy-recompile baseline "
                "predates the kernel bank and would silently ignore them")
    if args.fused_attention or args.fused_ssm:
        # fail loudly instead of silently training the reference op: the
        # fused flags are per-module, and the module must exist in the arch
        from ..configs import get_smoke_config
        _cfg = get_smoke_config(args.arch)
        if args.fused_attention and not _cfg.has_attention:
            p.error(f"--fused-attention: arch {args.arch!r} has no attention "
                    "mixer (it would silently run unfused)")
        if args.fused_ssm and not _cfg.has_mamba:
            p.error(f"--fused-ssm: arch {args.arch!r} has no SSM mixer "
                    "(it would silently run unfused)")
    if args.model_parallel < 1:
        p.error("--model-parallel must be >= 1")
    if args.model_parallel > 1:
        if not args.shard_population:
            p.error("--model-parallel W splits each lane's tensors over a "
                    "W-device row of the population mesh; it requires "
                    "--vectorize K with --shard-population")
        if args.elastic_regrid:
            p.error("--model-parallel is incompatible with --elastic-regrid: "
                    "elastic flights lease their own lane widths through the "
                    "ElasticLanePool (the regrid IS the width change)")
    if args.elastic_regrid:
        if args.vectorize <= 0:
            p.error("--elastic-regrid acts on the population engines; it "
                    "requires --vectorize K")
        if args.device_rules:
            p.error("--elastic-regrid is incompatible with --device-rules: "
                    "in-scan rule state is K-shaped, a regrid changes K "
                    "mid-flight")
        if args.pbt_streaming:
            p.error("--elastic-regrid is incompatible with --pbt-streaming: "
                    "keep/clone directives pin members to lanes a regrid "
                    "reindexes")
    if args.data_ring:
        if args.vectorize <= 0 or args.chunk_steps <= 1:
            p.error("--data-ring feeds the fused scans; it requires "
                    "--vectorize K and --chunk-steps T > 1")
        if args.shared_stream:
            p.error("--data-ring fills per-lane slabs; drop --shared-stream")
        if args.device_rules:
            p.error("--data-ring is incompatible with --device-rules: the "
                    "rule-carrying scan synthesizes its own batches (in-scan "
                    "cursors ride the rule state)")
        if args.elastic_regrid:
            p.error("--data-ring is incompatible with --elastic-regrid: the "
                    "ring's lane axis is K-shaped, a regrid changes K "
                    "mid-flight")
        if args.ring_windows < 2:
            p.error("--ring-windows must be >= 2 (one window training, one "
                    "filling)")
    per_trial_streams = not args.shared_stream
    # lane-snapshot store: armed when snapshots are being taken OR when a
    # resume may need to restore lanes a previous run persisted
    snap_store = None
    if args.lane_refill and (args.snapshot_every > 0 or args.resume is not None):
        from ..checkpoint import LaneSnapshotStore

        snap_root = args.snapshot_dir or (args.db + ".lanes" if args.db else None)
        snap_store = LaneSnapshotStore(root=snap_root)
    if args.vectorize > 0:
        exp_cfg["resource"] = "sharded" if args.shard_population else "vectorized"
        exp_cfg["n_parallel"] = args.vectorize
        if args.lane_refill:
            exp_cfg["lane_refill"] = True
        if args.elastic_regrid and args.shard_population:
            exp_cfg["elastic_regrid"] = True
        if args.model_parallel > 1:
            exp_cfg["model_parallel"] = args.model_parallel
        trial = PopulationTrial(args.arch, args.steps, args.batch, args.seq,
                                args.seed, population=args.vectorize,
                                per_trial_streams=per_trial_streams,
                                per_trial_init=args.per_trial_init,
                                chunk_steps=args.chunk_steps,
                                snapshot_every=args.snapshot_every,
                                snapshots=snap_store,
                                device_rules=args.device_rules,
                                elastic_regrid=args.elastic_regrid,
                                data_ring=args.data_ring,
                                ring_windows=args.ring_windows,
                                fused_rmsnorm=args.fused_rmsnorm,
                                fused_attention=args.fused_attention,
                                fused_ssm=args.fused_ssm,
                                model_parallel=args.model_parallel)
    elif args.legacy_recompile:
        trial = make_trial(args.arch, args.steps, args.batch, args.seq, args.seed)
    else:
        trial = PopulationTrial(args.arch, args.steps, args.batch, args.seq,
                                args.seed, per_trial_streams=per_trial_streams,
                                per_trial_init=args.per_trial_init,
                                fused_rmsnorm=args.fused_rmsnorm,
                                fused_attention=args.fused_attention,
                                fused_ssm=args.fused_ssm)
    # the stored CLI geometry is what --resume rebuilds the trial from
    exp_cfg["cli"] = {k: getattr(args, k) for k in (
        "arch", "steps", "batch", "seq", "seed", "vectorize",
        "shard_population", "chunk_steps", "per_trial_init", "shared_stream",
        "lane_refill", "inflight_stop", "snapshot_every", "snapshot_dir",
        "legacy_recompile", "pbt_streaming", "pbt_async", "device_rules",
        "elastic_regrid", "data_ring", "ring_windows", "fused_rmsnorm",
        "fused_attention", "fused_ssm", "model_parallel",
        "max_flight_restarts")}
    t0 = time.time()
    if resume_db is not None:
        exp = Experiment.resume(resume_db, trial, exp_id=resume_exp_id)
    else:
        exp = Experiment(exp_cfg, trial)
    # incremental result telemetry: with streaming flights, results land while
    # the batch is still running — record when each settles
    result_times: list = []
    exp.add_result_callback(lambda job: result_times.append(time.time()))
    if args.inflight_stop:
        hook_factory = getattr(exp.proposer, "inflight_hook", None)
        if hook_factory is None:
            p.error(f"--inflight-stop needs a rung proposer (asha/hyperband/bohb), "
                    f"got {args.proposer!r}")
        trial.early_stop = hook_factory(steps_per_unit=args.steps)
    if args.device_rules and args.pbt_streaming:
        # switch decide() to consume scan-emitted window-quantile verdicts
        exp.proposer.lifecycle_hook().enable_device_rule()
    best = exp.run()
    dt = time.time() - t0
    engine = ("legacy-recompile" if args.legacy_recompile else
              "serial" if args.vectorize == 0 else
              "sharded" if args.shard_population else "vmapped")
    out = {
        "proposer": args.proposer,
        "arch": args.arch,
        "engine": engine + (f"+tp{args.model_parallel}"
                            if args.model_parallel > 1 else "")
                         + ("+refill" if args.lane_refill else "")
                         + ("+chunked" if args.chunk_steps > 1 else "")
                         + ("+ring" if args.data_ring else "")
                         + ("+devrules" if args.device_rules else "")
                         + ("+elastic" if args.elastic_regrid else ""),
        "vectorize": args.vectorize,
    }
    if args.device_rules:
        out["device_rules"] = True
    if args.elastic_regrid:
        out["regrids"] = trial.n_regrids
        out["lane_width_history"] = trial.lane_width_history
    if args.vectorize > 0:
        # always emitted for the population engines: a zero-budget /
        # all-quarantined flight reports its dispatch count with a null
        # ratio instead of dividing by zero (or silently dropping the block)
        trained = int(getattr(trial, "n_train_steps", 0))
        out["chunk_steps"] = args.chunk_steps
        out["device_dispatches"] = getattr(trial, "n_dispatches", 0)
        out["trained_steps"] = trained
        out["dispatches_per_step"] = (
            round(trial.n_dispatches / trained, 3) if trained else None)
    if args.data_ring:
        out["ring_windows"] = args.ring_windows
        out["ring_fills"] = trial.n_ring_fills
        out["ring_invalidations"] = trial.n_ring_invalidations
        out["ring_fill_wait_s"] = round(trial.ring_fill_wait_s, 4)
        out["ring_fill_busy_s"] = round(trial.ring_fill_busy_s, 4)
        out["overlap_frac"] = round(trial.ring_overlap_frac, 4)
    if args.fused_rmsnorm:
        out["fused_rmsnorm"] = True
    if args.fused_attention:
        out["fused_attention"] = True
    if args.fused_ssm:
        out["fused_ssm"] = True
    if args.vectorize > 0 and args.shard_population and not args.elastic_regrid:
        # static telemetry off the lowered per-step program: how many
        # all-reduces the model axis contributes per train step (0 at width 1
        # — the whole point of the width-is-layout invariant)
        from ..train.population import (count_model_axis_collectives,
                                        pad_population)
        tc_, data_ = trial._setup()
        mesh_ = getattr(exp.rm, "mesh", None)
        if mesh_ is not None:
            out["model_parallel"] = args.model_parallel
            trial.model_axis_collectives = count_model_axis_collectives(
                tc_, pad_population(max(args.vectorize, 1), mesh_), mesh_,
                data_, per_trial_batch=per_trial_streams)
            out["model_axis_collectives"] = trial.model_axis_collectives
    if getattr(trial, "per_rung_step_time_s", None):
        out["per_rung_step_time_s"] = trial.per_rung_step_time_s
    if getattr(trial, "early_stop", None) is not None:
        out["inflight_truncated_lanes"] = trial.early_stop.n_truncated
        out["inflight_reclaimed_diverged_lanes"] = trial.early_stop.n_reclaimed
    if args.lane_refill:
        if getattr(trial, "ladder_dispatches", None) is not None:
            # the first cohort's cost: 1 under --device-rules (the whole
            # multi-rung ladder in one fused dispatch), init + one dispatch
            # per event gap otherwise
            out["ladder_device_dispatches"] = trial.ladder_dispatches
        out["lane_refills"] = trial.n_refills
        out["streamed_results"] = exp.rm.n_streamed
        out["refill_flights"] = exp.rm.n_refill_flights
        out["flight_deaths"] = getattr(exp.rm, "n_flight_deaths", 0)
        out["flight_restarts"] = getattr(exp.rm, "n_flight_restarts", 0)
        out["quarantined"] = getattr(exp.rm, "n_quarantined", 0)
    if args.snapshot_every or args.resume is not None:
        out["snapshots"] = getattr(trial, "n_snapshots", 0)
        out["resumed"] = args.resume is not None
        out["resumed_lanes"] = getattr(trial, "n_lane_restores", 0)
        out["resumed_from_steps"] = list(
            getattr(trial, "resumed_from_steps", []))
    if args.pbt_streaming:
        hook = exp.proposer.lifecycle_hook()
        out["pbt_clones"] = trial.n_clones
        out["pbt_splices"] = trial.n_splices
        out["pbt_keeps"] = hook.n_keeps
        out["pbt_donor_waits"] = trial.n_donor_waits + hook.n_donor_waits
        out["pbt_lineage_resets"] = trial.n_lineage_resets
        # the streaming engine's whole point: weights never visit the host
        out["pbt_host_ckpt_roundtrips"] = trial.n_host_ckpt_roundtrips
        if args.device_rules:
            out["pbt_device_verdicts"] = hook.n_device_verdicts
    if result_times:
        out["first_result_s"] = round(result_times[0] - t0, 2)
        out["last_result_s"] = round(result_times[-1] - t0, 2)
    print(json.dumps(dict(out, **{
        "best_score": best["score"],
        "best_config": {k: v for k, v in best["config"].items()
                        if not k.startswith(("hb_", "asha_", "pbt_"))
                        and k not in ("job_id", "stream")},
        "n_jobs": best.get("n_jobs"),
        "seconds": round(dt, 1),
    }), default=float, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
