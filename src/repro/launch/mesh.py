"""Production mesh construction.

A *function*, not a module-level constant — importing this module never
touches jax device state, so tests and benches keep seeing 1 CPU device.
The dry-run entrypoint sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import; everything here just consumes ``jax.devices()``.

Topology (TPU v5e target):
    single-pod : (data=16, model=16)            = 256 chips
    multi-pod  : (pod=2, data=16, model=16)     = 512 chips

``model`` is the high-bandwidth inner axis (TP/EP); ``data``/(``pod``,``data``)
carry batch + FSDP.  ``make_slice_mesh`` builds sub-meshes for HPO trials.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"production mesh {shape} needs {n} devices, found {len(devices)}; "
            "run under launch/dryrun.py which forces 512 host devices"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_trial_mesh(
    n_devices: int,
    axes: Tuple[str, ...] = ("data", "model"),
    shape: Optional[Tuple[int, ...]] = None,
    devices: Optional[Sequence] = None,
) -> jax.sharding.Mesh:
    """Mesh for a single HPO trial on a slice of the pod (or the CPU container)."""
    devices = list(devices) if devices is not None else jax.devices()[:n_devices]
    if shape is None:
        # favour the model axis: (1, n) for tiny trials, squarish otherwise
        d = 1
        while d * d <= n_devices:
            d += 1
        d -= 1
        while n_devices % d:
            d -= 1
        shape = (d, n_devices // d)
    return jax.make_mesh(shape, axes, devices=devices)
