"""Batched serving driver: continuous-batching decode loop.

Demonstrates the production serving path on real (CPU-sized) configs:
prefill via ``forward(last_only=True)`` seeds the KV/SSM cache position,
then a jit'd single-token ``serve_step`` decodes a batch of requests with
temperature sampling.  Requests arrive with different prompt lengths and are
slot-assigned into the batch (a minimal continuous-batching scheduler).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \\
        --requests 8 --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4, help="decode batch slots")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=12, help="max prompt length")
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from ..configs import get_config, get_smoke_config
    from ..models import transformer as T
    from ..train.serve_step import make_serve_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        print(f"{args.arch} is encoder-only: no decode serving path")
        return 0
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    step = jax.jit(make_serve_step(cfg, temperature=args.temperature))

    # request queue: (id, prompt tokens)
    queue = [
        (i, rng.integers(1, cfg.vocab_size, size=rng.integers(2, args.prompt_len + 1)))
        for i in range(args.requests)
    ]
    B = args.batch
    cache = T.init_cache(cfg, B, args.max_seq, dtype=jnp.float32)
    slots = [None] * B          # per-slot: [req_id, prompt, emitted, done_at]
    outputs = {}
    pos = 0
    t0 = time.time()
    steps = 0

    cur = jnp.zeros((B, 1), jnp.int32)
    while queue or any(s is not None for s in slots):
        # fill free slots (continuous batching: new request enters at current pos)
        for b in range(B):
            if slots[b] is None and queue:
                rid, prompt = queue.pop(0)
                slots[b] = {"id": rid, "prompt": list(prompt), "out": [], "fed": 0}
        # choose this step's token per slot: prompt feed or generated token
        tok = np.zeros((B, 1), np.int32)
        for b, s in enumerate(slots):
            if s is None:
                continue
            if s["fed"] < len(s["prompt"]):
                tok[b, 0] = s["prompt"][s["fed"]]
            else:
                tok[b, 0] = s["out"][-1] if s["out"] else 0
        key, sub = jax.random.split(key)
        nxt, cache = step(params, cache, jnp.asarray(tok), pos, sub)
        nxt = np.asarray(nxt)
        steps += 1
        pos += 1
        for b, s in enumerate(slots):
            if s is None:
                continue
            s["fed"] += 1
            if s["fed"] >= len(s["prompt"]):
                s["out"].append(int(nxt[b, 0]))
            if len(s["out"]) >= args.new_tokens or pos >= args.max_seq - 1:
                outputs[s["id"]] = s["out"]
                slots[b] = None
        if pos >= args.max_seq - 1:
            # cache full: flush remaining slots (demo-scale simplification)
            for b, s in enumerate(slots):
                if s is not None:
                    outputs[s["id"]] = s["out"]
                    slots[b] = None
            if queue:
                cache = T.init_cache(cfg, B, args.max_seq, dtype=jnp.float32)
                pos = 0

    dt = time.time() - t0
    for rid in sorted(outputs):
        print(f"req {rid}: {outputs[rid][:10]}{'...' if len(outputs[rid]) > 10 else ''}")
    print(f"{len(outputs)} requests, {steps} decode steps, {dt:.1f}s "
          f"({steps * B / max(dt, 1e-9):.1f} tok/s batched)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
