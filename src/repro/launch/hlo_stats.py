"""Parse collective traffic out of (post-SPMD, per-device) HLO text.

``compiled.as_text()`` is the per-device program after the SPMD partitioner —
every cross-chip transfer appears as an explicit collective op whose operand
types are printed inline:

    %ar = bf16[4,512]{1,0} all-reduce(bf16[4,512]{1,0} %add.9), replica_groups=...

We sum operand bytes per collective family (the prompt's roofline definition)
and additionally model *wire* bytes per op from its replica-group size n:

    all-reduce        2 (n-1)/n x operand      (ring reduce-scatter + all-gather)
    all-gather        (n-1)   x operand        (each device receives n-1 shards)
    reduce-scatter    (n-1)/n x operand
    all-to-all        (n-1)/n x operand
    collective-permute       1 x operand

Both totals are reported; the roofline's collective term uses wire bytes over
a single 50 GB/s ICI link (conservative: assumes no multi-link parallelism).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <shape(s)> <opcode>(" — opcode may carry -start suffix (async)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+("
    + "|".join(COLLECTIVES)
    + r")(-start)?\("
)
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(text: str, f32_as_bf16: bool = False) -> int:
    """Sum byte sizes of every dtype[dims] group in ``text``.

    ``f32_as_bf16``: count f32 tensors at 2 bytes/elem — XLA-CPU's float
    normalization promotes logically-bf16 tensors to f32, which a TPU build
    keeps in bf16; this gives the TPU-equivalent byte count.
    """
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        width = _DTYPE_BYTES[dtype]
        if f32_as_bf16 and dtype == "f32":
            width = 2
        total += n * width
    return total


def _operand_region(line: str) -> str:
    """The text inside the top-level parens of the op call on this line."""
    i = line.find("(")
    depth, j = 0, i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    return line[i + 1 : j]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "all-gather":
        return float(n - 1)
    if op in ("reduce-scatter", "all-to-all", "ragged-all-to-all"):
        return (n - 1) / n
    if op == "collective-broadcast":
        return 1.0
    return 1.0  # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    per_op: Dict[str, Dict[str, float]]      # op -> {count, operand_bytes, wire_bytes}
    operand_bytes: int
    wire_bytes: float

    def summary(self) -> Dict:
        return {
            "per_op": self.per_op,
            "operand_bytes": self.operand_bytes,
            "wire_bytes": self.wire_bytes,
        }


def parse_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    per_op: Dict[str, Dict[str, float]] = {}
    total_operand = 0
    total_wire = 0.0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(2)
        operands = _operand_region(line)
        obytes = shape_bytes(operands)
        n = _group_size(line, default_group)
        wire = obytes * _wire_factor(op, n)
        d = per_op.setdefault(op, {"count": 0, "operand_bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += obytes
        d["wire_bytes"] += wire
        total_operand += obytes
        total_wire += wire
    return CollectiveStats(per_op=per_op, operand_bytes=total_operand, wire_bytes=total_wire)
