"""Launchers: production mesh, multi-pod dry-run, train / serve / HPO drivers."""
