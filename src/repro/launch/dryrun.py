import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.
# This module is the ONLY place the 512-device placeholder topology exists;
# tests/benches import repro.* normally and see the real 1-CPU container.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full production step — sharded train_step for
``train_*`` shapes, single-token ``serve``/decode step (with its KV/SSM cache)
for ``decode_*``/``long_*`` shapes, last-token-logits forward for
``prefill_*`` — entirely from ShapeDtypeStructs (no allocation), then:

    lowered  = jax.jit(step, in_shardings=..., out_shardings=..., donate...)
                  .lower(*input_specs(arch, shape))
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves the cell fits
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

and writes a JSON artifact with cost/memory/collective stats + the three-term
roofline (see ``roofline.py``).  Failures here are bugs in the system.

Usage:
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --arch gemma2-9b --shape decode_32k --multi-pod
    python -m repro.launch.dryrun --all [--multi-pod] [--force]   # sweep (subprocess per cell)
"""
import argparse
import functools
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

ARTIFACT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


# --------------------------------------------------------------------------------------
def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as SDS

    from ..configs import get_config
    from ..configs.base import SHAPES

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    gb, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.frontend != "none":
            batch = {"embeds": SDS((gb, S, cfg.d_model), jnp.bfloat16)}
        else:
            batch = {"tokens": SDS((gb, S), jnp.int32)}
        if shape.kind == "train":
            batch["targets"] = SDS((gb, S), jnp.int32)
            batch["mask"] = SDS((gb, S), jnp.float32)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": SDS((gb, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def _batch_sharding(batch_specs, mesh, rules):
    """Batch tensors: leading dim over the data axes (when divisible)."""
    import jax
    from jax.sharding import NamedSharding

    from ..distributed.sharding import build_pspec

    def one(sds):
        logical = ["batch"] + [None] * (len(sds.shape) - 1)
        return NamedSharding(mesh, build_pspec(sds.shape, logical, rules, mesh))

    return jax.tree.map(one, batch_specs)


# --------------------------------------------------------------------------------------
def _apply_overrides(cfg, pc, overrides):
    """--set key=value overrides: model fields go to ModelConfig, run-policy
    fields to ParallelConfig.  Values parse as int/float/str."""
    import dataclasses

    def parse(v):
        for cast in (int, float):
            try:
                return cast(v)
            except ValueError:
                continue
        return v

    mfields = {f.name for f in dataclasses.fields(cfg)}
    pfields = {f.name for f in dataclasses.fields(pc)}
    for kv in overrides or []:
        k, _, v = kv.partition("=")
        v = parse(v)
        if k in mfields:
            cfg = dataclasses.replace(cfg, **{k: v})
        elif k in pfields:
            pc = dataclasses.replace(pc, **{k: v})
        else:
            raise KeyError(f"--set {k}: not a ModelConfig or ParallelConfig field")
    return cfg, pc


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    save_hlo: Optional[str] = None,
    overrides=None,
) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_config, memory_policy
    from ..configs.base import SHAPES, TrainConfig
    from ..distributed.sharding import build_sharding, make_rules, sharding_context
    from ..models import transformer as T
    from ..train.train_step import init_train_state, make_train_step, train_state_specs
    from . import hlo_cost, roofline
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pc = memory_policy(arch, shape, multi_pod=multi_pod)
    tp = None
    rest = []
    for kv in overrides or []:
        if kv.startswith("tp="):
            tp = int(kv.split("=")[1])
        else:
            rest.append(kv)
    cfg, pc = _apply_overrides(cfg, pc, rest)
    if tp is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:
        # perf-iteration lever: same chip count, different (data, model) split
        # (e.g. model=8 when an arch's head count doesn't divide 16)
        import dataclasses as _dc

        n = 512 if multi_pod else 256
        shp = (2, (n // 2) // tp, tp) if multi_pod else (n // tp, tp)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        mesh = jax.make_mesh(shp, axes, devices=jax.devices()[:n])
        pc = _dc.replace(pc, mesh_shape=shp, mesh_axes=axes)
    n_chips = mesh.size
    rules = make_rules(pc.mesh_axes, shard_cache_seq=pc.shard_cache_seq)
    dp_axes = tuple(a for a in ("pod", "data") if a in pc.mesh_axes)
    tc = TrainConfig(model=cfg, parallel=pc)
    rep = NamedSharding(mesh, P())

    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": {"shape": list(mesh.devices.shape), "axes": list(mesh.axis_names)},
        "overrides": list(overrides or []),
        "parallel": {
            "zero_stage": pc.zero_stage,
            "microbatch": pc.microbatch,
            "remat": pc.remat,
            "mu_dtype": pc.mu_dtype,
            "nu_dtype": pc.nu_dtype,
            "grad_allreduce_dtype": pc.grad_allreduce_dtype,
            "shard_cache_seq": pc.shard_cache_seq,
        },
    }

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    t0 = time.time()

    if shape.kind == "train":
        state_shapes = jax.eval_shape(functools.partial(init_train_state, tc=tc), key_sds)
        specs = train_state_specs(tc)
        if pc.zero_stage == "zero1":
            # params replicated over the data axes (TP/model sharding kept);
            # optimizer moments stay data-sharded -> grads reduce-scatter once
            # per step and params all-gather once after the update, instead of
            # per-microbatch FSDP regathers.
            rules_params = dict(rules, embed=())
            state_sh = {
                "params": build_sharding(state_shapes["params"], specs["params"], rules_params, mesh),
                "opt": build_sharding(state_shapes["opt"], specs["opt"], rules, mesh),
            }
        else:
            state_sh = build_sharding(state_shapes, specs, rules, mesh)
        batch_specs = input_specs(arch, shape_name)
        batch_sh = _batch_sharding(batch_specs, mesh, rules)
        step = make_train_step(tc)

        def fn(state, batch):
            with sharding_context(mesh, rules):
                return step(state, batch)

        jitted = jax.jit(
            fn, in_shardings=(state_sh, batch_sh), out_shardings=(state_sh, rep),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_shapes, batch_specs)

    elif shape.kind == "prefill":
        params_shapes = jax.eval_shape(
            functools.partial(T.init_params, cfg=cfg), key_sds
        )
        params_sh = build_sharding(params_shapes, T.param_specs(cfg), rules, mesh)
        batch_specs = input_specs(arch, shape_name)
        batch_sh = _batch_sharding(batch_specs, mesh, rules)

        def fn(params, batch):
            with sharding_context(mesh, rules):
                logits, _ = T.forward(
                    params,
                    batch.get("tokens"),
                    cfg,
                    inputs_embeds=batch.get("embeds"),
                    remat="none",
                    last_only=not cfg.encoder_only,
                )
            return logits

        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh), out_shardings=None)
        lowered = jitted.lower(params_shapes, batch_specs)

    else:  # decode
        params_shapes = jax.eval_shape(
            functools.partial(T.init_params, cfg=cfg), key_sds
        )
        params_sh = build_sharding(params_shapes, T.param_specs(cfg), rules, mesh)
        cache_shapes = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
        )
        cache_sh = build_sharding(cache_shapes, T.cache_specs(cfg), rules, mesh)
        tok_specs = input_specs(arch, shape_name)
        tok_sh = {
            "tokens": _batch_sharding({"t": tok_specs["tokens"]}, mesh, rules)["t"],
            "pos": rep,
        }

        def fn(params, cache, tokens, pos):
            with sharding_context(mesh, rules):
                logits, new_cache = T.decode_step(params, cache, tokens, pos, cfg)
            return logits, new_cache

        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, cache_sh, tok_sh["tokens"], tok_sh["pos"]),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(
            params_shapes, cache_shapes, tok_specs["tokens"], tok_specs["pos"]
        )

    record["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 2)

    # ---- memory analysis (proves the cell fits) -----------------------------------
    try:
        mem = compiled.memory_analysis()
        print(mem)
        record["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(mem, k)
        } or str(mem)
    except Exception as e:  # CPU backend may not implement it
        record["memory_analysis"] = f"unavailable: {e}"

    # ---- cost analysis (FLOPs / bytes for the roofline) ----------------------------
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    print({k: v for k, v in sorted(cost.items()) if "{" not in k})
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    record["cost_analysis"] = {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }

    # ---- trip-count-aware walk of the post-SPMD HLO --------------------------------
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    totals = hlo_cost.analyse_hlo(hlo, default_group=n_chips)
    record["hlo_cost"] = {
        "flops": totals.flops,
        "bytes": totals.bytes,
        "bytes_bf16eq": totals.bytes_bf16eq,
        "kernel_flops": totals.kernel_flops,
        "kernel_bytes_bf16eq": totals.kernel_bytes_bf16eq,
        "coll_operand_bytes": totals.coll_operand,
        "coll_wire_bytes": totals.coll_wire,
        "coll_tpu_wire_bytes": totals.coll_tpu_wire,
        "per_collective": totals.per_op,
    }

    # ---- roofline -------------------------------------------------------------------
    counts = cfg.param_counts()
    tokens_global = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    rl = roofline.analyse(
        flops_dev=totals.flops,
        bytes_bf16eq_dev=totals.bytes_bf16eq,
        kernel_bytes_bf16eq_dev=totals.kernel_bytes_bf16eq,
        bytes_raw_dev=totals.bytes,
        wire_bytes_dev=totals.coll_tpu_wire,
        n_params_active=counts["active"],
        tokens_global=tokens_global,
        kind=shape.kind,
        n_chips=n_chips,
    )
    record["roofline"] = rl.to_json()
    record["param_counts"] = {k: float(v) for k, v in counts.items()}
    record["status"] = "ok"
    return record


# --------------------------------------------------------------------------------------
def cell_path(arch: str, shape_name: str, multi_pod: bool, out_dir: str, tag: str = "") -> str:
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(out_dir, mesh_tag, f"{arch}__{shape_name}{suffix}.json")


def run_one(args) -> int:
    from ..configs import cells

    skip = dict((s.name, r) for s, r in cells(args.arch))[args.shape]
    path = cell_path(args.arch, args.shape, args.multi_pod, args.out, args.tag)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if skip:
        record = {
            "arch": args.arch, "shape": args.shape, "status": "skipped", "reason": skip,
            "mesh": "multipod" if args.multi_pod else "pod",
        }
        print(f"SKIP {args.arch} x {args.shape}: {skip}")
    else:
        try:
            record = lower_cell(
                args.arch, args.shape, multi_pod=args.multi_pod,
                save_hlo=args.save_hlo, overrides=args.overrides,
            )
            rl = record["roofline"]
            mesh_str = "x".join(str(x) for x in record["mesh"]["shape"])
            print(
                f"OK {args.arch} x {args.shape} mesh={mesh_str} "
                f"compile={record['compile_s']}s bottleneck={rl['bottleneck']} "
                f"terms(c/m/coll)={rl['compute_s']:.3e}/{rl['memory_s']:.3e}/{rl['collective_s']:.3e}s "
                f"useful={rl['useful_ratio']:.2f} frac={rl['roofline_fraction']:.2f}"
            )
        except Exception:
            record = {
                "arch": args.arch, "shape": args.shape, "status": "failed",
                "error": traceback.format_exc(),
            }
            print(f"FAIL {args.arch} x {args.shape}", file=sys.stderr)
            traceback.print_exc()
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return 0 if record["status"] in ("ok", "skipped") else 1


def run_all(args) -> int:
    """Sweep driver: one fresh subprocess per cell (isolates XLA memory and
    any single-cell failure), resumable via the per-cell JSON artifacts."""
    from ..configs import ARCH_IDS
    from ..configs.base import SHAPES

    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]
    failures, done, total = [], 0, 0
    for multi_pod in meshes:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                total += 1
                path = cell_path(arch, shape_name, multi_pod, args.out)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        st = json.load(f).get("status")
                    if st in ("ok", "skipped"):
                        done += 1
                        continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape_name, "--out", args.out,
                ]
                if multi_pod:
                    cmd.append("--multi-pod")
                print(f"[{total}] {arch} x {shape_name} multi_pod={multi_pod}", flush=True)
                try:
                    r = subprocess.run(cmd, timeout=args.timeout)
                    rc = r.returncode
                except subprocess.TimeoutExpired:
                    rc = -9
                    with open(path, "w") as f:
                        json.dump(
                            {"arch": arch, "shape": shape_name, "status": "failed",
                             "error": f"timeout after {args.timeout}s"}, f)
                if rc == 0:
                    done += 1
                else:
                    failures.append((arch, shape_name, multi_pod))
    print(f"\ndry-run sweep: {done}/{total} cells ok/skipped, {len(failures)} failed")
    for f in failures:
        print("  FAILED:", f)
    return 1 if failures else 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="both",
                   help="which mesh(es) --all sweeps")
    p.add_argument("--out", default=ARTIFACT_DIR)
    p.add_argument("--force", action="store_true", help="recompute existing artifacts")
    p.add_argument("--timeout", type=int, default=3000, help="per-cell seconds (--all)")
    p.add_argument("--save-hlo", default=None, help="dump post-SPMD HLO text to file")
    p.add_argument("--tag", default="", help="artifact filename suffix (perf iterations)")
    p.add_argument("--set", action="append", default=[], dest="overrides",
                   metavar="KEY=VALUE", help="override ModelConfig/ParallelConfig fields")
    args = p.parse_args(argv)
    if args.all:
        return run_all(args)
    if not args.arch or not args.shape:
        p.error("need --arch and --shape (or --all)")
    return run_one(args)


if __name__ == "__main__":
    sys.exit(main())
