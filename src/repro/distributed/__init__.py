from .sharding import (
    build_pspec,
    build_sharding,
    constrain,
    make_rules,
    map_specs,
    population_mesh,
    population_specs,
    sharding_context,
)

__all__ = [
    "build_pspec",
    "build_sharding",
    "constrain",
    "make_rules",
    "map_specs",
    "population_mesh",
    "population_specs",
    "sharding_context",
]
