"""Logical-axis sharding rules -> NamedSharding / PartitionSpec.

Every parameter/cache/activation tree has a parallel *specs* tree whose leaves
are tuples of logical axis names.  ``build_sharding`` maps logical names onto
mesh axes through a rules dict, enforcing the two legality constraints XLA
requires: (i) a mesh axis is used at most once per tensor, (ii) the dimension
must be divisible by the product of its mesh axes (else that dim replicates).

Default placement (single-pod (data=16, model=16)):

    weights   : "embed" -> data (FSDP/ZeRO-3), "vocab"/"heads"/"ff"/"expert"/
                "inner"/"moe_ff" -> model (TP/EP)
    activations: "batch" -> (pod, data); inner activation dims follow the op
    KV caches : "cache_seq" -> model (decode), or (data, model) for the
                batch=1 long-context cells (sequence parallelism)

Multi-pod ((pod=2, data=16, model=16)) additionally folds "pod" into the
batch and FSDP axes — parameters and optimizer state shard over all 512 chips.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Rules = Dict[str, Tuple[str, ...]]


def make_rules(mesh_axes: Sequence[str], *, shard_cache_seq: bool = False) -> Rules:
    has_pod = "pod" in mesh_axes
    dp: Tuple[str, ...] = ("pod", "data") if has_pod else ("data",)
    rules: Rules = {
        "batch": dp,
        "embed": dp,              # FSDP: weights' d_model dim over data(+pod)
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head": (),
        "ff": ("model",),
        "moe_ff": ("model",),
        "expert": ("model",),
        "inner": ("model",),
        "cache_seq": ("data", "model") if shard_cache_seq else ("model",),
        "act_embed": (),
        "act_seq": (),
        # Ulysses-style fallback: when an arch's head count does not divide the
        # model axis (starcoder2 24H, qwen3 kv=4, ...) the *query sequence*
        # takes the model axis instead, so attention compute still shards 16
        # ways rather than silently replicating.  Priority ordering below makes
        # heads claim the axis first whenever they can.
        "act_seq_attn": ("model",),
    }
    return {k: tuple(a for a in v if a in mesh_axes) for k, v in rules.items()}


# Lower number = claims mesh axes first.  Head/ff/expert dims take the model
# axis when divisible; act_seq_attn only picks it up as a fallback.
_PRIORITY = {
    "vocab": 0, "heads": 0, "kv_heads": 0, "ff": 0, "moe_ff": 0,
    "expert": 0, "inner": 0, "cache_seq": 0,
    "embed": 1, "batch": 1,
    "act_seq_attn": 2, "act_seq": 3, "act_embed": 3, "head": 3,
}


def build_pspec(
    shape: Sequence[int], logical: Sequence[Optional[str]], rules: Rules, mesh: Mesh
) -> PartitionSpec:
    """Map a logical-axes tuple to a legal PartitionSpec for ``shape``."""
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    spec: list = [None] * len(shape)
    # resolve dims in priority order so e.g. "heads" claims the model axis
    # before the "act_seq_attn" fallback can
    order = sorted(range(len(shape)), key=lambda i: _PRIORITY.get(logical[i], 1))
    for i in order:
        name = logical[i]
        if name is None:
            continue
        dim = shape[i]
        axes = [a for a in rules.get(name, ()) if a not in used]
        # greedily keep the prefix of mesh axes that divides the dim
        keep = []
        prod = 1
        for a in axes:
            n = mesh.shape[a]
            if dim % (prod * n) == 0:
                keep.append(a)
                prod *= n
        if keep:
            used.update(keep)
            spec[i] = tuple(keep) if len(keep) > 1 else keep[0]
    return PartitionSpec(*spec)


def _is_spec_leaf(s: Any) -> bool:
    return isinstance(s, tuple) and all(i is None or isinstance(i, str) for i in s)


def map_specs(shapes, specs, fn):
    """Walk a (nested dict/list) shapes tree in lockstep with its specs tree.
    specs leaves are tuples of logical names; shapes leaves are arrays/SDS."""
    if _is_spec_leaf(specs):
        return fn(shapes, specs)
    if isinstance(specs, dict):
        return {k: map_specs(shapes[k], specs[k], fn) for k in specs}
    if isinstance(specs, (list, tuple)):
        return type(specs)(map_specs(a, b, fn) for a, b in zip(shapes, specs))
    raise TypeError(f"bad specs node: {type(specs)}")


def build_sharding(tree_shapes, tree_specs, rules: Rules, mesh: Mesh):
    """Pytree of shapes (arrays/ShapeDtypeStructs) + specs -> NamedSharding tree."""

    def one(leaf, logical):
        return NamedSharding(mesh, build_pspec(leaf.shape, logical, rules, mesh))

    return map_specs(tree_shapes, tree_specs, one)


# -- population (HPO trial) axis ------------------------------------------------------


def population_mesh(
    devices: Optional[Sequence[Any]] = None,
    axis: str = "pop",
    width: Optional[int] = None,
    model_axis: str = "model",
) -> Mesh:
    """Mesh over ``devices`` (default: all) whose leading axis is the HPO
    *population* axis — K trials shard over it as K/N per device (see
    ``repro.train.population.make_sharded_population_step``).

    With ``width`` the mesh becomes **two-level**: ``(pop, model)`` with
    ``width`` devices per lane row, so each trial is itself a ``width``-way
    model-parallel program while trials still parallelize across the ``pop``
    rows (the elastic-regrid engine widens ``width`` as rung cuts shrink the
    survivor set).  Distinct from the (data, model) axes of a mesh-pool
    slice only in that the leading axis crosses trials, not batches."""
    devs = list(devices) if devices is not None else jax.devices()
    if width is None:
        return Mesh(np.array(devs, dtype=object), axis_names=(axis,))
    w = int(width)
    if w <= 0 or len(devs) % w:
        raise ValueError(
            f"width {width} does not tile {len(devs)} devices into lane rows")
    grid = np.array(devs, dtype=object).reshape(len(devs) // w, w)
    return Mesh(grid, axis_names=(axis, model_axis))


def two_level_mesh(
    devices: Optional[Sequence[Any]] = None,
    width: int = 1,
    axis: str = "pop",
    model_axis: str = "model",
) -> Mesh:
    """``(pop = N/width, model = width)`` mesh — see ``population_mesh``."""
    return population_mesh(devices, axis=axis, width=width,
                           model_axis=model_axis)


def population_specs(tree: Any, mesh: Mesh, axis: str = "pop") -> Any:
    """NamedSharding tree placing every leaf's leading (population) dim on
    ``axis`` — used to put a population state / stacked HParams on the mesh
    before the first sharded step so jit never has to reshard inputs.

    Rank-aware: rank-0 leaves, and leaves whose leading dim does not divide
    over the population axis (scalar rule state, history rings, window
    counters), replicate instead of getting a leading-dim spec
    unconditionally — a spec naming a mesh axis a leaf cannot carry is a
    lowering error, not a fallback."""
    n = int(mesh.shape[axis])
    pop = NamedSharding(mesh, PartitionSpec(axis))
    rep = NamedSharding(mesh, PartitionSpec())

    def one(leaf: Any):
        shape = getattr(leaf, "shape", ())
        if len(shape) < 1 or shape[0] % n:
            return rep
        return pop

    return jax.tree.map(one, tree)


def two_level_pspecs(
    tree: Any, specs: Any, mesh: Mesh, axis: str = "pop",
    rules: Optional[Rules] = None,
) -> Any:
    """Per-leaf ``PartitionSpec`` tree for a population state on a two-level
    mesh: ``P(axis, *build_pspec(leaf.shape[1:], logical, rules, mesh))``.
    This is ``two_level_state_specs`` without the ``NamedSharding`` wrapper —
    the form ``shard_map`` in/out_specs want for the tensor-parallel
    population step."""
    if rules is None:
        rules = make_rules(tuple(a for a in mesh.axis_names if a != axis))

    def one(leaf: Any, logical):
        inner = build_pspec(leaf.shape[1:], logical, rules, mesh)
        return PartitionSpec(axis, *inner)

    return map_specs(tree, specs, one)


def two_level_state_specs(
    tree: Any, specs: Any, mesh: Mesh, axis: str = "pop",
    rules: Optional[Rules] = None,
) -> Any:
    """NamedSharding tree for a population state on a two-level mesh.

    Every leaf keeps its leading K (population) dim on ``axis``; the trailing
    *intra-trial* dims are partitioned per-leaf by composing the leaf's
    logical-axes spec through the ordinary ``make_rules``/``build_pspec``
    machinery restricted to the mesh's non-population axes — so a lane's
    parameters and optimizer moments shard over its own device row exactly
    like a single-trial program would, instead of the blanket leading-dim
    ``population_specs``.  ``specs`` mirrors ``tree`` with logical-name
    tuples for the *trailing* dims (``()`` for per-lane scalars such as the
    step counter or the divergence latch).  ``rules`` overrides the default
    generic rules — the tensor-parallel population engine passes
    ``tp_width_rules`` so storage layout matches what the compiled step
    actually computes on."""
    pspecs = two_level_pspecs(tree, specs, mesh, axis=axis, rules=rules)
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


# -- activation constraints inside model code -----------------------------------------
_CTX: contextvars.ContextVar[Optional[Tuple[Mesh, Rules]]] = contextvars.ContextVar(
    "sharding_ctx", default=None
)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Rules):
    tok = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(tok)


def ctx_mesh() -> Optional[Mesh]:
    """The active sharding context's mesh (None outside a context)."""
    ctx = _CTX.get()
    return ctx[0] if ctx is not None else None


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint through the active context; no-op outside it."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = build_pspec(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# -- tensor-parallel population step (explicit shard_map seams) -----------------------
#
# The two-level (pop, model) mesh gives every lane row ``width`` devices.  The
# GSPMD context above is for the single-trial training path; the population
# engines instead run *explicit* tensor parallelism inside ``shard_map``: the
# width rules below decide which weight families shard over the model axis,
# and the f/g seam ops place the matching psum reductions at activation seams
# (Megatron's f/g operators):
#
#   tp_enter (f): forward identity, backward psum — wraps a *replicated*
#       activation right before it feeds width-sharded weights (column
#       parallel), so the partial input-gradients from each shard sum up.
#   tp_reduce (g): forward psum, backward identity — closes a row-parallel
#       contraction (output dim replicated, contracting dim sharded), turning
#       per-shard partial sums into the full activation.
#
# Correctness rule: an activation branch that feeds REPLICATED weights must
# bypass tp_enter — psum-ing a full (already-replicated) contribution W ways
# overcounts its gradient by W.  The per-module flags in the TP context keep
# seam placement exactly consistent with the width rules' shard decisions.

_TP_SHARDED_LOGICAL = ("heads", "kv_heads", "ff", "inner")

_TP_CTX: contextvars.ContextVar[Optional[Dict[str, Any]]] = contextvars.ContextVar(
    "tp_ctx", default=None
)


def tp_width_rules(cfg, width: int, model_axis: str = "model") -> Rules:
    """Logical-axes rules for a ``width``-way tensor-parallel lane.

    Decisions are per *module*, not per leaf, so e.g. GQA never ends up with
    sharded q-heads but replicated kv-heads (which would break the grouped
    attention reshape):

    * attention shards iff ``n_heads % width == 0`` AND ``n_kv_heads %
      width == 0`` (MLA archs have no separate kv heads — their per-head
      ``wk_b``/``wv_b`` shard with the q heads);
    * the dense MLP ``ff`` dim shards iff ``d_ff % width == 0`` and the arch
      has no MoE blocks (expert weights stay replicated: the dispatch path
      is token-sorted host-free compute that is only correct replicated);
    * mamba's ``inner`` channel dim shards iff ``d_inner % width == 0``.

    Everything else — vocab/embed (tied unembed), norms, router, experts,
    caches — replicates across the model axis: width must stay layout, never
    math."""
    flags = tp_module_flags(cfg, width)
    rules: Rules = {}
    if flags["attn"]:
        rules["heads"] = (model_axis,)
        rules["kv_heads"] = (model_axis,)
    if flags["mlp"]:
        rules["ff"] = (model_axis,)
    if flags["mamba"]:
        rules["inner"] = (model_axis,)
    return rules


def tp_module_flags(cfg, width: int) -> Dict[str, bool]:
    """Which modules actually shard at this width (coherent per-module
    divisibility; see ``tp_width_rules``)."""
    if width <= 1:
        return {"attn": False, "mlp": False, "mamba": False}
    n_kv = int(getattr(cfg, "n_kv_heads", 0) or 0)
    return {
        "attn": bool(cfg.has_attention and cfg.n_heads % width == 0
                     and n_kv % width == 0),
        "mlp": bool(cfg.d_ff % width == 0 and not cfg.has_moe),
        "mamba": bool(cfg.has_mamba and cfg.d_inner % width == 0),
    }


@contextlib.contextmanager
def tp_shard_context(axis: str, flags: Dict[str, bool], gnorm_mask: Any = None):
    """Arm the tensor-parallel seams for the duration of a trace.

    Set INSIDE the ``shard_map``-ed local function body (contextvars are
    Python-trace-scoped, which is exactly when the model code runs) — never
    around the outer jit.  ``flags`` are the ``tp_module_flags`` decisions;
    ``gnorm_mask`` is a params-shaped bool tree (True = leaf sharded over the
    model axis) that ``optim.adamw.global_norm`` uses to psum only the
    width-local sum-of-squares."""
    tok = _TP_CTX.set(dict(flags, axis=axis, gnorm_mask=gnorm_mask))
    try:
        yield
    finally:
        _TP_CTX.reset(tok)


def tp_ctx() -> Optional[Dict[str, Any]]:
    return _TP_CTX.get()


def tp_axis(module: Optional[str] = None) -> Optional[str]:
    """The model-axis name if TP is armed (and ``module`` shards), else None."""
    ctx = _TP_CTX.get()
    if ctx is None:
        return None
    if module is not None and not ctx.get(module, False):
        return None
    return ctx["axis"]


def _seam_f(axis: str):
    """f: identity forward, psum backward (enter column-parallel weights)."""

    @jax.custom_vjp
    def f(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, g):
        return (jax.lax.psum(g, axis),)

    f.defvjp(fwd, bwd)
    return f


def _seam_g(axis: str):
    """g: psum forward, identity backward (close row-parallel contractions)."""

    @jax.custom_vjp
    def g(x):
        return jax.lax.psum(x, axis)

    def fwd(x):
        return jax.lax.psum(x, axis), None

    def bwd(_, ct):
        return (ct,)

    g.defvjp(fwd, bwd)
    return g


@functools.lru_cache(maxsize=None)
def _seams(axis: str):
    return _seam_f(axis), _seam_g(axis)


def tp_enter(x: jax.Array, module: str) -> jax.Array:
    """Seam into a column-parallel block: no-op unless TP is armed for
    ``module``.  ONLY wrap activations that feed width-sharded weights;
    replicated-weight branches must consume the raw input."""
    ax = tp_axis(module)
    if ax is None:
        return x
    return _seams(ax)[0](x)


def tp_reduce(x: jax.Array, module: str) -> jax.Array:
    """Seam out of a row-parallel contraction: psum the per-shard partials
    (no-op unless TP is armed for ``module``)."""
    ax = tp_axis(module)
    if ax is None:
        return x
    return _seams(ax)[1](x)


def tp_gnorm_sumsq(leaf_sumsqs: Sequence[jax.Array], tree: Any):
    """Total sum-of-squares for a grads tree under TP: width-local (sharded)
    leaves psum their partial sums over the model axis, replicated leaves
    count once.  ``leaf_sumsqs`` aligns with ``jax.tree.leaves(tree)``.
    Returns None when TP is not armed (caller keeps its plain path)."""
    import jax.numpy as jnp

    ctx = _TP_CTX.get()
    if ctx is None or ctx.get("gnorm_mask") is None:
        return None
    mask = jax.tree.leaves(ctx["gnorm_mask"])
    if len(mask) != len(leaf_sumsqs):
        # grads tree does not mirror the params mask (e.g. a partial subtree)
        return None
    rep = [s for s, m in zip(leaf_sumsqs, mask) if not m]
    shard = [s for s, m in zip(leaf_sumsqs, mask) if m]
    total = jnp.sum(jnp.stack(rep)) if rep else jnp.zeros((), jnp.float32)
    if shard:
        total = total + jax.lax.psum(jnp.sum(jnp.stack(shard)), ctx["axis"])
    return total


def tp_gnorm_mask(param_specs: Any, rules: Rules) -> Any:
    """Bool tree over a params specs tree: True iff the leaf's logical spec
    names a dimension the width rules shard over the model axis."""
    return map_specs(
        param_specs, param_specs,
        lambda _, logical: any(n in rules for n in logical if n is not None))
