"""repro.core — the paper's contribution: the Auptimizer HPO framework.

Public API mirrors the released ``aup`` package:

    from repro.core import BasicConfig, print_result      # job side
    from repro.core import Experiment                     # controller side
"""
from .basic_config import BasicConfig, print_result, parse_result
from .experiment import Experiment
from .job import Job, JobResult, JobStatus
from .search_space import ParamSpec, SearchSpace
from .proposer import available_proposers, get_proposer_cls, make_proposer, Proposer
from .resource import (
    ResourceManager,
    available_resource_managers,
    get_resource_manager_cls,
)
from .tracking import TrackingDB

__all__ = [
    "BasicConfig", "print_result", "parse_result",
    "Experiment", "Job", "JobResult", "JobStatus",
    "ParamSpec", "SearchSpace",
    "Proposer", "available_proposers", "get_proposer_cls", "make_proposer",
    "ResourceManager", "available_resource_managers", "get_resource_manager_cls",
    "TrackingDB",
]
