"""Typed hyperparameter search-space definition.

The paper's ``parameter_config`` block (Code 2) defines each hyperparameter as
``{"name": ..., "type": "float"|"int"|"choice", "range": [...]}``.  We keep that
JSON form as the canonical serialized representation and add a typed layer on
top so proposers can reason about dimensionality, log-scaling and grids.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence

import numpy as np

_VALID_TYPES = ("float", "int", "choice")


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One hyperparameter dimension.

    ``range`` is [lo, hi] for float/int (inclusive) or the list of values for
    choice.  ``scale='log'`` samples uniformly in log-space (lr-style params).
    ``n_grid`` controls how many points grid search places on this dimension.
    """

    name: str
    type: str
    range: Sequence[Any]
    scale: str = "linear"  # 'linear' | 'log'
    n_grid: int = 3

    def __post_init__(self):
        if self.type not in _VALID_TYPES:
            raise ValueError(f"param {self.name}: bad type {self.type!r}")
        if self.type in ("float", "int"):
            if len(self.range) != 2 or self.range[0] > self.range[1]:
                raise ValueError(f"param {self.name}: bad range {self.range!r}")
            if self.scale == "log" and self.range[0] <= 0:
                raise ValueError(f"param {self.name}: log scale needs positive range")
            if self.type == "int" and math.ceil(self.range[0]) > math.floor(self.range[1]):
                raise ValueError(f"param {self.name}: no integer in range {self.range!r}")
        if self.type == "choice" and len(self.range) == 0:
            raise ValueError(f"param {self.name}: empty choice set")

    # -- sampling ----------------------------------------------------------
    def sample(self, rng: np.random.Generator) -> Any:
        if self.type == "choice":
            return self.range[int(rng.integers(len(self.range)))]
        lo, hi = float(self.range[0]), float(self.range[1])
        if self.scale == "log":
            v = math.exp(rng.uniform(math.log(lo), math.log(hi)))
        else:
            v = rng.uniform(lo, hi)
        if self.type == "int":
            # round can escape fractional bounds (e.g. [0.25, 1.25] -> 0);
            # clamp to the integers inside the range
            q = int(round(v))
            q = max(q, int(math.ceil(lo)))
            q = min(q, int(math.floor(hi)))
            return q
        return float(v)

    def grid(self) -> List[Any]:
        if self.type == "choice":
            return list(self.range)
        lo, hi = float(self.range[0]), float(self.range[1])
        n = max(1, int(self.n_grid))
        if n == 1:
            pts = [0.5 * (lo + hi)]
        elif self.scale == "log":
            pts = list(np.exp(np.linspace(math.log(lo), math.log(hi), n)))
        else:
            pts = list(np.linspace(lo, hi, n))
        if self.type == "int":
            out, seen = [], set()
            for p in pts:
                q = int(round(p))
                q = max(q, int(math.ceil(lo)))
                q = min(q, int(math.floor(hi)))
                if q not in seen:
                    seen.add(q)
                    out.append(q)
            return out
        return [float(p) for p in pts]

    # -- unit-cube encoding (for GP-BO / TPE internals) ---------------------
    def to_unit(self, value: Any) -> float:
        if self.type == "choice":
            return self.range.index(value) / max(1, len(self.range) - 1) if len(self.range) > 1 else 0.0
        lo, hi = float(self.range[0]), float(self.range[1])
        v = float(value)
        if self.scale == "log":
            lo, hi, v = math.log(lo), math.log(hi), math.log(max(v, 1e-300))
        return 0.0 if hi == lo else (v - lo) / (hi - lo)

    def from_unit(self, u: float) -> Any:
        u = min(1.0, max(0.0, float(u)))
        if self.type == "choice":
            idx = int(round(u * (len(self.range) - 1)))
            return self.range[idx]
        lo, hi = float(self.range[0]), float(self.range[1])
        if self.scale == "log":
            v = math.exp(math.log(lo) + u * (math.log(hi) - math.log(lo)))
        else:
            v = lo + u * (hi - lo)
        return int(round(v)) if self.type == "int" else float(v)

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "type": self.type,
            "range": list(self.range),
            "scale": self.scale,
            "n_grid": self.n_grid,
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "ParamSpec":
        return ParamSpec(
            name=d["name"],
            type=d["type"],
            range=d["range"],
            scale=d.get("scale", "linear"),
            n_grid=int(d.get("n_grid", 3)),
        )


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    params: Sequence[ParamSpec]

    def __post_init__(self):
        names = [p.name for p in self.params]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate parameter names: {names}")

    def __len__(self) -> int:
        return len(self.params)

    def __iter__(self):
        return iter(self.params)

    def names(self) -> List[str]:
        return [p.name for p in self.params]

    def sample(self, rng: np.random.Generator) -> Dict[str, Any]:
        return {p.name: p.sample(rng) for p in self.params}

    def to_unit(self, config: Dict[str, Any]) -> np.ndarray:
        return np.array([p.to_unit(config[p.name]) for p in self.params], dtype=np.float64)

    def from_unit(self, u: np.ndarray) -> Dict[str, Any]:
        return {p.name: p.from_unit(u[i]) for i, p in enumerate(self.params)}

    def to_json(self) -> List[Dict[str, Any]]:
        return [p.to_json() for p in self.params]

    @staticmethod
    def from_json(lst: Sequence[Dict[str, Any]]) -> "SearchSpace":
        return SearchSpace(tuple(ParamSpec.from_json(d) for d in lst))
