"""Grid search.  ``n_samples`` is derived from the grid itself (paper §IV-D
uses 162 = 3^4 x 2 configurations)."""
from __future__ import annotations

import itertools
from typing import Any, Dict, Optional

from . import Proposer, register


@register("grid")
class GridProposer(Proposer):
    def __init__(self, space, **kwargs):
        super().__init__(space, **kwargs)
        axes = [p.grid() for p in space]
        self._grid = [
            {p.name: v for p, v in zip(space, combo)}
            for combo in itertools.product(*axes)
        ]
        # Grid size overrides any requested n_samples.
        self.n_samples = len(self._grid)

    def _propose(self) -> Optional[Dict[str, Any]]:
        if self.n_proposed >= len(self._grid):
            return None
        return dict(self._grid[self.n_proposed])
