"""Proposer interface (paper §III-A) + registry.

Every HPO algorithm is reduced to:

* ``get_param()``  -> next hyperparameter dict (or ``None`` == "wait": a rung /
  batch barrier is outstanding, ask again after a callback fires),
* ``update(score, job)`` -> feed one finished job's score back,
* ``finished()``   -> experiment is complete.

This is the paper's central extensibility claim — integrating a new algorithm
touches exactly one file (a subclass registered with ``@register``), which the
``benchmarks/extensibility_loc.py`` benchmark counts.

Auxiliary keys the proposer places in the config (``n_iterations``,
``hb_bracket``, ...) flow through the BasicConfig to the job and back —
the mechanism the paper uses so Hyperband can resume/extend training
(§III-A2).  ``replay(rows)`` rebuilds internal state from the tracking DB for
crash-resume; it relies only on those auxiliary keys, never on in-memory state.

Optional protocols: rung-based proposers (ASHA, Hyperband, BOHB) additionally
expose ``inflight_hook(steps_per_unit)`` returning a stateless-per-flight
early-stop rule the population engines apply *between* proposals — see
``early_stop.InFlightSuccessiveHalving``.  Lifecycle proposers (streaming
PBT) expose ``lifecycle_hook()`` returning the shared decision/registry
object (``pbt.PBTLifecycle``) the lane-refill engine and ``LaneScheduler``
consult on lane retirement and lease, so a losing member is refilled in
place as a donor-clone (compiled ``make_lane_clone``) instead of through a
host checkpoint.  The Experiment wires the hook onto targets exposing a
``lifecycle`` attribute automatically.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, List, Optional, Sequence, Type

import numpy as np

from ..search_space import SearchSpace

_REGISTRY: Dict[str, Type["Proposer"]] = {}


def register(name: str):
    def deco(cls: Type["Proposer"]) -> Type["Proposer"]:
        _REGISTRY[name.lower()] = cls
        cls.registry_name = name.lower()
        return cls
    return deco


def get_proposer_cls(name: str) -> Type["Proposer"]:
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown proposer {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def available_proposers() -> List[str]:
    return sorted(_REGISTRY)


def make_proposer(name: str, space: SearchSpace, **kwargs) -> "Proposer":
    return get_proposer_cls(name)(space=space, **kwargs)


class Proposer(abc.ABC):
    """Base class: bookkeeping shared by all algorithms."""

    registry_name = "base"

    def __init__(
        self,
        space: SearchSpace,
        n_samples: int = 100,
        seed: int = 0,
        maximize: bool = True,
        **_unused: Any,
    ):
        self.space = space
        self.n_samples = int(n_samples)
        self.maximize = bool(maximize)
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.n_proposed = 0
        self.n_updated = 0
        self.n_failed = 0
        self.history: List[Dict[str, Any]] = []  # {config, score}

    # -- core interface -------------------------------------------------------
    def get_param(self) -> Optional[Dict[str, Any]]:
        """Next config, or None to signal 'wait for outstanding jobs'."""
        if self.finished():
            return None
        cfg = self._propose()
        if cfg is not None:
            self.n_proposed += 1
        return cfg

    def get_params(self, k: int) -> List[Dict[str, Any]]:
        """Up to ``k`` configs in one call — the batched-draining protocol.

        The Experiment loop claims every free resource each pass and asks for
        that many configs at once, which is how a whole population of lanes
        (``VectorizedResourceManager`` / the sharded pool) fills per round.
        The contract:

        * the return value has **at most** ``k`` entries and may be empty;
        * draining stops at the first ``None`` from ``get_param`` — a ``None``
          mid-drain means "a barrier is outstanding" (rung/generation barrier,
          budget issued), NOT "finished"; the loop must hand back the unused
          resources and retry after a callback fires;
        * every returned config counts as *proposed*: the caller is expected
          to run each one and eventually feed ``update`` exactly once per
          config (score or failure), or the proposer's accounting will stall.

        The default loops ``get_param`` so synchronous proposers fill a whole
        population per round with no per-algorithm work.  Subclasses that can
        propose a batch more cheaply (or atomically) may override.
        """
        out: List[Dict[str, Any]] = []
        for _ in range(max(0, int(k))):
            cfg = self.get_param()
            if cfg is None:
                break
            out.append(cfg)
        return out

    def update(self, score: Optional[float], job: Any = None) -> None:
        """Feed back one finished job.  ``job.config`` carries auxiliary keys."""
        config = dict(job.config) if job is not None else {}
        if score is None:
            self.n_failed += 1
            self._on_failure(config)
        else:
            self.n_updated += 1
            s = float(score) if self.maximize else -float(score)
            self.history.append({"config": config, "score": s})
            self._on_result(config, s)

    def finished(self) -> bool:
        return (self.n_updated + self.n_failed) >= self.n_samples

    # -- crash-resume -----------------------------------------------------------
    def replay(self, rows: Sequence[Dict[str, Any]]) -> None:
        """Rebuild state from tracking-DB job rows.

        Rows still ``running`` at the crash count as *proposed* (the
        Experiment re-queues them under new job ids without consulting the
        proposer), so a resumed proposer issues exactly the remaining draws
        instead of double-issuing replacements for in-flight work.
        """
        for r in rows:
            if r.get("status") == "finished" and r.get("score") is not None:
                self.n_proposed += 1

                class _J:  # minimal duck-typed job
                    config = r["config"]

                self.update(r["score"], _J())
            elif r.get("status") in ("failed", "killed", "lost"):
                self.n_proposed += 1
                self.n_failed += 1
            elif r.get("status") == "running":
                self.n_proposed += 1

    def state_json(self) -> Dict[str, Any]:
        """JSON-able snapshot of the proposer's *draw* state, written ahead of
        each proposal batch (``TrackingDB.save_proposer_state``).  The default
        captures the numpy bit-generator state — enough for any proposer whose
        draws come from ``self.rng`` to continue the exact sequence an
        uninterrupted run would have produced.  Subclasses with extra RNGs or
        draw cursors should extend the dict (and ``load_state_json``)."""
        try:
            rng_state = self.rng.bit_generator.state
        except AttributeError:  # pragma: no cover - exotic rng
            rng_state = None
        return {"rng": rng_state, "n_proposed": self.n_proposed}

    def load_state_json(self, state: Optional[Dict[str, Any]]) -> None:
        """Restore the draw state saved by ``state_json``.  Called *after*
        ``replay`` (replay rebuilds result structures from rows; this puts the
        RNG back where the last write-ahead save left it)."""
        if not state:
            return
        rng_state = state.get("rng")
        if rng_state:
            try:
                self.rng.bit_generator.state = rng_state
            except (AttributeError, ValueError, TypeError):  # pragma: no cover
                pass

    # -- subclass hooks ---------------------------------------------------------
    @abc.abstractmethod
    def _propose(self) -> Optional[Dict[str, Any]]:
        ...

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        pass

    def _on_failure(self, config: Dict[str, Any]) -> None:
        pass

    # -- helpers -----------------------------------------------------------------
    def best(self) -> Optional[Dict[str, Any]]:
        if not self.history:
            return None
        h = max(self.history, key=lambda r: r["score"])
        return {"config": h["config"], "score": h["score"] if self.maximize else -h["score"]}


# Import submodules so @register decorators run on package import.
from . import random_search, grid_search, bayesian, tpe, hyperband, bohb, asha, pbt, eas, cmaes  # noqa: E402,F401
