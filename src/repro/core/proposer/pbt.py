"""Population-Based Training (beyond-paper addition).

Two execution modes share one exploit/explore rule:

* **generation-barriered** (default) — a population of ``population`` members
  trains in generations; after each generation the bottom ``quantile`` clones
  the top quantile's hyperparameters AND checkpoint (via the ``pbt_ckpt`` /
  ``pbt_inherit`` aux keys — the job restores the donor's weights from a host
  checkpoint) then perturbs.  Maps naturally onto the mesh-slice pool: one
  member per slice.  The barrier means the whole population idles until its
  slowest member finishes each generation.

* **streaming** (``streaming=True``) — members live in population *lanes* of
  the lane-refill engine (``repro.launch.hpo.PopulationTrial``).  Each member
  trains one round per job; when a round retires, the member's next job
  carries a **lifecycle directive**: ``keep`` (continue in place — no device
  op at all), or ``clone`` (the lane inherits a donor lane's weights AND
  optimizer state via the compiled ``make_lane_clone`` op — no ``pbt_ckpt``
  host round-trip, no generation bubble).  Exploit decisions come from an
  asynchronous quantile rule over a sliding window of member scores
  (``PBTLifecycle``), mirroring how the staggered in-flight SHA rule replaces
  Hyperband's cohort rung.  With ``sync_rounds=True`` (the default) rounds
  are gated so every member finishes round ``r`` before any round ``r+1``
  proposal is issued — decisions (and RNG draws) then match the
  generation-barriered driver exactly, which is what the equivalence tests
  and benchmarks pin; ``sync_rounds=False`` unlocks the fully asynchronous
  rule (fast members lap slow ones; the window is the only cohort).

Aux config keys planted by the streaming mode: ``pbt_member`` / ``pbt_round``
/ ``pbt_lifecycle`` (``init`` | ``keep`` | ``clone``) / ``pbt_donor`` (donor
*member* id, clone only) / ``stream`` (the member's stable data stream).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from . import Proposer, register

DIVERGED_SCORE = -1e9


def window_quantile(scores, count, quantile, xp=None):
    """``(lo, hi)`` quantile scores of a sliding window, as a pure array op.

    ``scores`` is the window as a fixed-size ring buffer (length W);
    ``count`` is how many appends it has ever absorbed, so the valid region
    is the first ``min(count, W)`` slots (a full ring is valid everywhere).
    ``lo`` is the k-th smallest and ``hi`` the k-th largest valid score with
    ``k = max(1, int(quantile * n))`` — exactly the thresholds
    ``PBTLifecycle.decide`` reads off its sorted window, but expressed
    through ``xp`` (NumPy or ``jax.numpy``) so the population engines can
    evaluate it *inside* a fused scan (``--device-rules`` with
    ``--pbt-async``): invalid slots are masked to +/-inf so they sort to the
    far ends, and both thresholds come from one sort each.
    """
    import numpy as np

    if xp is None:
        xp = np
    w = scores.shape[0]
    n = xp.minimum(xp.asarray(count), w)
    k = xp.maximum(1, (xp.asarray(quantile) * n.astype(scores.dtype))
                   .astype(xp.int32))
    valid = xp.arange(w) < n
    asc_lo = xp.sort(xp.where(valid, scores, xp.inf))
    asc_hi = xp.sort(xp.where(valid, scores, -xp.inf))
    return xp.take(asc_lo, k - 1), xp.take(asc_hi, w - k)


def perturb_config(space, cfg: Dict[str, Any], rng, factor: float) -> Dict[str, Any]:
    """The explore rule, shared by both PBT modes (their decision-for-decision
    equivalence depends on consuming the RNG identically): floats scale by
    ``factor`` (or its inverse) through the unit cube, choices resample with
    p=0.25."""
    new_cfg = dict(cfg)
    for p in space:
        if p.type == "choice":
            if rng.uniform() < 0.25:
                new_cfg[p.name] = p.sample(rng)
        else:
            f = factor if rng.uniform() < 0.5 else 1.0 / factor
            u = p.to_unit(new_cfg[p.name])
            # perturb in unit space, clamped to the cube
            new_cfg[p.name] = p.from_unit(min(1.0, max(0.0, u * f)))
    return new_cfg


class PBTLifecycle:
    """Shared PBT decision rule + lane registry + donor pins.

    One object, two threads: the *proposer* half (``note_result`` /
    ``decide``) runs on the experiment loop thread and implements the
    asynchronous exploit/explore rule over a sliding window of the last
    ``window`` member scores; the *engine* half (``bind`` / ``lane_of`` /
    ``lease_blocked`` / ``clone_done``) runs on the streaming flight worker,
    which consults it on lane retirement and lease to map directives onto
    lane-lifecycle device ops.

    Donor pinning: when ``decide`` issues a clone, the donor member is pinned
    until the engine executes the device copy (``clone_done``).  A pinned
    member's own next-round ``keep`` lease is deferred (``lease_blocked``) so
    the donor lane cannot resume training — and advance its weights — between
    the exploit decision and the copy.  Pins release on terminal failure of
    the clone job too (``abandon``), so a dead clone cannot deadlock its
    donor.
    """

    def __init__(self, space, perturb: float = 1.2, quantile: float = 0.25,
                 window: int = 8, rng=None):
        import numpy as np

        self.space = space
        self.perturb = float(perturb)
        self.quantile = float(quantile)
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self._lock = threading.Lock()
        # (member, score, round) — round is None for legacy callers; it feeds
        # the decision-lag telemetry only, never the decision rule itself
        self.window: deque = deque(maxlen=max(2, int(window)))
        self.last_score: Dict[int, float] = {}
        # decision-lag telemetry: for every exploit/explore decision at round
        # r, how stale each window entry informing it was, in rounds
        # ((r - 1) - entry_round).  Gated (sync_rounds) mode is all zeros by
        # construction; --pbt-async spreads — the `pbt_async_quality` bench
        # row histograms this to quantify what dropping the gate costs.
        self.decision_lags: List[int] = []
        # engine registry: member -> (flight epoch, lane).  A flight that dies
        # loses its device state, so a stale epoch means the member's weights
        # are gone and the engine must fall back to a fresh init.
        self._lane: Dict[int, Tuple[int, int]] = {}
        # donor pins, keyed by the clone that created them ((member, round)):
        # releasing is idempotent, so a clone that is retried after its copy
        # already ran cannot double-release its donor
        self._pins: Dict[Tuple[int, int], int] = {}
        self._wait_tokens: set = set()  # jobs counted as donor waits (once)
        self.n_clones = 0
        self.n_keeps = 0
        self.n_donor_waits = 0
        # --device-rules: the fused scan evaluates the window quantile itself
        # (window_quantile as an in-scan op) and latches a per-lane verdict at
        # the lane's budget end; the engine reports it here keyed by (member,
        # round) and decide() consumes it instead of re-deriving the bottom
        # test on the host.  Off by default — enable_device_rule() is called
        # by the driver only under --device-rules + --pbt-async.
        self.device_rule_on = False
        self._device_verdicts: Dict[Tuple[int, int], Tuple[bool, float, float]] = {}
        self.n_device_verdicts = 0

    # -- proposer side ----------------------------------------------------------
    def note_result(self, member: int, score: float, rnd: Optional[int] = None) -> None:
        with self._lock:
            self.window.append((int(member), float(score),
                                None if rnd is None else int(rnd)))
            self.last_score[int(member)] = float(score)

    def enable_device_rule(self) -> None:
        """Switch decide() to consume scan-emitted window-quantile verdicts
        (--device-rules with --pbt-async).  Rounds without a verdict — e.g. a
        member retired early by a host divergence poll — fall back to the
        host rule, so the switch degrades gracefully."""
        self.device_rule_on = True

    def window_snapshot(self) -> List[Tuple[int, float, Optional[int]]]:
        """The window's entries oldest-first, for lowering to the scan's ring
        buffer before a device-rule dispatch."""
        with self._lock:
            return list(self.window)

    def note_device_verdict(self, member: int, rnd: int, bottom: bool,
                            lo: float, hi: float) -> None:
        """Record the scan's latched verdict for the member's round ``rnd``:
        whether its end-of-round score sat in the bottom quantile of the
        device-side sliding window, plus the (lo, hi) thresholds it saw."""
        with self._lock:
            self._device_verdicts[(int(member), int(rnd))] = (
                bool(bottom), float(lo), float(hi))
            self.n_device_verdicts += 1

    def decide(self, member: int, own_cfg: Dict[str, Any],
               rnd: Optional[int] = None) -> Tuple[str, Optional[int], Dict[str, Any]]:
        """``(lifecycle, donor_member, hparams_cfg)`` for the member's next round.

        Exploit iff the member's latest score sits in the bottom ``quantile``
        of the sliding window and a distinct, finite-scored donor exists in
        the top quantile — then the donor's hyperparameters are perturbed
        (floats scaled by ``perturb`` up or down through the unit cube,
        choices resampled with p=0.25) and the donor member is pinned until
        the device copy lands.  Otherwise the member keeps its own
        hyperparameters and weights untouched.  ``rnd`` (the round being
        decided) only feeds ``decision_lags`` telemetry.
        """
        with self._lock:
            entries = list(self.window)
            my = self.last_score.get(int(member))
            verdict = (self._device_verdicts.pop((int(member), int(rnd) - 1), None)
                       if self.device_rule_on and rnd is not None else None)
        if rnd is not None:
            # staleness of the evidence behind this decision: a gated run
            # decides round r strictly from round r-1 scores (lag 0); the
            # async rule may be looking at arbitrarily old rounds
            lags = [max(0, int(rnd) - 1 - er) for _, _, er in entries
                    if er is not None]
            with self._lock:
                self.decision_lags.extend(lags)
        scores = [s for _, s, _ in entries]
        n = len(scores)
        if my is None or n < 2:
            return "keep", None, dict(own_cfg)
        k = max(1, int(self.quantile * n))
        lo = sorted(scores)[k - 1]
        # top-quantile donors: distinct members, best score first, never self,
        # never a diverged sentinel
        hi = sorted(scores, reverse=True)[k - 1]
        if verdict is not None:
            # the scan already judged this round against the window it saw at
            # the lane's budget end — its bottom-quantile bit and thresholds
            # replace the host re-derivation; donors still come from the host
            # window (the device log carries verdicts, not donor identities)
            is_bottom, _dev_lo, hi = verdict
        else:
            is_bottom = not (my > lo)
        donors: List[int] = []
        for m, s, _ in sorted(entries, key=lambda ms: -ms[1]):
            if s >= hi and s > DIVERGED_SCORE and m != member and m not in donors:
                donors.append(m)
        if not is_bottom or not donors:
            with self._lock:
                self.n_keeps += 1
            return "keep", None, dict(own_cfg)
        donor = donors[int(self.rng.integers(len(donors)))]
        new_cfg = self._perturb(self._member_cfg(donor))
        with self._lock:
            self.n_clones += 1
        return "clone", donor, new_cfg

    def pin(self, config: Dict[str, Any]) -> None:
        """Pin the clone's donor until its device copy lands (or the clone
        dies for good).  Keyed by the clone job's (member, round), so release
        is idempotent across retries."""
        donor, token = config.get("pbt_donor"), self._token(config)
        if donor is None or token is None:
            return
        with self._lock:
            self._pins[token] = int(donor)

    @staticmethod
    def _token(config: Dict[str, Any]) -> Optional[Tuple[int, int]]:
        m, r = config.get("pbt_member"), config.get("pbt_round")
        return None if m is None or r is None else (int(m), int(r))

    def _member_cfg(self, member: int) -> Dict[str, Any]:
        """Hook point: the proposer stores members' current hparams here."""
        return dict(self.member_cfgs[member])

    def _perturb(self, cfg: Dict[str, Any]) -> Dict[str, Any]:
        return perturb_config(self.space, cfg, self.rng, self.perturb)

    # -- engine side ------------------------------------------------------------
    def bind(self, member: int, lane: int, epoch: int) -> None:
        with self._lock:
            self._lane[int(member)] = (int(epoch), int(lane))

    def lane_of(self, member: int, epoch: int) -> Optional[int]:
        """The member's lane in the current flight, or None when the member's
        device state belongs to a dead flight (fall back to a fresh init)."""
        with self._lock:
            got = self._lane.get(int(member))
        if got is None or got[0] != int(epoch):
            return None
        return got[1]

    def pinned(self, member: int) -> bool:
        with self._lock:
            return int(member) in self._pins.values()

    def lease_blocked(self, config: Dict[str, Any]) -> bool:
        """True when leasing this job now would let a pinned donor's lane
        resume training before an outstanding clone copies its weights.
        ``n_donor_waits`` counts each deferred job once, however many times
        the scheduler re-polls it."""
        if config.get("pbt_lifecycle") != "keep":
            return False
        member = config.get("pbt_member")
        if member is None or not self.pinned(member):
            return False
        token = self._token(config)
        with self._lock:
            if token not in self._wait_tokens:
                self._wait_tokens.add(token)
                self.n_donor_waits += 1
        return True

    def _release(self, config: Dict[str, Any]) -> None:
        token = self._token(config)
        if token is not None:
            with self._lock:
                self._pins.pop(token, None)

    def clone_done(self, config: Dict[str, Any]) -> None:
        """The engine executed this clone's device copy: release the donor."""
        self._release(config)

    def abandon(self, config: Dict[str, Any]) -> None:
        """A clone job died for good before its copy ran: release the donor so
        its next round is not deferred forever."""
        if config.get("pbt_lifecycle") == "clone":
            self._release(config)


@register("pbt")
class PBTProposer(Proposer):
    def __init__(self, space, population: int = 8, n_generations: int = None,
                 perturb: float = 1.2, quantile: float = 0.25,
                 streaming: bool = False, window: int = 0,
                 sync_rounds: bool = True, **kwargs):
        super().__init__(space, **kwargs)
        self.population = int(population)
        self.n_generations = int(n_generations or max(1, self.n_samples // self.population))
        self.n_samples = self.population * self.n_generations
        self.perturb = float(perturb)
        self.quantile = float(quantile)
        self.members: List[Dict[str, Any]] = [self.space.sample(self.rng) for _ in range(self.population)]
        self.streaming = bool(streaming)
        # -- generation-barriered state ----------------------------------------
        self.gen = 0
        self.gen_issued: set = set()
        self.gen_results: Dict[int, float] = {}
        # -- streaming state ----------------------------------------------------
        self.sync_rounds = bool(sync_rounds)
        self.member_round = [0] * self.population
        self.member_outstanding = [False] * self.population
        # sync mode: the current round's configs, decided atomically at the
        # barrier (pins included) and handed out one get_param at a time
        self._round_queue: List[Dict[str, Any]] = []
        self._lifecycle: Optional[PBTLifecycle] = None
        if self.streaming:
            self._lifecycle = PBTLifecycle(
                space, perturb=self.perturb, quantile=self.quantile,
                window=int(window) or self.population, rng=self.rng,
            )
            self._lifecycle.member_cfgs = self.members

    def lifecycle_hook(self) -> Optional[PBTLifecycle]:
        """The engine-facing half of the streaming proposer (sibling of the
        rung proposers' ``inflight_hook``): the lane-refill engine consults it
        on lane retirement/lease to execute keep/clone directives as compiled
        lane-lifecycle ops.  None in generation-barriered mode."""
        return self._lifecycle

    # -- proposal ---------------------------------------------------------------
    def _propose(self) -> Optional[Dict[str, Any]]:
        if self.streaming:
            return self._propose_streaming()
        if self.gen >= self.n_generations:
            return None
        for m in range(self.population):
            if m not in self.gen_issued and m not in self.gen_results:
                self.gen_issued.add(m)
                cfg = dict(self.members[m])
                cfg.update(pbt_member=m, pbt_gen=self.gen, pbt_ckpt=f"m{m}")
                return cfg
        if len(self.gen_results) >= self.population:
            self._exploit_explore()
        return None  # generation barrier

    def _propose_streaming(self) -> Optional[Dict[str, Any]]:
        if self.sync_rounds:
            # decide the WHOLE round atomically at the barrier: every member's
            # directive (and every donor pin) exists before the first config
            # leaves the proposer, so no interleaving of Experiment loop
            # passes can lease a donor's next round ahead of the clone that
            # still needs its round-boundary weights
            if not self._round_queue:
                gate = min(self.member_round)
                if gate < self.n_generations:
                    # members AT the gate and not in flight: the full
                    # population in normal operation (a round only unblocks
                    # once every member finished the previous one), the
                    # not-yet-reissued remainder after a crash-resume (the
                    # outstanding members' configs ride the requeue path)
                    self._round_queue = [
                        self._decide_member(m, gate)
                        for m in range(self.population)
                        if self.member_round[m] == gate
                        and not self.member_outstanding[m]
                    ]
            if self._round_queue:
                cfg = self._round_queue.pop(0)
                self.member_outstanding[cfg["pbt_member"]] = True
                return cfg
            return None  # round barrier
        for m in range(self.population):
            r = self.member_round[m]
            if self.member_outstanding[m] or r >= self.n_generations:
                continue
            cfg = self._decide_member(m, r)
            self.member_outstanding[m] = True
            return cfg
        return None  # every ready member is in flight

    def _decide_member(self, m: int, r: int) -> Dict[str, Any]:
        if r == 0:
            lifecycle, donor, cfg = "init", None, dict(self.members[m])
        else:
            lifecycle, donor, cfg = self._lifecycle.decide(
                m, self.members[m], rnd=r)
            self.members[m] = dict(cfg)
        cfg.update(pbt_member=m, pbt_round=r, pbt_lifecycle=lifecycle, stream=m)
        if donor is not None:
            cfg["pbt_donor"] = donor
            self._lifecycle.pin(cfg)
        return cfg

    # -- results ----------------------------------------------------------------
    def _exploit_explore(self) -> None:
        ranked = sorted(self.gen_results.items(), key=lambda kv: -kv[1])
        k = max(1, int(self.quantile * self.population))
        top = [m for m, _ in ranked[:k]]
        bottom = [m for m, _ in ranked[-k:]]
        for loser in bottom:
            donor = top[int(self.rng.integers(len(top)))]
            new_cfg = perturb_config(
                self.space, self.members[donor], self.rng, self.perturb)
            new_cfg["pbt_inherit"] = f"m{donor}"  # job restores donor checkpoint
            self.members[loser] = new_cfg
        self.gen += 1
        self.gen_issued = set()
        self.gen_results = {}

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        if self.streaming:
            m, r = config.get("pbt_member"), config.get("pbt_round")
            if m is None or r is None:
                return
            self._lifecycle.note_result(m, score, rnd=int(r))
            self.member_outstanding[m] = False
            self.member_round[m] = max(self.member_round[m], int(r) + 1)
            return
        m = config.get("pbt_member")
        if m is not None and config.get("pbt_gen") == self.gen:
            self.gen_results[m] = score
            self.gen_issued.discard(m)

    def _on_failure(self, config: Dict[str, Any]) -> None:
        if self.streaming and self._lifecycle is not None:
            # a clone that will never execute must release its donor pin
            self._lifecycle.abandon(config)
        self._on_result(config, float("-inf"))

    def finished(self) -> bool:
        if self.streaming:
            return (all(r >= self.n_generations for r in self.member_round)
                    and not any(self.member_outstanding))
        return self.gen >= self.n_generations

    def replay(self, rows) -> None:
        """Rebuild state from tracking-DB rows without double-issuing a
        generation/round.

        Generation-barriered mode replays *incrementally*: each finished row
        lands in its own generation's results and ``_exploit_explore`` fires
        the moment a generation completes — exactly like the live path — so
        rows spanning several generations advance ``gen`` (and consume the
        perturbation RNG) in the same order a never-crashed run would.  Rows
        still ``running`` at the crash mark their member as issued: the
        Experiment re-queues those jobs directly, so proposing the member
        again would double-issue it.

        Streaming mode restores each member's round cursor, hyperparameters
        (the decided config is materialized in the row itself) and the score
        window; ``running`` rows mark the member outstanding.  The decision
        RNG is *not* rewound, so post-resume exploit draws may differ from the
        never-crashed run — decisions already made are preserved verbatim.
        """
        if self.streaming:
            self._replay_streaming(rows)
            return
        for r in rows:
            if r.get("status") == "finished" and r.get("score") is not None:
                cfg = r["config"]
                self.n_proposed += 1
                self.n_updated += 1
                sc = float(r["score"]) if self.maximize else -float(r["score"])
                self.history.append({"config": cfg, "score": sc})
                if cfg.get("pbt_gen") == self.gen and cfg.get("pbt_member") is not None:
                    self.gen_results[cfg.get("pbt_member")] = sc
                    # the live path advances the moment a generation completes;
                    # replay must too, or later generations' rows are dropped
                    # and the next _propose re-issues an already-run generation
                    if len(self.gen_results) >= self.population:
                        self._exploit_explore()
            elif r.get("status") in ("failed", "killed", "lost"):
                cfg = r["config"]
                self.n_proposed += 1
                self.n_failed += 1
                if cfg.get("pbt_gen") == self.gen and cfg.get("pbt_member") is not None:
                    self.gen_results[cfg.get("pbt_member")] = float("-inf")
                    if len(self.gen_results) >= self.population:
                        self._exploit_explore()
            elif r.get("status") == "running":
                # mid-flight at the crash: the Experiment re-queues this exact
                # job, so its member counts as issued for the current gen
                cfg = r["config"]
                if cfg.get("pbt_gen") == self.gen and cfg.get("pbt_member") is not None:
                    self.gen_issued.add(cfg["pbt_member"])

    def _replay_streaming(self, rows) -> None:
        for r in rows:
            cfg = r["config"]
            m, rnd = cfg.get("pbt_member"), cfg.get("pbt_round")
            if m is None or rnd is None:
                continue
            base = {k: v for k, v in cfg.items()
                    if not k.startswith("pbt_") and k not in ("job_id", "stream")}
            self.members[m] = base
            if r.get("status") == "finished" and r.get("score") is not None:
                sc = float(r["score"]) if self.maximize else -float(r["score"])
                self.n_proposed += 1
                self.n_updated += 1
                self.history.append({"config": cfg, "score": sc})
                self._lifecycle.note_result(m, sc, rnd=int(rnd))
                self.member_round[m] = max(self.member_round[m], int(rnd) + 1)
            elif r.get("status") in ("failed", "killed", "lost"):
                self.n_proposed += 1
                self.n_failed += 1
                self._lifecycle.note_result(m, float("-inf"), rnd=int(rnd))
                self.member_round[m] = max(self.member_round[m], int(rnd) + 1)
            elif r.get("status") == "running":
                # the Experiment re-queues this job; issuing the member again
                # would double-run the round
                self.member_round[m] = max(self.member_round[m], int(rnd))
                self.member_outstanding[m] = True
