"""Population-Based Training (beyond-paper addition).

A population of ``population`` members trains in generations; after each
generation the bottom quartile clones the top quartile's hyperparameters AND
checkpoint (via ``pbt_ckpt`` aux key — the job restores the donor's weights)
then perturbs.  Maps naturally onto the mesh-slice pool: one member per slice.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from . import Proposer, register


@register("pbt")
class PBTProposer(Proposer):
    def __init__(self, space, population: int = 8, n_generations: int = None,
                 perturb: float = 1.2, quantile: float = 0.25, **kwargs):
        super().__init__(space, **kwargs)
        self.population = int(population)
        self.n_generations = int(n_generations or max(1, self.n_samples // self.population))
        self.n_samples = self.population * self.n_generations
        self.perturb = float(perturb)
        self.quantile = float(quantile)
        self.members: List[Dict[str, Any]] = [self.space.sample(self.rng) for _ in range(self.population)]
        self.gen = 0
        self.gen_issued: set = set()
        self.gen_results: Dict[int, float] = {}

    def _propose(self) -> Optional[Dict[str, Any]]:
        if self.gen >= self.n_generations:
            return None
        for m in range(self.population):
            if m not in self.gen_issued and m not in self.gen_results:
                self.gen_issued.add(m)
                cfg = dict(self.members[m])
                cfg.update(pbt_member=m, pbt_gen=self.gen, pbt_ckpt=f"m{m}")
                return cfg
        if len(self.gen_results) >= self.population:
            self._exploit_explore()
        return None  # generation barrier

    def _exploit_explore(self) -> None:
        ranked = sorted(self.gen_results.items(), key=lambda kv: -kv[1])
        k = max(1, int(self.quantile * self.population))
        top = [m for m, _ in ranked[:k]]
        bottom = [m for m, _ in ranked[-k:]]
        for loser in bottom:
            donor = top[int(self.rng.integers(len(top)))]
            new_cfg = dict(self.members[donor])
            for p in self.space:
                if p.type == "choice":
                    if self.rng.uniform() < 0.25:
                        new_cfg[p.name] = p.sample(self.rng)
                else:
                    factor = self.perturb if self.rng.uniform() < 0.5 else 1.0 / self.perturb
                    u = p.to_unit(new_cfg[p.name])
                    # perturb in native space, clamp through the unit cube
                    new_cfg[p.name] = p.from_unit(min(1.0, max(0.0, u * factor)))
            new_cfg["pbt_inherit"] = f"m{donor}"  # job restores donor checkpoint
            self.members[loser] = new_cfg
        self.gen += 1
        self.gen_issued = set()
        self.gen_results = {}

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        m = config.get("pbt_member")
        if m is not None and config.get("pbt_gen") == self.gen:
            self.gen_results[m] = score
            self.gen_issued.discard(m)

    def _on_failure(self, config: Dict[str, Any]) -> None:
        self._on_result(config, float("-inf"))

    def finished(self) -> bool:
        return self.gen >= self.n_generations

    def replay(self, rows) -> None:
        for r in rows:
            if r.get("status") == "finished" and r.get("score") is not None:
                cfg = r["config"]
                self.n_proposed += 1
                self.n_updated += 1
                sc = float(r["score"]) if self.maximize else -float(r["score"])
                self.history.append({"config": cfg, "score": sc})
                if cfg.get("pbt_gen") == self.gen:
                    self.gen_results[cfg.get("pbt_member")] = sc
            elif r.get("status") in ("failed", "killed", "lost"):
                self.n_proposed += 1
                self.n_failed += 1
        if len(self.gen_results) >= self.population:
            self._exploit_explore()
