"""ASHA — Asynchronous Successive Halving (beyond-paper addition).

Hyperband's rung *barriers* waste parallel resources (exactly the Fig. 3
"last-job" effect the paper measures).  ASHA promotes asynchronously: a config
is promoted the moment it is in the top 1/eta of *completed* results at its
rung, so workers never idle at a barrier.  This is the proposer we pair with
the elastic mesh-slice pool: it tolerates stragglers and lost jobs natively.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

from . import Proposer, register


@register("asha")
class ASHAProposer(Proposer):
    def __init__(self, space, max_iter: int = 27, min_iter: int = 1, eta: float = 3.0, **kwargs):
        super().__init__(space, **kwargs)
        self.eta = float(eta)
        self.min_iter = int(min_iter)
        self.max_iter = int(max_iter)
        self.n_rungs = int(math.floor(math.log(max(max_iter / max(min_iter, 1), 1.0)) / math.log(eta))) + 1
        # rung k: results {cfg_idx: score}; promoted set
        self.rung_results: List[Dict[int, float]] = [dict() for _ in range(self.n_rungs)]
        self.promoted: List[set] = [set() for _ in range(self.n_rungs)]
        self.configs: List[Dict[str, Any]] = []
        self.outstanding = 0
        self.n_configs_target = self.n_samples  # new configs at rung 0
        # ASHA job count is dynamic; cap generously (promotions add jobs).
        self.n_samples = self.n_configs_target * self.n_rungs

    def _budget(self, rung: int) -> int:
        return min(self.max_iter, int(round(self.min_iter * self.eta ** rung)))

    def inflight_hook(self, steps_per_unit: int = 1):
        """Rung rule as an in-flight lane-truncation hook (population engines).

        Budgets/boundaries are scaled to raw train steps (``n_iterations`` is
        in budget units; a unit is ``steps_per_unit`` steps).  The hook shares
        no state with this proposer — thread-safe on the batch worker.
        """
        from .early_stop import InFlightSuccessiveHalving

        return InFlightSuccessiveHalving(
            eta=self.eta,
            min_iter=self.min_iter * steps_per_unit,
            max_iter=self.max_iter * steps_per_unit,
        )

    def _promotable(self) -> Optional[tuple]:
        for k in range(self.n_rungs - 1):
            res = self.rung_results[k]
            if not res:
                continue
            n_top = int(len(res) / self.eta)
            if n_top < 1:
                continue
            ranked = sorted(res.items(), key=lambda kv: -kv[1])
            for idx, _ in ranked[:n_top]:
                if idx not in self.promoted[k]:
                    return k, idx
        return None

    def _propose(self) -> Optional[Dict[str, Any]]:
        promo = self._promotable()
        if promo is not None:
            k, idx = promo
            self.promoted[k].add(idx)
            cfg = dict(self.configs[idx])
            cfg.update(n_iterations=self._budget(k + 1), asha_rung=k + 1,
                       asha_idx=idx, hb_key=f"a{idx}")
            self.outstanding += 1
            return cfg
        if len(self.configs) < self.n_configs_target:
            base = self.space.sample(self.rng)
            idx = len(self.configs)
            self.configs.append(base)
            cfg = dict(base)
            cfg.update(n_iterations=self._budget(0), asha_rung=0,
                       asha_idx=idx, hb_key=f"a{idx}")
            self.outstanding += 1
            return cfg
        return None  # drain

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        rung, idx = config.get("asha_rung"), config.get("asha_idx")
        if rung is not None and idx is not None:
            self.rung_results[rung][idx] = score
        self.outstanding = max(0, self.outstanding - 1)

    def _on_failure(self, config: Dict[str, Any]) -> None:
        rung, idx = config.get("asha_rung"), config.get("asha_idx")
        if rung is not None and idx is not None:
            self.rung_results[rung][idx] = -math.inf
        self.outstanding = max(0, self.outstanding - 1)

    def finished(self) -> bool:
        return (
            len(self.configs) >= self.n_configs_target
            and self.outstanding == 0
            and self._promotable() is None
        )

    def replay(self, rows) -> None:
        for r in rows:
            cfg = r["config"]
            idx = cfg.get("asha_idx")
            if idx is None:
                continue
            while len(self.configs) <= idx:
                # regenerate deterministically-shaped slot; base = cfg minus aux keys
                base = {k: v for k, v in cfg.items()
                        if k not in ("n_iterations", "asha_rung", "asha_idx", "hb_key", "job_id")}
                self.configs.append(base)
            rung = cfg.get("asha_rung", 0)
            if rung > 0:
                self.promoted[rung - 1].add(idx)
            if r.get("status") == "finished" and r.get("score") is not None:
                sc = float(r["score"]) if self.maximize else -float(r["score"])
                self.rung_results[rung][idx] = sc
                self.n_updated += 1
                self.n_proposed += 1
                self.history.append({"config": cfg, "score": sc})
            elif r.get("status") in ("failed", "killed", "lost"):
                self.rung_results[rung][idx] = -math.inf
                self.n_failed += 1
                self.n_proposed += 1
            elif r.get("status") == "running":
                # mid-flight at the crash: the Experiment re-queues it under a
                # new job id, so it stays outstanding here (its eventual result
                # decrements) and is never proposed a second time
                self.n_proposed += 1
                self.outstanding += 1
