"""Tree-structured Parzen Estimator (Bergstra et al. 2011) — Hyperopt's engine.

Observations are split at the ``gamma`` quantile into good/bad sets; each
dimension gets a 1-D Parzen (Gaussian KDE on the unit cube, categorical counts
for choices).  Candidates are drawn from the *good* density and ranked by the
density ratio l(x)/g(x).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from . import Proposer, register


def _kde_logpdf(x: np.ndarray, samples: np.ndarray, bw: float) -> np.ndarray:
    # x: (n,), samples: (m,) -> log mean_j N(x | s_j, bw^2), reflected at [0,1]
    if len(samples) == 0:
        return np.zeros_like(x)
    d = x[:, None] - samples[None, :]
    log_k = -0.5 * (d / bw) ** 2 - np.log(bw * np.sqrt(2 * np.pi))
    m = log_k.max(axis=1, keepdims=True)
    return (m + np.log(np.exp(log_k - m).mean(axis=1, keepdims=True)))[:, 0]


@register("hyperopt")
@register("tpe")
class TPEProposer(Proposer):
    def __init__(self, space, n_init: int = 10, gamma: float = 0.25,
                 n_candidates: int = 64, engine: str = "tpe", **kwargs):
        super().__init__(space, **kwargs)
        if engine != "tpe":  # paper Code 2 passes {"engine": "tpe"} through
            raise ValueError(f"hyperopt proposer supports engine='tpe', got {engine!r}")
        self.n_init = int(n_init)
        self.gamma = float(gamma)
        self.n_candidates = int(n_candidates)

    def _propose(self) -> Optional[Dict[str, Any]]:
        if self.n_proposed >= self.n_samples:
            return None
        if len(self.history) < self.n_init:
            return self.space.sample(self.rng)

        X = np.array([self.space.to_unit(h["config"]) for h in self.history])
        y = np.array([h["score"] for h in self.history])
        n_good = max(1, int(np.ceil(self.gamma * len(y))))
        order = np.argsort(-y)  # internal scores are always maximized
        good, bad = X[order[:n_good]], X[order[n_good:]]
        bw = max(0.08, 1.0 / max(2.0, np.sqrt(len(y))))

        dim = len(self.space)
        cand = np.empty((self.n_candidates, dim))
        for j in range(dim):
            centers = good[:, j]
            picks = centers[self.rng.integers(len(centers), size=self.n_candidates)]
            cand[:, j] = np.clip(picks + bw * self.rng.standard_normal(self.n_candidates), 0.0, 1.0)

        score = np.zeros(self.n_candidates)
        for j in range(dim):
            score += _kde_logpdf(cand[:, j], good[:, j], bw)
            score -= _kde_logpdf(cand[:, j], bad[:, j], bw) if len(bad) else 0.0
        return self.space.from_unit(cand[int(np.argmax(score))])
