"""BOHB (Falkner et al. 2018) — Hyperband brackets + TPE-modeled sampling.

The paper's extensibility showcase: its authors integrated BOHB with 138 new
lines against HpBandSter's 4305.  Here the integration is a Hyperband subclass
that overrides one hook (``_sample_config``) with a TPE density-ratio model
fitted on the highest budget that has enough observations.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from . import register
from .hyperband import HyperbandProposer
from .tpe import _kde_logpdf


@register("bohb")
class BOHBProposer(HyperbandProposer):
    def __init__(self, space, min_points_in_model: int = None, gamma: float = 0.25,
                 n_candidates: int = 64, **kwargs):
        # set model params BEFORE super().__init__ — bracket construction
        # already calls the _sample_config hook.
        self.min_points = int(min_points_in_model or (len(space) + 2))
        self.gamma = float(gamma)
        self.n_candidates = int(n_candidates)
        self.history = []  # _sample_config may consult it during bracket build
        super().__init__(space, **kwargs)

    def _sample_config(self) -> Dict[str, Any]:
        obs = self._observations_at_best_budget()
        if len(obs) < self.min_points:
            return self.space.sample(self.rng)
        X = np.array([self.space.to_unit(c) for c, _ in obs])
        y = np.array([s for _, s in obs])
        n_good = max(1, int(np.ceil(self.gamma * len(y))))
        order = np.argsort(-y)
        good, bad = X[order[:n_good]], X[order[n_good:]]
        bw = max(0.08, 1.0 / max(2.0, np.sqrt(len(y))))
        dim = len(self.space)
        cand = np.empty((self.n_candidates, dim))
        for j in range(dim):
            centers = good[:, j]
            picks = centers[self.rng.integers(len(centers), size=self.n_candidates)]
            cand[:, j] = np.clip(picks + bw * self.rng.standard_normal(self.n_candidates), 0.0, 1.0)
        score = np.zeros(self.n_candidates)
        for j in range(dim):
            score += _kde_logpdf(cand[:, j], good[:, j], bw)
            if len(bad):
                score -= _kde_logpdf(cand[:, j], bad[:, j], bw)
        return self.space.from_unit(cand[int(np.argmax(score))])

    def _observations_at_best_budget(self):
        """(config, score) pairs at the largest budget with >= min_points obs."""
        by_budget: Dict[int, list] = {}
        for h in self.history:
            b = int(h["config"].get("n_iterations", 0))
            by_budget.setdefault(b, []).append((h["config"], h["score"]))
        for b in sorted(by_budget, reverse=True):
            if len(by_budget[b]) >= self.min_points:
                return by_budget[b]
        # fall back to pooling everything
        return [(h["config"], h["score"]) for h in self.history]
