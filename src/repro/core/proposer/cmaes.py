"""CMA-ES proposer (beyond-paper addition — the paper's intro cites
evolutionary tuning [Friedrichs & Igel 2005] as a major HPO family).

Generation-synchronous (μ/μ_w, λ)-CMA-ES in the search space's unit cube:
propose λ offspring, wait for all scores (same barrier pattern as PBT/EAS),
then update the mean with the weighted top-μ, adapt the step size via
cumulative path length, and adapt a diagonal covariance (sep-CMA — full
covariance buys little at the ≤10 dims typical of HPO and diagonal keeps
the update O(d)).  Choice dims ride along through the unit-cube encoding.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import numpy as np

from . import Proposer, register


@register("cmaes")
@register("evolution")
class CMAESProposer(Proposer):
    def __init__(self, space, popsize: int = 0, sigma0: float = 0.3, **kwargs):
        super().__init__(space, **kwargs)
        d = max(len(space), 1)
        self.lam = int(popsize) or (4 + int(3 * math.log(d)))
        self.mu = self.lam // 2
        # log-linear recombination weights
        w = np.log(self.mu + 0.5) - np.log(np.arange(1, self.mu + 1))
        self.w = w / w.sum()
        self.mu_eff = 1.0 / float((self.w ** 2).sum())
        # step-size / covariance time constants (Hansen's defaults, diag variant)
        self.c_sigma = (self.mu_eff + 2) / (d + self.mu_eff + 5)
        self.d_sigma = 1 + 2 * max(0.0, math.sqrt((self.mu_eff - 1) / (d + 1)) - 1) + self.c_sigma
        self.c_c = (4 + self.mu_eff / d) / (d + 4 + 2 * self.mu_eff / d)
        self.c_1 = 2 / ((d + 1.3) ** 2 + self.mu_eff)
        self.c_mu = min(1 - self.c_1, 2 * (self.mu_eff - 2 + 1 / self.mu_eff) / ((d + 2) ** 2 + self.mu_eff))
        self.chi_n = math.sqrt(d) * (1 - 1 / (4 * d) + 1 / (21 * d * d))

        self.d = d
        self.mean = np.full(d, 0.5)
        self.sigma = float(sigma0)
        self.diag_c = np.ones(d)          # diagonal covariance
        self.p_sigma = np.zeros(d)
        self.p_c = np.zeros(d)
        self.gen = 0
        self.n_generations = max(1, self.n_samples // self.lam)
        self.n_samples = self.lam * self.n_generations
        self.offspring: List[np.ndarray] = []
        self.gen_results: Dict[int, float] = {}

    def _propose(self) -> Optional[Dict[str, Any]]:
        if self.gen >= self.n_generations:
            return None
        if len(self.offspring) < self.lam:
            z = self.rng.standard_normal(self.d)
            y = np.sqrt(self.diag_c) * z
            u = np.clip(self.mean + self.sigma * y, 0.0, 1.0)
            idx = len(self.offspring)
            self.offspring.append(u)
            cfg = self.space.from_unit(u)
            cfg.update(cma_gen=self.gen, cma_idx=idx)
            return cfg
        if len(self.gen_results) >= self.lam:
            self._update()
            return self._propose()
        return None  # generation barrier

    def _update(self) -> None:
        ranked = sorted(self.gen_results.items(), key=lambda kv: -kv[1])
        elite = [self.offspring[i] for i, _ in ranked[: self.mu]]
        old_mean = self.mean
        self.mean = np.clip(sum(w * e for w, e in zip(self.w, elite)), 0.0, 1.0)
        y_w = (self.mean - old_mean) / max(self.sigma, 1e-12)

        c_inv_sqrt = 1.0 / np.sqrt(np.maximum(self.diag_c, 1e-12))
        self.p_sigma = (1 - self.c_sigma) * self.p_sigma + math.sqrt(
            self.c_sigma * (2 - self.c_sigma) * self.mu_eff
        ) * c_inv_sqrt * y_w
        self.sigma *= math.exp(
            (self.c_sigma / self.d_sigma)
            * (np.linalg.norm(self.p_sigma) / self.chi_n - 1)
        )
        self.sigma = float(np.clip(self.sigma, 1e-6, 1.0))

        h_sigma = float(
            np.linalg.norm(self.p_sigma)
            / math.sqrt(1 - (1 - self.c_sigma) ** (2 * (self.gen + 1)))
            < (1.4 + 2 / (self.d + 1)) * self.chi_n
        )
        self.p_c = (1 - self.c_c) * self.p_c + h_sigma * math.sqrt(
            self.c_c * (2 - self.c_c) * self.mu_eff
        ) * y_w
        rank_mu = np.zeros(self.d)
        for w, e in zip(self.w, elite):
            ye = (e - old_mean) / max(self.sigma, 1e-12)
            rank_mu += w * ye * ye
        self.diag_c = (
            (1 - self.c_1 - self.c_mu) * self.diag_c
            + self.c_1 * self.p_c * self.p_c
            + self.c_mu * rank_mu
        )
        self.diag_c = np.clip(self.diag_c, 1e-8, 1e4)

        self.gen += 1
        self.offspring, self.gen_results = [], {}

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        if config.get("cma_gen") == self.gen:
            self.gen_results[config["cma_idx"]] = score

    def _on_failure(self, config: Dict[str, Any]) -> None:
        self._on_result(config, float("-inf"))
