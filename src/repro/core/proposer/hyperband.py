"""Hyperband (Li et al. 2018) — bandit-based budget allocation.

Auxiliary keys placed into each job's BasicConfig — ``n_iterations`` (budget),
``hb_bracket`` / ``hb_rung`` / ``hb_idx`` (position) and ``hb_key`` (stable
checkpoint key so jobs can resume a promoted config's training) — are exactly
the mechanism the paper describes in §III-A1/§III-A2 for Hyperband support.
Crash-resume rebuilds rung tables from these keys alone (``replay``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import numpy as np

from . import Proposer, register


class _Rung:
    def __init__(self, size: int, budget: int):
        self.size = size              # how many configs run at this rung
        self.budget = budget          # n_iterations for this rung
        self.alive: List[int] = []    # config indices admitted to this rung
        self.issued: set = set()
        self.results: Dict[int, float] = {}

    def complete(self) -> bool:
        return len(self.results) >= len(self.alive) > 0


class _Bracket:
    def __init__(self, s: int, s_max: int, max_iter: int, eta: float, sampler, min_iter: int):
        self.s = s
        n = int(math.ceil((s_max + 1) / (s + 1) * eta ** s))
        r = max(min_iter, max_iter * eta ** (-s))
        self.base_configs = [sampler() for _ in range(n)]
        self.rungs: List[_Rung] = []
        for i in range(s + 1):
            n_i = max(1, int(n * eta ** (-i)))
            r_i = min(max_iter, int(round(r * eta ** i)))
            self.rungs.append(_Rung(n_i, max(min_iter, r_i)))
        self.rungs[0].alive = list(range(n))
        self.cur = 0

    def done(self) -> bool:
        return self.cur > self.s

    def total_jobs(self) -> int:
        return sum(r.size for r in self.rungs)


@register("hyperband")
class HyperbandProposer(Proposer):
    def __init__(self, space, max_iter: int = 27, min_iter: int = 1, eta: float = 3.0, **kwargs):
        super().__init__(space, **kwargs)
        self.max_iter = int(max_iter)
        self.min_iter = int(min_iter)
        self.eta = float(eta)
        self.s_max = int(math.floor(math.log(max(self.max_iter / max(self.min_iter, 1), 1.0)) / math.log(eta)))
        self.brackets = [
            _Bracket(s, self.s_max, self.max_iter, eta, self._sample_config, self.min_iter)
            for s in range(self.s_max, -1, -1)
        ]
        # Hyperband defines its own job count; override requested n_samples.
        self.n_samples = sum(b.total_jobs() for b in self.brackets)

    # Hook BOHB overrides to bias sampling with a model.
    def _sample_config(self) -> Dict[str, Any]:
        return self.space.sample(self.rng)

    def inflight_hook(self, steps_per_unit: int = 1):
        """Rung rule as an in-flight lane-truncation hook (population engines);
        see ``ASHAProposer.inflight_hook``."""
        from .early_stop import InFlightSuccessiveHalving

        return InFlightSuccessiveHalving(
            eta=self.eta,
            min_iter=self.min_iter * steps_per_unit,
            max_iter=self.max_iter * steps_per_unit,
        )

    def _active_bracket(self) -> Optional[_Bracket]:
        for b in self.brackets:
            if not b.done():
                return b
        return None

    def _propose(self) -> Optional[Dict[str, Any]]:
        b = self._active_bracket()
        while b is not None:
            rung = b.rungs[b.cur]
            for idx in rung.alive:
                if idx not in rung.issued and idx not in rung.results:
                    rung.issued.add(idx)
                    cfg = dict(b.base_configs[idx])
                    cfg.update(
                        n_iterations=rung.budget,
                        hb_bracket=b.s,
                        hb_rung=b.cur,
                        hb_idx=idx,
                        hb_key=f"b{b.s}c{idx}",
                    )
                    return cfg
            if rung.complete():
                self._promote(b)
                b = self._active_bracket()
                continue
            return None  # rung barrier: wait for callbacks
        return None

    def _promote(self, b: _Bracket) -> None:
        rung = b.rungs[b.cur]
        b.cur += 1
        if b.cur > b.s:
            return
        nxt = b.rungs[b.cur]
        ranked = sorted(rung.results.items(), key=lambda kv: -kv[1])
        nxt.alive = [idx for idx, _ in ranked[: nxt.size]]

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        self._record(config, score)

    def _on_failure(self, config: Dict[str, Any]) -> None:
        self._record(config, -math.inf)

    def _record(self, config: Dict[str, Any], score: float) -> None:
        s, rung_i, idx = config.get("hb_bracket"), config.get("hb_rung"), config.get("hb_idx")
        if s is None:
            return
        for b in self.brackets:
            if b.s == s:
                b.rungs[rung_i].results[idx] = score
                b.rungs[rung_i].issued.discard(idx)
                return

    def finished(self) -> bool:
        return all(b.done() for b in self.brackets)

    def replay(self, rows) -> None:
        # Re-seed sampling so base_configs regenerate identically, then replay
        # finished rows through the aux keys. Mid-flight rows re-issue naturally.
        for r in rows:
            if r.get("status") == "finished" and r.get("score") is not None:
                self.n_proposed += 1
                sc = float(r["score"]) if self.maximize else -float(r["score"])
                self.n_updated += 1
                self.history.append({"config": r["config"], "score": sc})
                self._record(r["config"], sc)
            elif r.get("status") in ("failed", "killed", "lost"):
                self.n_proposed += 1
                self.n_failed += 1
                self._record(r["config"], -math.inf)
        # advance through any rungs completed before the crash
        for b in self.brackets:
            while not b.done() and b.rungs[b.cur].complete():
                self._promote(b)
