"""EAS-style NAS proposer (Cai et al. 2018, paper §V).

The paper wraps EAS's RL meta-controller as a Proposer: each *episode* the
controller derives K child architectures from the incumbent by net2net
morphisms (WIDEN a conv layer / DEEPEN by inserting an identity layer), runs
them as jobs, and uses the reported accuracies as reward to update its policy
before committing to the best child.  Weight reuse happens job-side via the
``arch_parent`` aux key (function-preserving morphisms => children start from
parent weights; see train/cnn.py morphism init).

The controller here is a compact softmax-preference policy (REINFORCE on
operation logits) rather than the original bidirectional-LSTM — the *framework
integration* (controller <-> jobs synchronization, which is what the paper
demonstrates) is identical.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

import numpy as np

from . import Proposer, register

_OPS = ("widen", "deepen")


def encode_arch(arch: Dict[str, Any]) -> str:
    return json.dumps(arch, sort_keys=True)


def default_arch() -> Dict[str, Any]:
    # the paper's §IV demo net: 2 conv + 2 fc
    return {"conv": [[16, 3], [32, 3]], "fc": 128}


@register("eas")
class EASProposer(Proposer):
    def __init__(self, space=None, n_episodes: int = 4, children_per_episode: int = 4,
                 lr: float = 0.5, max_layers: int = 6, max_filters: int = 256, **kwargs):
        # NAS explores architectures, not the numeric space; space may be empty.
        from ..search_space import SearchSpace
        super().__init__(space if space is not None else SearchSpace(()), **kwargs)
        self.n_episodes = int(n_episodes)
        self.K = int(children_per_episode)
        self.n_samples = self.n_episodes * self.K + 1  # +1 incumbent eval
        self.lr = float(lr)
        self.max_layers = int(max_layers)
        self.max_filters = int(max_filters)
        self.incumbent = default_arch()
        self.incumbent_score: Optional[float] = None
        self.episode = 0
        self.ep_children: List[Dict[str, Any]] = []
        self.ep_issued = 0
        self.ep_results: Dict[int, float] = {}
        # policy: preference logits over morphism ops
        self.op_logits = np.zeros(len(_OPS))
        self._baseline = 0.0
        self._pending_incumbent = True

    # -- morphisms -------------------------------------------------------------
    def _morph(self, arch: Dict[str, Any]) -> tuple:
        probs = np.exp(self.op_logits - self.op_logits.max())
        probs /= probs.sum()
        op = _OPS[int(self.rng.choice(len(_OPS), p=probs))]
        child = json.loads(json.dumps(arch))
        convs = child["conv"]
        if op == "widen" or len(convs) >= self.max_layers:
            li = int(self.rng.integers(len(convs)))
            convs[li][0] = min(self.max_filters, convs[li][0] * 2)
            op = "widen"
        else:
            li = int(self.rng.integers(len(convs)))
            # identity-initialized layer: same width as predecessor
            convs.insert(li + 1, [convs[li][0], 3])
        return child, op

    # -- proposer API ------------------------------------------------------------
    def _propose(self) -> Optional[Dict[str, Any]]:
        if self._pending_incumbent:
            self._pending_incumbent = False
            return {"arch": encode_arch(self.incumbent), "arch_parent": "", "eas_role": "incumbent"}
        if self.incumbent_score is None:
            return None  # wait for incumbent eval
        if self.episode >= self.n_episodes:
            return None
        if len(self.ep_children) < self.K:
            child, op = self._morph(self.incumbent)
            idx = len(self.ep_children)
            self.ep_children.append({"arch": child, "op": op})
            return {
                "arch": encode_arch(child),
                "arch_parent": encode_arch(self.incumbent),
                "eas_role": "child",
                "eas_episode": self.episode,
                "eas_idx": idx,
                "eas_op": op,
            }
        if len(self.ep_results) >= self.K:
            self._end_episode()
            return self._propose()
        return None  # episode barrier

    def _end_episode(self) -> None:
        # REINFORCE: advantage = child score - EMA baseline, applied to op logits
        for idx, score in self.ep_results.items():
            op = self.ep_children[idx]["op"]
            adv = score - self._baseline
            self.op_logits[_OPS.index(op)] += self.lr * adv
            self._baseline = 0.9 * self._baseline + 0.1 * score
        best_idx = max(self.ep_results, key=self.ep_results.get)
        if self.ep_results[best_idx] >= (self.incumbent_score or -np.inf):
            self.incumbent = self.ep_children[best_idx]["arch"]
            self.incumbent_score = self.ep_results[best_idx]
        self.episode += 1
        self.ep_children, self.ep_results = [], {}

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        if config.get("eas_role") == "incumbent":
            self.incumbent_score = score
        elif config.get("eas_episode") == self.episode:
            self.ep_results[config.get("eas_idx")] = score

    def _on_failure(self, config: Dict[str, Any]) -> None:
        self._on_result(config, float("-inf"))

    def finished(self) -> bool:
        return self.episode >= self.n_episodes and self.incumbent_score is not None

    def best(self) -> Optional[Dict[str, Any]]:
        if self.incumbent_score is None:
            return None
        return {"config": {"arch": encode_arch(self.incumbent)}, "score": self.incumbent_score}
