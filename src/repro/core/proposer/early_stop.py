"""In-flight successive halving — early-stop decisions *inside* a population.

The rung-based proposers (ASHA, Hyperband) normally act only between jobs: a
config runs its whole ``n_iterations`` budget, reports, and the proposer then
decides whether it earns a promotion.  On the population engines that is
wasteful — all K lanes of a flight stay busy until the *longest* budget
finishes even when most lanes are clearly losing.

``InFlightSuccessiveHalving`` moves the rung rule into the flight.  The
population driver (``PopulationTrial.run_population``) calls the hook at every
rung boundary with each lane's current loss; lanes outside the top ``1/eta``
of still-active lanes get their traced ``hp.total_steps`` budget truncated to
the current step, which freezes them in the next population step **without a
recompile** (the budget is a traced leaf).  The host loop ends as soon as the
surviving max budget is reached, so the flush returns early and the freed
lanes go back to Algorithm 1 for the next batch — mid-flight lane reuse.

The hook is deliberately *stateless across flights* and shares nothing with
the proposer instance that spawned it (``ASHAProposer.inflight_hook()``), so
it is safe to call from the resource manager's batch worker thread while the
proposer keeps running on the experiment loop thread.  Truncated lanes report
the loss at their truncation step — ordinary early-stop semantics: the score
the proposer sees is simply measured at a smaller budget.
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np


class InFlightSuccessiveHalving:
    """Rung-boundary lane truncation with reduction factor ``eta``.

    ``boundaries`` is the set of step counts at which the rule fires:
    ``min_iter * eta**k`` for every rung below ``max_iter``.  At a boundary,
    every lane that reached it (budget >= step, not diverged, not padding) is
    ranked by current loss — including lanes whose budget *ends* here, exactly
    like ASHA compares rung completers against promotions passing through.
    The top ``ceil(n / eta)`` keep their budgets; ranked lanes below the cut
    that still had budget left are truncated to the boundary step.  Diverged
    lanes lose their remaining budget outright (they can never advance), so a
    flight of frozen lanes does not keep the devices busy.  Lanes never gain
    budget — promotions remain the proposer's decision between flights.
    """

    def __init__(self, eta: float = 3.0, min_iter: int = 1, max_iter: int = 27):
        self.eta = float(eta)
        self.min_iter = max(1, int(min_iter))
        self.max_iter = int(max_iter)
        n_rungs = int(
            math.floor(math.log(max(self.max_iter / self.min_iter, 1.0))
                       / math.log(self.eta))
        ) + 1
        self.boundaries = sorted(
            {
                min(self.max_iter, int(round(self.min_iter * self.eta ** k)))
                for k in range(n_rungs)
                if int(round(self.min_iter * self.eta ** k)) < self.max_iter
            }
        )
        # across all flights, for tests/telemetry: lanes cut by the rung rule
        # vs dead budget reclaimed from diverged lanes (a different mechanism)
        self.n_truncated = 0
        self.n_reclaimed = 0
        # per-rung loss history for the staggered (lane-refill) rule: every
        # loss ever observed at that boundary, across all lanes and flights
        self._rung_history: dict = {}

    def __call__(
        self,
        step: int,
        losses: Sequence[float],
        budgets: Sequence[float],
        diverged: Sequence[bool],
    ) -> np.ndarray:
        """Return the (possibly truncated) per-lane budgets after ``step``.

        ``losses`` are each lane's most recent applied-step losses
        (``pstate["last_loss"]``); padding lanes arrive with budget 0 and are
        never considered active.
        """
        budgets = np.asarray(budgets, np.float64).copy()
        losses = np.asarray(losses, np.float64)
        diverged = np.asarray(diverged, bool)
        if step not in self.boundaries:
            return budgets
        # a diverged lane's remaining budget is dead weight — reclaim it so an
        # all-frozen flight ends instead of stepping masked no-ops
        dead = diverged & (budgets > step)
        budgets[dead] = step
        self.n_reclaimed += int(dead.sum())
        ranked_mask = (budgets >= step) & (budgets > 0) & ~diverged & np.isfinite(losses)
        n_ranked = int(ranked_mask.sum())
        n_keep = int(math.ceil(n_ranked / self.eta))
        if n_ranked <= 1 or n_keep >= n_ranked:
            return budgets
        idx = np.flatnonzero(ranked_mask)
        ranked = idx[np.argsort(losses[idx])]  # ascending loss = best first
        cut = [i for i in ranked[n_keep:] if budgets[i] > step]
        budgets[cut] = step
        self.n_truncated += len(cut)
        return budgets

    def observe(
        self,
        local_steps: Sequence[float],
        losses: Sequence[float],
        budgets: Sequence[float],
        diverged: Sequence[bool],
    ) -> np.ndarray:
        """Staggered-lane variant for the continuous refill engine.

        With lane refill, lanes of one flight sit at *different* local steps
        (a refilled lane restarted its own step 0 mid-flight), so there is no
        synchronized cohort to rank at a boundary.  This is exactly the
        asynchronous-SHA setting: a lane reaching rung ``b`` is compared
        against the **history** of losses ever recorded at ``b`` — it keeps
        its budget only while inside the top ``1/eta`` of that history, else
        it is truncated to ``b``.  Early observations are optimistic (a lane
        with few predecessors always survives), matching ASHA's eager
        promotions; the history spans refills and flights, mirroring how ASHA
        rungs accumulate across the whole experiment.  The refill engine
        aligns its dispatch-chunk boundaries to these rung steps, so a lane
        is observed at *exactly* its boundary whether the flight advances one
        step or one fused chunk per device call.

        ``local_steps``/``budgets`` are lane-local; idle lanes carry budget 0
        and are skipped.  Diverged lanes are skipped too — the refill engine
        retires them directly (their budget is dead either way).
        """
        budgets = np.asarray(budgets, np.float64).copy()
        local_steps = np.asarray(local_steps, np.float64)
        losses = np.asarray(losses, np.float64)
        diverged = np.asarray(diverged, bool)
        for lane in np.flatnonzero((budgets > 0) & ~diverged):
            st = int(local_steps[lane])
            if st not in self.boundaries or st != local_steps[lane]:
                continue
            if not np.isfinite(losses[lane]):
                continue
            hist = self._rung_history.setdefault(st, [])
            loss = float(losses[lane])
            hist.append(loss)
            n_keep = int(math.ceil(len(hist) / self.eta))
            rank = sum(1 for x in hist if x < loss)  # ties keep the lane
            if rank >= n_keep and budgets[lane] > st:
                budgets[lane] = float(st)
                self.n_truncated += 1
        return budgets
