"""In-flight successive halving — early-stop decisions *inside* a population.

The rung-based proposers (ASHA, Hyperband) normally act only between jobs: a
config runs its whole ``n_iterations`` budget, reports, and the proposer then
decides whether it earns a promotion.  On the population engines that is
wasteful — all K lanes of a flight stay busy until the *longest* budget
finishes even when most lanes are clearly losing.

``InFlightSuccessiveHalving`` moves the rung rule into the flight.  The
population driver (``PopulationTrial.run_population``) calls the hook at every
rung boundary with each lane's current loss; lanes outside the top ``1/eta``
of still-active lanes get their traced ``hp.total_steps`` budget truncated to
the current step, which freezes them in the next population step **without a
recompile** (the budget is a traced leaf).  The host loop ends as soon as the
surviving max budget is reached, so the flush returns early and the freed
lanes go back to Algorithm 1 for the next batch — mid-flight lane reuse.

The hook is deliberately *stateless across flights* and shares nothing with
the proposer instance that spawned it (``ASHAProposer.inflight_hook()``), so
it is safe to call from the resource manager's batch worker thread while the
proposer keeps running on the experiment loop thread.  Truncated lanes report
the loss at their truncation step — ordinary early-stop semantics: the score
the proposer sees is simply measured at a smaller budget.
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np


class DeviceRuleSpec:
    """Numpy lowering of a rung rule for the in-scan device twin.

    ``InFlightSuccessiveHalving.device_rule()`` returns one of these: the
    rule's configuration (``boundaries``, ``eta``) as plain arrays that the
    population engines can carry as extra ``lax.scan`` state, plus the
    host-sync pair ``lower_history`` / ``absorb_history`` that moves the
    staggered rule's per-rung loss history between the hook's Python dict
    and the fixed-capacity device arrays around each fused dispatch.  The
    spec holds a reference to its hook so truncation counters reconstructed
    from device results land back on the object tests and telemetry read.
    """

    def __init__(self, hook: "InFlightSuccessiveHalving"):
        self.hook = hook
        self.eta = np.float32(hook.eta)
        self.boundaries = np.asarray(hook.boundaries, np.float32)

    def lower_history(self, capacity: int):
        """``(hist f32[B, capacity] (+inf padded), counts i32[B])`` from the
        hook's per-rung history.  ``capacity`` must cover the largest rung's
        current length plus every append the next dispatch can make (at most
        one per lane per rung)."""
        b = len(self.hook.boundaries)
        hist = np.full((b, int(capacity)), np.inf, np.float32)
        counts = np.zeros((b,), np.int32)
        for bi, bnd in enumerate(self.hook.boundaries):
            h = self.hook._rung_history.get(bnd, [])
            if len(h) > capacity:
                raise ValueError(
                    f"rung {bnd} history ({len(h)}) exceeds capacity {capacity}")
            counts[bi] = len(h)
            hist[bi, : len(h)] = h
        return hist, counts

    def absorb_history(self, hist, counts) -> None:
        """Write device-side history arrays back into the hook's dict, so host
        rules (or a later host-rule flight) continue from the same state."""
        hist = np.asarray(hist)
        counts = np.asarray(counts)
        for bi, bnd in enumerate(self.hook.boundaries):
            c = int(counts[bi])
            self.hook._rung_history[bnd] = [float(x) for x in hist[bi, :c]]

    def absorb_cuts(self, old_budgets, new_budgets, diverged) -> None:
        """Reconstruct the hook's counters from a dispatch's budget delta:
        a shrunk budget on a diverged lane was reclaimed, on a live lane it
        was a rung cut."""
        old = np.asarray(old_budgets, np.float64)
        new = np.asarray(new_budgets, np.float64)
        div = np.asarray(diverged, bool)
        shrunk = new < old
        self.hook.n_reclaimed += int((shrunk & div).sum())
        self.hook.n_truncated += int((shrunk & ~div).sum())


class InFlightSuccessiveHalving:
    """Rung-boundary lane truncation with reduction factor ``eta``.

    ``boundaries`` is the set of step counts at which the rule fires:
    ``min_iter * eta**k`` for every rung below ``max_iter``.  At a boundary,
    every lane that reached it (budget >= step, not diverged, not padding) is
    ranked by current loss — including lanes whose budget *ends* here, exactly
    like ASHA compares rung completers against promotions passing through.
    The top ``ceil(n / eta)`` keep their budgets; ranked lanes below the cut
    that still had budget left are truncated to the boundary step.  Diverged
    lanes lose their remaining budget outright (they can never advance), so a
    flight of frozen lanes does not keep the devices busy.  Lanes never gain
    budget — promotions remain the proposer's decision between flights.
    """

    def __init__(self, eta: float = 3.0, min_iter: int = 1, max_iter: int = 27):
        self.eta = float(eta)
        self.min_iter = max(1, int(min_iter))
        self.max_iter = int(max_iter)
        n_rungs = int(
            math.floor(math.log(max(self.max_iter / self.min_iter, 1.0))
                       / math.log(self.eta))
        ) + 1
        self.boundaries = sorted(
            {
                min(self.max_iter, int(round(self.min_iter * self.eta ** k)))
                for k in range(n_rungs)
                if int(round(self.min_iter * self.eta ** k)) < self.max_iter
            }
        )
        # across all flights, for tests/telemetry: lanes cut by the rung rule
        # vs dead budget reclaimed from diverged lanes (a different mechanism)
        self.n_truncated = 0
        self.n_reclaimed = 0
        # per-rung loss history for the staggered (lane-refill) rule: every
        # loss ever observed at that boundary, across all lanes and flights
        self._rung_history: dict = {}

    def __call__(
        self,
        step: int,
        losses: Sequence[float],
        budgets: Sequence[float],
        diverged: Sequence[bool],
    ) -> np.ndarray:
        """Return the (possibly truncated) per-lane budgets after ``step``.

        ``losses`` are each lane's most recent applied-step losses
        (``pstate["last_loss"]``); padding lanes arrive with budget 0 and are
        never considered active.
        """
        budgets = np.asarray(budgets, np.float64).copy()
        losses = np.asarray(losses, np.float64)
        diverged = np.asarray(diverged, bool)
        if step not in self.boundaries:
            return budgets
        # a diverged lane's remaining budget is dead weight — reclaim it so an
        # all-frozen flight ends instead of stepping masked no-ops
        dead = diverged & (budgets > step)
        budgets[dead] = step
        self.n_reclaimed += int(dead.sum())
        ranked_mask = (budgets >= step) & (budgets > 0) & ~diverged & np.isfinite(losses)
        n_ranked = int(ranked_mask.sum())
        n_keep = int(math.ceil(n_ranked / self.eta))
        if n_ranked <= 1 or n_keep >= n_ranked:
            return budgets
        idx = np.flatnonzero(ranked_mask)
        # ascending loss = best first; stable: ties keep the lower lane index
        # (the device twin's pairwise rank reproduces exactly this order)
        ranked = idx[np.argsort(losses[idx], kind="stable")]
        cut = [i for i in ranked[n_keep:] if budgets[i] > step]
        budgets[cut] = step
        self.n_truncated += len(cut)
        return budgets

    def observe(
        self,
        local_steps: Sequence[float],
        losses: Sequence[float],
        budgets: Sequence[float],
        diverged: Sequence[bool],
    ) -> np.ndarray:
        """Staggered-lane variant for the continuous refill engine.

        With lane refill, lanes of one flight sit at *different* local steps
        (a refilled lane restarted its own step 0 mid-flight), so there is no
        synchronized cohort to rank at a boundary.  This is exactly the
        asynchronous-SHA setting: a lane reaching rung ``b`` is compared
        against the **history** of losses ever recorded at ``b`` — it keeps
        its budget only while inside the top ``1/eta`` of that history, else
        it is truncated to ``b``.  Early observations are optimistic (a lane
        with few predecessors always survives), matching ASHA's eager
        promotions; the history spans refills and flights, mirroring how ASHA
        rungs accumulate across the whole experiment.  The refill engine
        aligns its dispatch-chunk boundaries to these rung steps, so a lane
        is observed at *exactly* its boundary whether the flight advances one
        step or one fused chunk per device call.

        ``local_steps``/``budgets`` are lane-local; idle lanes carry budget 0
        and are skipped.  Diverged lanes are skipped too — the refill engine
        retires them directly (their budget is dead either way).
        """
        budgets = np.asarray(budgets, np.float64).copy()
        local_steps = np.asarray(local_steps, np.float64)
        losses = np.asarray(losses, np.float64)
        diverged = np.asarray(diverged, bool)
        for lane in np.flatnonzero((budgets > 0) & ~diverged):
            st = int(local_steps[lane])
            if st not in self.boundaries or st != local_steps[lane]:
                continue
            if not np.isfinite(losses[lane]):
                continue
            hist = self._rung_history.setdefault(st, [])
            loss = float(losses[lane])
            hist.append(loss)
            n_keep = int(math.ceil(len(hist) / self.eta))
            rank = sum(1 for x in hist if x < loss)  # ties keep the lane
            if rank >= n_keep and budgets[lane] > st:
                budgets[lane] = float(st)
                self.n_truncated += 1
        return budgets

    def device_rule(self) -> DeviceRuleSpec:
        """Lower this rule for in-scan evaluation — the device twin of
        ``inflight_hook()``.

        The returned spec carries ``boundaries``/``eta`` as arrays; the
        population engines evaluate the same cohort (``__call__``) and
        staggered (``observe``) semantics as pure vectorized functions of the
        scan-carried budgets and loss histories
        (``repro.train.population.cohort_rule_update`` /
        ``staggered_rule_update``), so a fused chunk truncates lanes at rung
        boundaries without returning to the host.
        """
        return DeviceRuleSpec(self)
