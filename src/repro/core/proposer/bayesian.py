"""Spearmint-style Gaussian-process Bayesian optimization (Snoek et al. 2012).

Pure-numpy GP (Matérn 5/2 on the unit cube, Cholesky solve) + Expected
Improvement, maximized over a random candidate sweep.  Parallel proposals use
the *kriging believer* heuristic: pending points are imputed with the GP mean
so simultaneous workers do not pile onto the same optimum.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import numpy as np

from . import Proposer, register


def _matern52(X1: np.ndarray, X2: np.ndarray, ls: float) -> np.ndarray:
    d = np.sqrt(np.maximum(((X1[:, None, :] - X2[None, :, :]) ** 2).sum(-1), 1e-30)) / ls
    s5 = math.sqrt(5.0)
    return (1.0 + s5 * d + 5.0 / 3.0 * d * d) * np.exp(-s5 * d)


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    # erf-based CDF (no scipy in this container)
    from math import erf

    return np.vectorize(lambda t: 0.5 * (1.0 + erf(t / math.sqrt(2.0))))(z)


class _GP:
    def __init__(self, ls: float = 0.25, noise: float = 1e-4):
        self.ls, self.noise = ls, noise
        self.X: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> None:
        self.X = X
        self.ymean, self.ystd = float(y.mean()), float(y.std() + 1e-9)
        yn = (y - self.ymean) / self.ystd
        K = _matern52(X, X, self.ls) + self.noise * np.eye(len(X))
        self.L = np.linalg.cholesky(K)
        self.alpha = np.linalg.solve(self.L.T, np.linalg.solve(self.L, yn))

    def predict(self, Xs: np.ndarray):
        Ks = _matern52(Xs, self.X, self.ls)
        mu = Ks @ self.alpha
        v = np.linalg.solve(self.L, Ks.T)
        var = np.maximum(1.0 - (v * v).sum(0), 1e-12)
        return mu * self.ystd + self.ymean, np.sqrt(var) * self.ystd


@register("spearmint")
@register("gp")
class GPBayesianProposer(Proposer):
    """``n_init`` random warmup points, then EI over ``n_candidates`` samples."""

    def __init__(self, space, n_init: int = 8, n_candidates: int = 2048,
                 length_scale: float = 0.25, **kwargs):
        super().__init__(space, **kwargs)
        self.n_init = int(n_init)
        self.n_candidates = int(n_candidates)
        self.gp = _GP(ls=float(length_scale))
        self._pending: List[np.ndarray] = []

    def _propose(self) -> Optional[Dict[str, Any]]:
        if self.n_proposed >= self.n_samples:
            return None
        if len(self.history) < self.n_init:
            cfg = self.space.sample(self.rng)
            self._pending.append(self.space.to_unit(cfg))
            return cfg

        X = np.array([self.space.to_unit(h["config"]) for h in self.history])
        y = np.array([h["score"] for h in self.history])
        # kriging believer: impute pending points at the current GP mean
        if self._pending:
            gp0 = _GP(self.gp.ls)
            gp0.fit(X, y)
            P = np.array(self._pending)
            mu_p, _ = gp0.predict(P)
            X = np.vstack([X, P])
            y = np.concatenate([y, mu_p])
        self.gp.fit(X, y)

        cand = self.rng.uniform(size=(self.n_candidates, len(self.space)))
        # densify around the incumbent (local exploitation)
        best_x = X[int(np.argmax(y))]
        local = np.clip(best_x + 0.05 * self.rng.standard_normal((self.n_candidates // 4, len(self.space))), 0, 1)
        cand = np.vstack([cand, local])

        mu, sigma = self.gp.predict(cand)
        f_best = float(y.max())
        z = (mu - f_best) / sigma
        ei = (mu - f_best) * _norm_cdf(z) + sigma * _norm_pdf(z)
        x = cand[int(np.argmax(ei))]
        self._pending.append(x)
        return self.space.from_unit(x)

    def _on_result(self, config: Dict[str, Any], score: float) -> None:
        self._drop_pending(config)

    def _on_failure(self, config: Dict[str, Any]) -> None:
        self._drop_pending(config)

    def _drop_pending(self, config: Dict[str, Any]) -> None:
        try:
            x = self.space.to_unit(config)
        except (KeyError, ValueError):
            return
        for i, p in enumerate(self._pending):
            if np.allclose(p, x, atol=1e-9):
                self._pending.pop(i)
                return
