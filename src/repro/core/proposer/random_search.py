"""Random search (Bergstra & Bengio 2012) — the paper's default benchmark."""
from __future__ import annotations

from typing import Any, Dict, Optional

from . import Proposer, register


@register("random")
class RandomProposer(Proposer):
    def _propose(self) -> Optional[Dict[str, Any]]:
        if self.n_proposed >= self.n_samples:
            return None  # budget fully issued; wait for stragglers
        return self.space.sample(self.rng)
