"""Experiment — the paper's Algorithm 1 orchestration loop.

    while not proposer.finished():
        resource <- resource_manager.get_available()
        if not resource: sleep
        hyperparameters <- proposer.get_param()
        Job <- aup.run(hyperparameters, resource)
        if Job.callback(): proposer.update()
    aup.finish()   # wait for unfinished jobs

plus the production features a thousand-node deployment needs:

* **asynchronous callbacks** — jobs finish on worker threads; results flow
  through a queue so the proposer stays single-threaded;
* **fault tolerance** — every proposal/result is in SQLite *before* it is
  acted on; ``Experiment.resume()`` replays finished jobs into the proposer
  and re-queues the ones that were mid-flight at the crash;
* **straggler mitigation** — per-job deadline -> kill -> retry;
* **retries** — failed/LOST jobs are resubmitted up to ``max_retries`` before
  the failure is surfaced to the proposer; the retry budget is tracked per job
  lineage (on the Job itself), so two proposals with identical params cannot
  eat each other's retries;
* **batched proposal draining** — each loop pass claims every free resource
  and asks the proposer for that many configs at once (``get_params``), which
  lets the vectorized resource manager fill a whole population per round;
* **elasticity** — works with ElasticResourceManager; lost resources simply
  shrink the pool, lost jobs are retried.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

from . import faultinject
from .basic_config import BasicConfig
from .job import Job, JobStatus
from .proposer import make_proposer
from .resource import ResourceManager, get_resource_manager_cls
from .tracking.database import FlightJournal, TrackingDB


class Experiment:
    def __init__(
        self,
        exp_config: Dict[str, Any],
        target: Any,
        db: Optional[TrackingDB] = None,
        resource_manager: Optional[ResourceManager] = None,
        user: str = "default",
    ):
        self.exp_config = dict(exp_config)
        self.target = target
        self.db = db or TrackingDB(exp_config.get("db_path", ":memory:"))
        self.user = user

        from .search_space import SearchSpace

        space = SearchSpace.from_json(self.exp_config.get("parameter_config", []))
        maximize = self.exp_config.get("target", "max") == "max"
        prop_kwargs = {
            k: v
            for k, v in self.exp_config.items()
            if k
            not in (
                "proposer", "parameter_config", "target", "resource", "script",
                "n_parallel", "db_path", "workdir", "job_deadline_s", "max_retries",
                "lane_refill", "cli", "snapshot_every", "snapshot_dir",
                "max_flight_restarts", "restart_backoff_s",
                "finish_join_timeout_s", "fault_spec", "resume",
                "model_parallel",
            )
        }
        self.proposer = make_proposer(
            self.exp_config["proposer"], space, maximize=maximize, **prop_kwargs
        )
        self.maximize = maximize

        if resource_manager is not None:
            self.rm = resource_manager
        else:
            rm_cls = get_resource_manager_cls(self.exp_config.get("resource", "local"))
            rm_kwargs: Dict[str, Any] = {"n_parallel": int(self.exp_config.get("n_parallel", 1))}
            if self.exp_config.get("workdir"):
                rm_kwargs["workdir"] = self.exp_config["workdir"]
            if self.exp_config.get("lane_refill"):
                rm_kwargs["lane_refill"] = True
            if self.exp_config.get("elastic_regrid"):
                # sharded manager only: lane geometry leased through an
                # ElasticLanePool so rung survivors absorb freed devices
                rm_kwargs["elastic_regrid"] = True
            if self.exp_config.get("model_parallel"):
                # sharded manager only: fold the device grid into a two-level
                # (pop, model) mesh whose model axis carries tensor-parallel
                # compute inside every lane
                rm_kwargs["model_parallel"] = int(self.exp_config["model_parallel"])
            for k in ("max_flight_restarts", "restart_backoff_s",
                      "finish_join_timeout_s"):
                if self.exp_config.get(k) is not None:
                    rm_kwargs[k] = self.exp_config[k]
            self.rm = rm_cls(**rm_kwargs)
            # unknown kwargs are silently swallowed by ResourceManager.__init__;
            # a streaming request that cannot stream must fail loudly instead
            if rm_kwargs.get("lane_refill") and not getattr(self.rm, "lane_refill", False):
                raise ValueError(
                    f"lane_refill requested but resource "
                    f"{self.exp_config.get('resource', 'local')!r} does not "
                    f"support streaming flights (use 'vectorized' or 'sharded')"
                )
        if (self.exp_config.get("lane_refill")
                and getattr(target, "per_trial_streams", True) is False):
            # refill was an *implicit* per-trial-stream assumption once; a
            # shared-stream target must fail at construction, not mid-flight
            # (a refilled lane has to replay its own stream from its step 0)
            raise ValueError(
                "lane_refill requires per-trial data streams: the target was "
                "built with per_trial_streams=False (drop --shared-stream)"
            )

        # lifecycle passthrough: a streaming proposer (PBT) exposes the
        # engine-facing half of its exploit/explore rule via lifecycle_hook();
        # targets with a `lifecycle` slot (PopulationTrial) get it wired here
        # so the lane-refill engine can execute keep/clone directives as
        # compiled lane ops.
        hook_factory = getattr(self.proposer, "lifecycle_hook", None)
        if hook_factory is not None and hasattr(self.target, "lifecycle"):
            hook = hook_factory()
            if hook is not None and getattr(self.target, "lifecycle") is None:
                self.target.lifecycle = hook

        self.deadline_s = self.exp_config.get("job_deadline_s")
        self.max_retries = int(self.exp_config.get("max_retries", 1))

        self.exp_id: Optional[int] = None
        self._next_job_id = 0
        self._cond = threading.Condition()
        self._finished_q: List[Job] = []
        self._running: Dict[int, Job] = {}
        # crash-resume / retry entries: (config, n_prior_retries).  Retries are
        # counted per job lineage, NOT per config value — two proposals with
        # identical params must not share a retry budget.
        self._requeue: List[tuple] = []
        self.job_log: List[Job] = []
        # incremental result hooks: fired once per *settled* job (scored, or
        # retries exhausted) as results drain — on the streaming engines this
        # happens while the rest of the population batch is still running
        self._result_callbacks: List[Callable[[Job], None]] = []

    def add_result_callback(self, fn: Callable[[Job], None]) -> None:
        """Register a hook fired for every settled job (finished with a score,
        or failed for good after its retry budget).  Fires on the experiment
        loop thread as soon as the result drains — with a streaming resource
        manager that is mid-batch, not at flight end.  Keep it fast: it runs
        under the experiment lock."""
        self._result_callbacks.append(fn)

    # -- callback (fires on worker threads; keep it tiny) -----------------------
    def _on_job_done(self, job: Job) -> None:
        with self._cond:
            self._finished_q.append(job)
            self._cond.notify_all()

    # -- helpers ------------------------------------------------------------------
    def _wire_journal(self) -> None:
        """Hand a ``FlightJournal`` to every collaborator exposing a
        ``journal`` slot (the streaming resource managers and the population
        trial), so flight deaths / restarts / snapshots / lane leases land in
        the tracking DB as write-ahead rows keyed to this experiment."""
        if self.exp_id is None:
            return
        journal = FlightJournal(self.db, self.exp_id)
        for obj in (self.rm, self.target):
            if hasattr(obj, "journal") and getattr(obj, "journal") is None:
                obj.journal = journal

    def _next_configs(self, k: int) -> List[tuple]:
        """Up to ``k`` ``(config, n_prior_retries)`` pairs: requeued jobs first,
        then a batched drain of the proposer (``get_params``) so synchronous
        proposers can fill a whole population of resources per loop pass.

        The requeue drains even when the proposer reports ``finished()`` —
        after a crash-resume every remaining job can be a re-queued lineage
        with zero proposals left to draw, and skipping the drain would strand
        them (the loop would spin on "finished but requeue non-empty")."""
        out: List[tuple] = []
        while self._requeue and len(out) < k:
            out.append(self._requeue.pop(0))
        if len(out) < k and not self.proposer.finished():
            out.extend((cfg, 0) for cfg in self.proposer.get_params(k - len(out)))
        return out

    def _drain_finished_locked(self) -> None:
        """Process completed jobs: DB, retries, proposer update, release."""
        while self._finished_q:
            job = self._finished_q.pop(0)
            self._running.pop(job.job_id, None)
            res = job.result
            ok = job.status == JobStatus.FINISHED and res is not None and res.score is not None
            self.db.record_job_end(
                self.exp_id, job.job_id, job.status.value,
                None if res is None else res.score,
                None if res is None else res.extra,
                None if res is None else res.error,
            )
            # resource returns to the pool unless it was lost with the node
            if job.status != JobStatus.LOST:
                self.rm.release(job.resource_id)
            if ok:
                self.proposer.update(res.score, job)
                self._fire_result_callbacks(job)
            else:
                # per-job retry counter rides on the Job itself: distinct
                # proposals with identical params keep separate retry budgets
                n = getattr(job, "retries", 0)
                if n < self.max_retries and not getattr(job, "quarantined", False):
                    cfg = {k: v for k, v in job.config.items() if k != "job_id"}
                    # the retry must keep the lineage's data stream: anonymous
                    # configs stream by job_id, and the new attempt gets a NEW
                    # job_id — without this stamp a retried trial would train
                    # on different batches than the original (and than an
                    # uninterrupted run)
                    cfg.setdefault("stream", job.job_id)
                    self._requeue.append((cfg, n + 1))
                else:
                    # quarantined jobs (poison lane across consecutive flight
                    # deaths) skip their remaining retry budget by design
                    self.proposer.update(None, job)
                    self._fire_result_callbacks(job)

    def _fire_result_callbacks(self, job: Job) -> None:
        for fn in self._result_callbacks:
            try:
                fn(job)
            except Exception:  # observers must never break the loop
                pass

    def _check_stragglers_locked(self) -> None:
        for job in list(self._running.values()):
            if job.is_overdue():
                self.rm.kill(job)

    # -- main loop -------------------------------------------------------------------
    def run(self, poll_interval: float = 0.02) -> Optional[Dict[str, Any]]:
        if self.exp_id is None:
            self.exp_id = self.db.create_experiment(self.exp_config, self.user)
        self._wire_journal()
        t0 = time.time()
        while True:
            with self._cond:
                self._drain_finished_locked()
                self._check_stragglers_locked()
                done = self.proposer.finished() and not self._running and not self._requeue
            if done:
                break

            res = self.rm.get_available()
            if res is None:
                if not self._running and self.rm.n_total() == 0:
                    raise RuntimeError("no resources left in the pool and none running")
                with self._cond:
                    self._cond.wait(timeout=poll_interval)
                continue

            # batched proposal draining: claim every free resource this pass so
            # a synchronous proposer can fill a whole population per round
            resources = [res]
            nxt = self.rm.get_available()
            while nxt is not None:
                resources.append(nxt)
                nxt = self.rm.get_available()

            with self._cond:
                self._drain_finished_locked()
                pairs = self._next_configs(len(resources))
                if pairs:
                    # write-ahead: the proposer's draw state lands in the DB
                    # before the drawn configs are acted on, so a resumed
                    # proposer continues the exact sequence an uninterrupted
                    # run would have produced (running rows replay as proposed)
                    try:
                        self.db.save_proposer_state(
                            self.exp_id, self.proposer.state_json())
                    except Exception:
                        pass  # state WAL is best-effort, never the data path
            if not pairs:
                for r in resources:
                    self.rm.release(r)
                with self._cond:
                    if self.proposer.finished() and not self._running and not self._requeue:
                        break
                    self._cond.wait(timeout=poll_interval)
                continue

            for (cfg, retries), r in zip(pairs, resources):
                job_id = self._next_job_id
                self._next_job_id += 1
                # chaos hook: 'raise@issue=N' — the classic between-batches
                # controller crash, right before job N lands in the DB
                faultinject.check("issue", issue=job_id)
                cfg = dict(cfg)
                cfg["job_id"] = job_id  # paper Code 1: job_id rides in the BasicConfig
                bc = BasicConfig(**cfg)
                job = Job(job_id, bc, r, self._on_job_done, deadline_s=self.deadline_s)
                job.retries = retries
                with self._cond:
                    self._running[job_id] = job
                self.job_log.append(job)
                self.db.record_job_start(self.exp_id, job_id, bc.to_json(), str(r))
                self.rm.run(job, self.target)
            # unused claims go back; for the vectorized manager this release is
            # also the signal to flush a partial population batch
            for r in resources[len(pairs):]:
                self.rm.release(r)

        # aup.finish(): drain stragglers, then let the resource manager close
        # any live streaming flight instead of lingering on its idle grace
        with self._cond:
            self._drain_finished_locked()
        rm_finish = getattr(self.rm, "finish", None)
        if rm_finish is not None:
            rm_finish()
        self.db.finish_experiment(self.exp_id)
        self.wall_time_s = time.time() - t0
        return self.best()

    def best(self) -> Optional[Dict[str, Any]]:
        return self.proposer.best()

    # -- crash-resume --------------------------------------------------------------
    @classmethod
    def resume(
        cls,
        db: TrackingDB,
        target: Any,
        exp_id: Optional[int] = None,
        resource_manager: Optional[ResourceManager] = None,
        user: str = "default",
    ) -> "Experiment":
        exp_id = exp_id if exp_id is not None else db.latest_experiment_id()
        if exp_id is None:
            raise ValueError("no experiment to resume")
        row = db.get_experiment(exp_id)
        exp = cls(row["exp_config"], target, db=db, resource_manager=resource_manager, user=user)
        exp.exp_id = exp_id
        rows = db.jobs(exp_id)
        # rows a *previous* resume marked lost ("controller crash") were
        # re-queued then under a new job id whose own row carries the outcome;
        # replaying them again would double-count the lineage on the 2nd+
        # resume (once as failed, once via the successor's row)
        live_rows = [r for r in rows
                     if not (r["status"] == "lost"
                             and r.get("error") == "controller crash")]
        exp.proposer.replay(live_rows)
        # the draw-state WAL puts the RNG back where the last proposal batch
        # left it, so the remaining draws continue the uninterrupted sequence
        exp.proposer.load_state_json(db.load_proposer_state(exp_id))
        max_id = -1
        for r in rows:
            max_id = max(max_id, int(r["job_id"]))
            if r["status"] == "running":  # mid-flight at crash -> re-queue
                cfg = {k: v for k, v in r["config"].items() if k != "job_id"}
                # keep the lineage's data stream across the new job id (see
                # the retry path) — bit-identical resume depends on it
                cfg.setdefault("stream", r["config"].get("stream", r["job_id"]))
                exp._requeue.append((cfg, 0))
                db.record_job_end(exp_id, r["job_id"], "lost", None, None, "controller crash")
        exp._next_job_id = max_id + 1
        exp._wire_journal()
        try:
            db.journal_append(exp_id, "resume",
                              detail={"requeued": len(exp._requeue)})
        except Exception:
            pass
        return exp
