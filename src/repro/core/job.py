"""Job object + status machine (paper §III-B2).

A Job wraps one execution of the user's code with one BasicConfig on one
resource.  ``callback`` fires exactly once when the job finishes (success or
failure) — it is the hook that triggers ``proposer.update()`` asynchronously
in Algorithm 1.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable, Dict, Optional

from .basic_config import BasicConfig


class JobStatus(str, enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"
    KILLED = "killed"      # straggler mitigation / early stop
    LOST = "lost"          # resource disappeared (node failure)


@dataclasses.dataclass
class JobResult:
    score: Optional[float]
    extra: Any = None
    error: Optional[str] = None
    wall_time_s: float = 0.0


class Job:
    """One (config, resource) execution unit."""

    def __init__(
        self,
        job_id: int,
        config: BasicConfig,
        resource_id: Any,
        callback: Callable[["Job"], None],
        deadline_s: Optional[float] = None,
    ):
        self.job_id = job_id
        self.config = config
        self.resource_id = resource_id
        self.retries = 0  # how many prior attempts this lineage already burned
        self.status = JobStatus.PENDING
        self.result: Optional[JobResult] = None
        self.deadline_s = deadline_s
        self.start_time: Optional[float] = None
        self.end_time: Optional[float] = None
        self._callback = callback
        self._done = threading.Event()
        self._cb_fired = False
        self._lock = threading.Lock()

    # -- lifecycle ----------------------------------------------------------
    def mark_running(self) -> None:
        self.status = JobStatus.RUNNING
        self.start_time = time.time()

    def finish(self, result: JobResult, status: JobStatus = JobStatus.FINISHED) -> bool:
        """Complete the job and fire the callback exactly once (thread-safe).
        Returns True when this call delivered the result, False when the job
        was already settled (e.g. killed by a deadline)."""
        with self._lock:
            if self._cb_fired:
                return False
            self._cb_fired = True
            self.end_time = time.time()
            if self.start_time is not None:
                result.wall_time_s = self.end_time - self.start_time
            self.result = result
            self.status = status
        try:
            self._callback(self)
        finally:
            self._done.set()
        return True

    def fail(self, error: str, status: JobStatus = JobStatus.FAILED) -> None:
        self.finish(JobResult(score=None, error=error), status=status)

    def is_overdue(self) -> bool:
        return (
            self.deadline_s is not None
            and self.status == JobStatus.RUNNING
            and self.start_time is not None
            and (time.time() - self.start_time) > self.deadline_s
        )

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def to_row(self) -> Dict[str, Any]:
        return {
            "job_id": self.job_id,
            "config": self.config.to_json(),
            "resource_id": str(self.resource_id),
            "status": self.status.value,
            "score": None if self.result is None else self.result.score,
            "error": None if self.result is None else self.result.error,
            "start_time": self.start_time,
            "end_time": self.end_time,
        }
