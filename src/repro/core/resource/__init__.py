"""Resource Manager interface (paper §III-B) + registry.

``get_available()`` returns a free resource id (or None — Algorithm 1 then
waits), ``run(job, target)`` launches the job on that resource and arranges
for ``job.finish(...)`` to fire asynchronously (the callback mechanism), and
``release(res)`` returns the resource to the pool.

Implementations:
* ``local``      — thread pool over in-process callables (paper's CPU/GPU mode)
* ``subprocess`` — paper-faithful script jobs: JSON argv[1] in, stdout score out
* ``mesh``       — TPU-native adaptation: resources are topology-contiguous
                   mesh *slices* of a pod; a trial is a pjit program on its slice
* ``elastic``    — wraps another manager; slices join/leave mid-experiment
                   (EC2-autoscaling analogue + node-failure injection)
* ``vectorized`` — K population slots; bound jobs are batched and executed as
                   ONE vmapped device program (compile-once HPO hot path)
* ``sharded``    — vectorized slots become per-device *lanes* on a 1-D
                   population mesh; a batch is ONE shard_map-ed program with
                   K/N trials per device
"""
from __future__ import annotations

import abc
import threading
from typing import Any, Callable, Dict, List, Optional, Type

from ..job import Job

_REGISTRY: Dict[str, Type["ResourceManager"]] = {}


def register(name: str):
    def deco(cls):
        _REGISTRY[name.lower()] = cls
        cls.registry_name = name.lower()
        return cls
    return deco


def get_resource_manager_cls(name: str) -> Type["ResourceManager"]:
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown resource manager {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def available_resource_managers() -> List[str]:
    return sorted(_REGISTRY)


class ResourceManager(abc.ABC):
    registry_name = "base"

    def __init__(self, **_unused: Any):
        self._lock = threading.RLock()
        self._free: List[Any] = []
        self._busy: Dict[Any, Optional[Job]] = {}

    # -- pool bookkeeping (shared) ---------------------------------------------
    def add_resource(self, res_id: Any) -> None:
        with self._lock:
            if res_id not in self._free and res_id not in self._busy:
                self._free.append(res_id)

    def remove_resource(self, res_id: Any) -> Optional[Job]:
        """Remove a resource; returns the job that was running on it (if any),
        which the caller should mark LOST (node-failure semantics)."""
        with self._lock:
            if res_id in self._free:
                self._free.remove(res_id)
                return None
            return self._busy.pop(res_id, None)

    def get_available(self) -> Optional[Any]:
        with self._lock:
            if not self._free:
                return None
            res = self._free.pop(0)
            self._busy[res] = None
            return res

    def release(self, res_id: Any) -> None:
        with self._lock:
            if res_id in self._busy:
                del self._busy[res_id]
                self._free.append(res_id)

    def n_total(self) -> int:
        with self._lock:
            return len(self._free) + len(self._busy)

    def n_free(self) -> int:
        with self._lock:
            return len(self._free)

    def bind(self, res_id: Any, job: Job) -> None:
        with self._lock:
            if res_id in self._busy:
                self._busy[res_id] = job

    # -- execution ----------------------------------------------------------------
    @abc.abstractmethod
    def run(self, job: Job, target: Any) -> None:
        """Launch ``job`` on ``job.resource_id``; must call job.finish/fail
        asynchronously and must NOT raise for job-level errors."""

    def kill(self, job: Job) -> None:
        """Best-effort termination (straggler mitigation)."""
        job.fail("killed by deadline", status=__import__("repro.core.job", fromlist=["JobStatus"]).JobStatus.KILLED)

    def shutdown(self) -> None:
        pass


from . import local, subprocess_rm, mesh_pool, elastic, vectorized, sharded  # noqa: E402,F401
