"""Local thread-pool resource manager — in-process callable jobs.

``target`` is a Python callable ``f(config_dict) -> score`` (or
``(score, extra)``).  Each resource is one worker slot; the callable runs in a
daemon thread and the job's callback fires from that thread — exercising the
same async path a pod deployment uses.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from . import ResourceManager, register
from ..job import Job, JobResult, JobStatus


@register("local")
@register("cpu")
@register("gpu")
class LocalResourceManager(ResourceManager):
    def __init__(self, n_parallel: int = 1, resource_prefix: str = "local", **kwargs):
        super().__init__(**kwargs)
        for i in range(int(n_parallel)):
            self.add_resource(f"{resource_prefix}{i}")

    def run(self, job: Job, target: Callable[[dict], Any]) -> None:
        self.bind(job.resource_id, job)

        def _worker():
            job.mark_running()
            try:
                out = target(dict(job.config))
                score, extra = out if isinstance(out, tuple) else (out, None)
                job.finish(JobResult(score=float(score), extra=extra))
            except Exception as e:  # job error != framework error
                job.fail(f"{type(e).__name__}: {e}")

        t = threading.Thread(target=_worker, name=f"job-{job.job_id}", daemon=True)
        t.start()

    def kill(self, job: Job) -> None:
        # Python threads cannot be force-killed; mark the job KILLED so its
        # eventual return is ignored (Job.finish fires the callback only once).
        job.fail("killed by deadline", status=JobStatus.KILLED)
