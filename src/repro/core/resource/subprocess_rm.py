"""Paper-faithful script jobs (§III-B2, Code 3/5).

The job's BasicConfig is written to ``<workdir>/job_<id>.json``; the user's
self-executable script runs as ``python <script> <json>``; stdout is parsed
for the ``print_result`` line.  The resource id is exported as
``REPRO_RESOURCE`` (the CUDA_VISIBLE_DEVICES analogue — on TPU the slice name).
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
from typing import Any, Optional

from . import ResourceManager, register
from ..basic_config import parse_result
from ..job import Job, JobResult, JobStatus


@register("subprocess")
@register("node")
class SubprocessResourceManager(ResourceManager):
    def __init__(self, n_parallel: int = 1, workdir: str = ".aup_jobs",
                 resource_prefix: str = "node", timeout_s: Optional[float] = None, **kwargs):
        super().__init__(**kwargs)
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.timeout_s = timeout_s
        self._procs = {}
        for i in range(int(n_parallel)):
            self.add_resource(f"{resource_prefix}{i}")

    def run(self, job: Job, target: str) -> None:
        self.bind(job.resource_id, job)
        cfg_path = os.path.join(self.workdir, f"job_{job.job_id}.json")
        job.config.save(cfg_path)

        def _worker():
            job.mark_running()
            env = dict(os.environ)
            env["REPRO_RESOURCE"] = str(job.resource_id)
            try:
                proc = subprocess.Popen(
                    [sys.executable, target, cfg_path],
                    stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
                )
                self._procs[job.job_id] = proc
                out, err = proc.communicate(timeout=self.timeout_s)
                if proc.returncode != 0:
                    job.fail(f"exit {proc.returncode}: {err[-500:]}")
                    return
                payload = parse_result(out)
                job.finish(JobResult(score=payload["score"], extra=payload.get("extra")))
            except subprocess.TimeoutExpired:
                proc.kill()
                job.fail("timeout", status=JobStatus.KILLED)
            except Exception as e:
                job.fail(f"{type(e).__name__}: {e}")
            finally:
                self._procs.pop(job.job_id, None)

        threading.Thread(target=_worker, name=f"job-{job.job_id}", daemon=True).start()

    def kill(self, job: Job) -> None:
        proc = self._procs.get(job.job_id)
        if proc is not None:
            proc.kill()
        job.fail("killed by deadline", status=JobStatus.KILLED)
