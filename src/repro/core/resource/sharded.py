"""Sharded population resource manager — mesh-aware lanes, one device program.

The vectorized manager buffers K jobs and runs them as one vmapped program on
a single device.  This subclass keeps that buffering machinery but presents
**mesh-aware slots**: the device set (default: every local device) is tiled
into 1-chip slices with ``mesh_pool.tile_pod``, a 1-D *population* mesh is
built over it (``repro.distributed.sharding.population_mesh``), and each
resource id names the lane AND the device it lands on::

    slice[0:1,3:4]/lane2   ->  4th device, 3rd of its K/N population lanes

``n_parallel`` is rounded up to a multiple of the device count so the
population axis always divides over the mesh (the trial pads short batches
with 0-budget lanes).  ``_run_batch`` forwards the mesh to the target's
``run_population(configs, mesh=...)``, which executes the flight as ONE
``shard_map``-ed jitted program — K/N trials per device, no cross-trial
communication.  Targets without a ``mesh`` kwarg still work (single-device
vmapped fallback), so the manager stays drop-in compatible with every
existing population target.

The streaming protocols ride through unchanged from the vectorized base: a
lane-refill flight leases jobs into mesh lanes and refills them with the
*sharded* lane-lifecycle twins (``get_compiled_lane_op(..., mesh=...)`` —
masked init / single-lane splice / donor clone), and streaming PBT's
clone/splice dispatch plus donor lease pinning (the ``lifecycle`` hook handed
to the ``LaneScheduler`` in ``_flush``) work across mesh boundaries: the
sharded clone ``all_gather``s the population axis, so a donor's weights can
live on a different device than the lane inheriting them.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence

from . import ResourceManager, register
from .elastic import ElasticResourceManager
from .mesh_pool import tile_pod
from .vectorized import VectorizedResourceManager, accepts_kwarg


class _SlicePool(ResourceManager):
    """Bookkeeping-only pool whose resources are device-slice leases, not job
    slots.  ``ElasticLanePool`` scales it in/out as lane geometry changes; no
    job ever binds to a slice lease, so ``scale_in`` can never mark a running
    flight LOST (that is the job-slot pool's failure protocol, not ours)."""

    def run(self, job, target) -> None:  # pragma: no cover - never dispatched
        raise RuntimeError("_SlicePool leases device slices; it does not run jobs")


class ElasticLanePool:
    """Width-annotated device leases for the elastic-regrid engine.

    The pool tiles its device row into ``width``-wide slices with
    ``mesh_pool.tile_pod`` and leases them through an ``ElasticResourceManager``
    so every geometry change is an observable scale event: ``regrid(survivors)``
    scale-ins the old ``slice[...]xW{w}`` leases and scale-outs the new, wider
    set, then hands back the matching two-level ``(pop, model)`` mesh.  The
    trial calls ``plan_regrid`` through here at each rung boundary; the
    full-occupancy invariant (every device row carries a live lane) is the
    planner's, the lease protocol is this class's.
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None,
                 width: int = 1, axis: str = "pop"):
        import jax

        self.devices = list(devices) if devices is not None else jax.devices()
        self.axis = axis
        self.manager = ElasticResourceManager(base=_SlicePool())
        self.width = 0
        self.lanes = 0
        self.width_history: List[int] = []
        self.n_regrids = 0
        self._lease_ids: List[str] = []
        self._retile(int(width))

    def _retile(self, width: int) -> None:
        n = len(self.devices)
        if width <= 0 or n % width:
            raise ValueError(f"width {width} does not tile {n} devices")
        old = self._lease_ids
        slices = tile_pod((1, n), (1, width), devices=self.devices)
        self._lease_ids = [f"{s.slice_id}xW{width}" for s in slices]
        self.manager.scale_out(self._lease_ids)
        self.manager.scale_in(old)
        self.width = width
        self.width_history.append(width)

    def mesh(self):
        from ...distributed.sharding import population_mesh

        return population_mesh(self.devices, axis=self.axis,
                               width=self.width if self.width > 1 else None)

    def plan(self, n_survivors: int):
        from ...train.population import plan_regrid

        return plan_regrid(len(self.devices), n_survivors)

    def regrid(self, n_survivors: int):
        """Re-lease the pod for ``n_survivors`` live trials: returns the
        ``(rows, width, lanes)`` plan and the new mesh.  A no-op plan (same
        width) still refreshes nothing and emits no scale events."""
        rows, width, lanes = self.plan(n_survivors)
        if width != self.width:
            self._retile(width)
            self.n_regrids += 1
        self.lanes = lanes
        return (rows, width, lanes), self.mesh()


@register("sharded")
class ShardedPopulationResourceManager(VectorizedResourceManager):
    def __init__(
        self,
        n_parallel: int = 8,
        devices: Optional[Sequence[Any]] = None,
        axis: str = "pop",
        elastic_regrid: bool = False,
        model_parallel: int = 1,
        **kwargs,
    ):
        from ...distributed.sharding import population_mesh

        from ...train.population import pad_population

        # --model-parallel W: the device grid folds into a two-level
        # (pop, model) mesh — N/W lane rows of W devices each.  Lane slots
        # (and thus padded K) count ROWS, not devices: each lane's tensor
        # computation splits over its row's model axis.
        width = max(1, int(model_parallel))
        self.model_parallel = width
        self.mesh = population_mesh(devices, axis=axis,
                                    width=width if width > 1 else None)
        devs = list(self.mesh.devices.flat)
        n_dev = len(devs)
        rows = n_dev // width
        # population axis must divide over the mesh: round lanes up (same rule
        # the trial applies to its batch, so slot count and padded K agree)
        n_slots = pad_population(int(n_parallel), self.mesh)
        self.lanes_per_device = n_slots // rows
        # resource ids name width-wide device slices: slot j of row i is
        # "slice[0:1,i*W:(i+1)*W]/lane{j}"
        self.slices = {
            s.slice_id: s for s in tile_pod((1, n_dev), (1, width), devices=devs)
        }
        super().__init__(n_parallel=0, **kwargs)  # resources added below
        self.n_slots = n_slots
        for lane in range(self.lanes_per_device):
            for sid in self.slices:
                self.add_resource(f"{sid}/lane{lane}")
        # mesh-degrade: when a supervised streaming flight exhausts its
        # restart budget on the mesh, the last attempt (and everything after)
        # runs on the single-device vmapped engine — a wedged collective or a
        # sick device should not take the whole experiment down with it
        self._degraded = False
        self.n_degraded_flights = 0
        # --elastic-regrid: lane geometry becomes a leased, scalable resource;
        # the trial regrids through the pool at rung boundaries so width
        # changes ride the ElasticResourceManager's scale-out/in protocol
        self.elastic = (
            ElasticLanePool(devices=devs, axis=axis) if elastic_regrid else None
        )

    def _on_flight_death(self, attempt: int) -> None:
        if not self._degraded and attempt >= self.supervisor.max_restarts:
            self._degraded = True
            if self.journal is not None:
                self.journal.append(
                    "mesh_degrade", step=attempt,
                    detail="sharded flight kept dying; retrying vmapped")

    def _run_batch(self, runner: Callable, configs: List[dict],
                   scheduler=None) -> List[Any]:
        # discriminate on the signature, not on a raised TypeError: an
        # in-flight TypeError must propagate, never silently re-run the batch
        # on the single-device engine
        kwargs = {}
        if accepts_kwarg(runner, "mesh") and not self._degraded:
            kwargs["mesh"] = self.mesh
        if self.elastic is not None and accepts_kwarg(runner, "elastic"):
            kwargs["elastic"] = self.elastic
        if self._degraded:
            self.n_degraded_flights += 1
        if scheduler is not None:  # streaming (lane-refill) flight
            kwargs["scheduler"] = scheduler
        return runner(configs, **kwargs)
