"""TPU mesh-slice resource pool — the TPU-native Resource Manager.

The paper's resource quantum is a GPU id; on a pod it is a **mesh slice**: a
topology-contiguous tile of the chip grid.  A 16x16 pod tiled into 4x4 slices
yields 16 HPO trials, each itself a distributed (data x model) pjit program.

``MeshSlice.mesh()`` builds the ``jax.sharding.Mesh`` for the slice; the trial
callable receives ``(config, slice)`` and runs its pjit step inside
``with slice.mesh(axis_names):``.  Contiguity matters on real ICI — we tile
row-major rectangles, never scattered chip sets.

``virtual=True`` backs slices with labeled placeholders instead of real
devices, so scheduling behaviour (the paper's Fig. 3 scalability experiment)
can be studied at 256-slice scale on this 1-CPU container.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from . import ResourceManager, register
from ..job import Job, JobResult, JobStatus


@dataclasses.dataclass(frozen=True)
class MeshSlice:
    slice_id: str
    shape: Tuple[int, ...]          # chip-grid tile shape, e.g. (4, 4)
    devices: Tuple[Any, ...]        # real jax devices, or str labels if virtual
    origin: Tuple[int, ...] = (0, 0)

    @property
    def virtual(self) -> bool:
        return len(self.devices) > 0 and isinstance(self.devices[0], str)

    def mesh(self, axis_names: Sequence[str] = ("data", "model")):
        import jax
        from jax.sharding import Mesh

        if self.virtual:
            raise RuntimeError(f"slice {self.slice_id} is virtual; no Mesh available")
        arr = np.array(self.devices).reshape(self.shape)
        return Mesh(arr, axis_names=tuple(axis_names))

    def __str__(self) -> str:
        return self.slice_id


def tile_pod(
    pod_shape: Tuple[int, int],
    slice_shape: Tuple[int, int],
    devices: Optional[Sequence[Any]] = None,
    virtual: bool = False,
) -> List[MeshSlice]:
    """Tile a (rows, cols) pod grid into row-major contiguous slices."""
    R, C = pod_shape
    r, c = slice_shape
    if R % r or C % c:
        raise ValueError(f"slice {slice_shape} does not tile pod {pod_shape}")
    if virtual:
        grid = np.array([f"chip({i},{j})" for i in range(R) for j in range(C)],
                        dtype=object).reshape(R, C)
    else:
        import jax

        devs = list(devices) if devices is not None else jax.devices()
        if len(devs) < R * C:
            raise ValueError(f"need {R * C} devices for pod {pod_shape}, have {len(devs)}")
        grid = np.array(devs[: R * C], dtype=object).reshape(R, C)
    slices = []
    for i in range(0, R, r):
        for j in range(0, C, c):
            tile = grid[i : i + r, j : j + c].reshape(-1)
            slices.append(
                MeshSlice(
                    slice_id=f"slice[{i}:{i+r},{j}:{j+c}]",
                    shape=(r, c),
                    devices=tuple(tile.tolist()),
                    origin=(i, j),
                )
            )
    return slices


@register("mesh")
class MeshPoolResourceManager(ResourceManager):
    """Trials are callables ``f(config, mesh_slice) -> score`` run on slices."""

    def __init__(
        self,
        pod_shape: Tuple[int, int] = (1, 1),
        slice_shape: Tuple[int, int] = (1, 1),
        devices: Optional[Sequence[Any]] = None,
        virtual: bool = False,
        **kwargs,
    ):
        super().__init__(**kwargs)
        self.slices = {
            s.slice_id: s
            for s in tile_pod(tuple(pod_shape), tuple(slice_shape), devices, virtual)
        }
        for sid in self.slices:
            self.add_resource(sid)

    def slice_of(self, res_id: str) -> MeshSlice:
        return self.slices[res_id]

    def run(self, job: Job, target: Callable[[dict, MeshSlice], Any]) -> None:
        self.bind(job.resource_id, job)
        sl = self.slices[job.resource_id]

        def _worker():
            job.mark_running()
            try:
                out = target(dict(job.config), sl)
                score, extra = out if isinstance(out, tuple) else (out, None)
                job.finish(JobResult(score=float(score), extra=extra))
            except Exception as e:
                job.fail(f"{type(e).__name__}: {e}")

        threading.Thread(target=_worker, name=f"job-{job.job_id}", daemon=True).start()

    def kill(self, job: Job) -> None:
        job.fail("killed by deadline", status=JobStatus.KILLED)
