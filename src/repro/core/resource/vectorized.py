"""Vectorized resource manager — K population slots, one device program.

Presents ``n_slots`` resources to Algorithm 1, but instead of launching each
job on its own worker it *buffers* bound jobs and executes a whole batch in a
single call — on the training substrate that call is one vmapped, jitted
population step advancing every trial simultaneously (see
``repro.train.population``).  ``ShardedPopulationResourceManager`` (in
``sharded.py``) keeps this exact buffering/flush machinery but lands the
batch on an N-device mesh: slots become per-device *lanes* and the batch call
carries the mesh.

Batch protocol: if the experiment's ``target`` exposes

    run_population(configs: list[dict]) -> list[score | (score, extra)]

the buffered batch goes through it in one shot (scores come back positionally,
one per config).  Otherwise the manager degrades gracefully to looping the
scalar ``target(config)`` over the batch on one worker thread — same
scheduling semantics, no vectorization.

Flush policy:

* the buffer flushes when all ``n_slots`` are bound (a full population), and
* ``release()`` of an *unbound* slot while jobs are buffered flushes a partial
  batch — that release is Algorithm 1 telling us the proposer has nothing
  more right now (budget exhausted, rung/generation barrier), so waiting for
  a full population would deadlock the loop.

Per-job failure stays per-job: an exception inside ``run_population`` fails
the whole batch (every job retries under the experiment's retry budget), but
a diverged trial only reports its own sentinel score.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List

from . import ResourceManager, register
from ..job import Job, JobResult, JobStatus


@register("vectorized")
class VectorizedResourceManager(ResourceManager):
    def __init__(self, n_parallel: int = 8, resource_prefix: str = "slot", **kwargs):
        super().__init__(**kwargs)
        self.n_slots = int(n_parallel)
        for i in range(self.n_slots):
            self.add_resource(f"{resource_prefix}{i}")
        self._pending: List[Job] = []
        self._last_target: Any = None
        self.n_batches = 0
        self.batch_sizes: List[int] = []

    # -- Algorithm 1 surface ----------------------------------------------------
    def run(self, job: Job, target: Callable) -> None:
        # jobs stay PENDING while buffered: the straggler deadline clock only
        # starts when the batch actually executes (mark_running in _flush)
        self.bind(job.resource_id, job)
        with self._lock:
            self._last_target = target
            self._pending.append(job)
            full = len(self._pending) >= self.n_slots
        if full:
            self._flush(target)

    def release(self, res_id: Any) -> None:
        super().release(res_id)
        # an unbound slot coming back with jobs buffered == "no more proposals
        # are coming before a callback fires" -> run the partial population
        with self._lock:
            has_pending = bool(self._pending)
            target = self._last_target
        if has_pending and target is not None:
            self._flush(target)

    def _flush(self, target: Callable) -> None:
        with self._lock:
            batch, self._pending = self._pending, []
            if not batch:
                return
            self.n_batches += 1
            self.batch_sizes.append(len(batch))

        def _worker():
            # anything no longer PENDING was killed/lost while buffered
            live = [j for j in batch if j.status == JobStatus.PENDING]
            if not live:
                return
            for job in live:
                job.mark_running()
            try:
                runner = getattr(target, "run_population", None)
                if runner is not None:
                    outs = self._run_batch(runner, [dict(j.config) for j in live])
                else:
                    outs = [target(dict(j.config)) for j in live]
                if len(outs) != len(live):
                    raise ValueError(
                        f"run_population returned {len(outs)} results for {len(live)} configs"
                    )
                for job, out in zip(live, outs):
                    score, extra = out if isinstance(out, tuple) else (out, None)
                    job.finish(JobResult(score=float(score), extra=extra))
            except Exception as e:  # job error != framework error
                for job in live:
                    job.fail(f"{type(e).__name__}: {e}")

        threading.Thread(
            target=_worker, name=f"popbatch-{self.n_batches}", daemon=True
        ).start()

    def _run_batch(self, runner: Callable, configs: List[dict]) -> List[Any]:
        """Execute one buffered batch.  Subclass hook: the sharded manager
        passes its device mesh through to ``run_population`` here."""
        return runner(configs)

    def kill(self, job: Job) -> None:
        # the batch thread cannot be interrupted; mark KILLED so the eventual
        # positional result is dropped (Job.finish fires exactly once)
        job.fail("killed by deadline", status=JobStatus.KILLED)
