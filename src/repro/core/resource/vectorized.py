"""Vectorized resource manager — K population slots, one device program.

Presents ``n_slots`` resources to Algorithm 1, but instead of launching each
job on its own worker it *buffers* bound jobs and executes a whole batch in a
single call — on the training substrate that call is one vmapped, jitted
population step advancing every trial simultaneously (see
``repro.train.population``).  ``ShardedPopulationResourceManager`` (in
``sharded.py``) keeps this exact buffering/flush machinery but lands the
batch on an N-device mesh: slots become per-device *lanes* and the batch call
carries the mesh.

Batch protocol: if the experiment's ``target`` exposes

    run_population(configs: list[dict]) -> list[score | (score, extra)]

the buffered batch goes through it in one shot (scores come back positionally,
one per config).  Otherwise the manager degrades gracefully to looping the
scalar ``target(config)`` over the batch on one worker thread — same
scheduling semantics, no vectorization.

Streaming protocol (``lane_refill=True``): when the target's
``run_population`` also accepts a ``scheduler`` keyword, the flush hands it a
``LaneScheduler`` instead of a positional batch.  The engine then *leases*
jobs into population lanes one at a time and *completes* them individually as
lanes retire (budget exhausted, rung-truncated, diverged) — each completion
fires the job callback immediately, Algorithm 1 releases the slot, the
proposer refills it, and ``run()`` offers the new job straight into the live
flight.  Freed lanes are re-initialized **inside the compiled program**
(``repro.train.population.make_reset_lanes``), so the whole experiment can be
one continuous flight with no inter-batch bubble.  The engine polls the
scheduler (lease/complete) only at *event* steps — with ``chunk_steps > 1``
that cadence is per fused chunk, not per training step: offers made mid-chunk
are picked up at the next chunk boundary.

Lifecycle dispatch (streaming PBT): when the target carries a ``lifecycle``
hook (``core.proposer.pbt.PBTLifecycle``, wired by the Experiment from the
proposer's ``lifecycle_hook()``), the flush hands it to the ``LaneScheduler``
so jobs carrying lane-lifecycle directives are sequenced safely: a *donor*
member whose weights a pending clone still needs is deferred at lease time
(donor lease pinning) until the engine executes the compiled clone/splice op
— the engine-side half of the dispatch lives in
``PopulationTrial._run_streaming``.

Flush policy:

* the buffer flushes when all ``n_slots`` are bound (a full population), and
* ``release()`` of an *unbound* slot while jobs are buffered flushes a partial
  batch — that release is Algorithm 1 telling us the proposer has nothing
  more right now (budget exhausted, rung/generation barrier), so waiting for
  a full population would deadlock the loop.  While a streaming flight is
  live, buffered jobs drain *into* it instead of opening a second flight.

Failure blast radius stays as small as the protocol allows: on the scalar
fallback path every job is called (and caught) individually; on the batch
path a malformed *result* fails only its own job, and only an exception from
inside the single device program fails the whole batch.  A streaming flight
that dies fails its leased jobs; jobs still queued go back to the buffer's
retry path instead of being silently stranded.
"""
from __future__ import annotations

import inspect
import threading
import warnings
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from . import ResourceManager, register
from ..job import Job, JobResult, JobStatus


def accepts_kwarg(fn: Callable, name: str) -> bool:
    """True when ``fn`` can be called with keyword ``name`` (explicitly or via
    ``**kwargs``).  Signature-less builtins count as True — an in-flight
    ``TypeError`` must propagate rather than silently change the protocol."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return True
    return name in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


class LaneScheduler:
    """Host-side lane <-> job ledger for one streaming (lane-refill) flight.

    The manager *offers* bound jobs; the population engine *leases* them into
    freed lanes (``lease() -> (handle, config)``) and *completes* them
    individually as lanes retire, so results stream out while the flight is
    still running.  ``close()`` ends the flight: it stops further offers and
    splits the ledger into jobs never leased (the manager re-buffers or fails
    them) and leased-but-incomplete orphans (the engine died mid-lane).

    Thread-safety: ``offer`` is called from Algorithm 1's loop thread,
    ``lease``/``complete``/``fail`` from the flight worker thread, ``close``
    from the flight worker after the engine returns.  All state is guarded by
    one lock; job completion callbacks fire outside it.

    ``lifecycle`` (optional) is a lane-lifecycle hook (e.g. the streaming PBT
    proposer's ``PBTLifecycle``): jobs it reports ``lease_blocked`` — a
    ``keep`` round for a member pinned as a pending clone's donor — are
    rotated to the back of the queue instead of leased, so the donor's lane
    cannot resume training (and drift its weights) before the clone's device
    copy executes.  ``n_donor_waits`` counts those deferrals.
    """

    def __init__(self, on_stream: Optional[Callable[[], None]] = None,
                 lifecycle: Any = None) -> None:
        self._lock = threading.Lock()
        self._queue: Deque[Job] = deque()
        self._live: Dict[int, Job] = {}
        self._next_handle = 0
        self._on_stream = on_stream  # fired per streamed result, mid-flight
        self._lifecycle = lifecycle
        self.closed = False
        self.n_leased = 0
        self.n_streamed = 0
        self.n_donor_waits = 0
        self.n_device_retired = 0  # retirements harvested from a scan's log
        self._donor_waited: set = set()  # job ids counted once, not per poll

    # -- manager side -----------------------------------------------------------
    def offer(self, job: Job) -> bool:
        """Queue a job for the flight; False once the flight is shutting down
        (the caller keeps the job and flushes it into a fresh flight)."""
        with self._lock:
            if self.closed:
                return False
            self._queue.append(job)
            return True

    def close(self) -> Tuple[List[Job], List[Job]]:
        """Stop accepting offers; return ``(never_leased, leased_incomplete)``."""
        with self._lock:
            self.closed = True
            leftovers = [j for j in self._queue if j.status == JobStatus.PENDING]
            self._queue.clear()
            orphans = list(self._live.values())
            self._live.clear()
        return leftovers, orphans

    # -- engine side ------------------------------------------------------------
    def lease(self) -> Optional[Tuple[int, dict]]:
        """Next leasable job as ``(handle, config)``, or None when the queue
        holds nothing leasable right now.  Jobs killed/lost while buffered are
        skipped (a dead clone releases its donor pin); jobs the lifecycle hook
        blocks — a donor's next round while its weights await a pending clone
        copy — rotate to the back and stay queued."""
        with self._lock:
            for _ in range(len(self._queue)):
                job = self._queue.popleft()
                if job.status != JobStatus.PENDING:
                    # killed/lost while buffered: the Experiment's retry path
                    # re-offers the same config, so any donor pin stays held
                    # until the retried clone executes (or fails for good)
                    continue
                if self._lifecycle is not None and self._lifecycle.lease_blocked(
                        dict(job.config)):
                    self._queue.append(job)
                    if job.job_id not in self._donor_waited:
                        self._donor_waited.add(job.job_id)
                        self.n_donor_waits += 1
                    continue
                handle = self._next_handle
                self._next_handle += 1
                self._live[handle] = job
                self.n_leased += 1
                job.mark_running()
                return handle, dict(job.config)
            return None

    def complete(self, handle: int, score: float, extra: Any = None) -> None:
        """Retire a leased job with its score — fires the job callback now,
        while the flight keeps running (the streaming-result path).  Jobs
        already settled (deadline-killed mid-lane) do not count as streamed."""
        with self._lock:
            job = self._live.pop(handle, None)
        if job is None or job.done:  # already settled: deadline-killed mid-lane
            return
        # count before finish: the finish callback can end the experiment, and
        # readers of the counters must see this result included.  (A kill
        # landing in between is a benign +-1 on telemetry.)
        with self._lock:
            self.n_streamed += 1
        if self._on_stream is not None:
            self._on_stream()
        job.finish(JobResult(score=float(score), extra=extra))

    def complete_retirements(self, events: List[Tuple[int, float, Any]]) -> None:
        """Consume a device dispatch's emitted retirement log (--device-rules):
        one ``(handle, score, extra)`` triple per lane the in-scan rules ended.
        Each settles through ``complete`` — streaming semantics, counters and
        callbacks unchanged — but they arrive as one batch per dispatch rather
        than one host sync per event step, and ``n_device_retired`` records
        that the decisions were made on-device."""
        for handle, score, extra in events:
            self.complete(handle, score, extra=extra)
        with self._lock:
            self.n_device_retired += len(events)

    def fail(self, handle: int, error: str) -> None:
        with self._lock:
            job = self._live.pop(handle, None)
        if job is not None:
            job.fail(str(error))

    # -- supervisor side --------------------------------------------------------
    def reclaim_live(self) -> List[Job]:
        """Pull every leased-but-incomplete job out of the ledger (the flight
        died mid-lane).  The supervisor decides each job's fate: requeue into
        the restarted flight, or quarantine after repeated deaths."""
        with self._lock:
            jobs = list(self._live.values())
            self._live.clear()
        return jobs

    def requeue(self, job: Job) -> None:
        """Put a reclaimed job back at the FRONT of the queue for the
        restarted flight (it already held a lane; it goes first).  The job
        returns to PENDING so ``lease`` picks it up again."""
        job.status = JobStatus.PENDING
        with self._lock:
            self._queue.appendleft(job)


class FlightSupervisor:
    """Restart policy for a streaming flight worker.

    On flight death the worker reclaims the leased lanes and asks this object
    how to proceed: up to ``max_restarts`` restarts with exponential backoff
    (``backoff_base_s * 2**(attempt-1)``, capped) plus deterministic jitter —
    seeded, so chaos tests replay exactly — and a poison threshold: a job
    whose lane was leased across ``poison_deaths`` consecutive flight deaths
    is the likely culprit and fails for good (quarantine) instead of riding
    every restart into the ground.
    """

    def __init__(self, max_restarts: int = 2, backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0, poison_deaths: int = 2,
                 seed: int = 0):
        import random

        self.max_restarts = int(max_restarts)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.poison_deaths = int(poison_deaths)
        self._rng = random.Random(seed)

    def delay_s(self, attempt: int) -> float:
        base = self.backoff_base_s * (2.0 ** max(0, attempt - 1))
        return min(self.backoff_cap_s, base) * (1.0 + 0.25 * self._rng.random())


class QueueFeedScheduler:
    """Minimal streaming feed for driving ``run_population(scheduler=...)``
    directly, without Algorithm 1 — a fixed config queue, results keyed by
    lease order.  ``closed=True`` tells the flight no more jobs will ever
    come, so it returns the moment the queue drains instead of lingering for
    late offers.  This is the reference adapter the benchmarks and tests use;
    ``LaneScheduler`` is the Algorithm-1 (Job-backed) implementation of the
    same lease/complete protocol.
    """

    closed = True

    def __init__(self, cfgs) -> None:
        self._q: List[Tuple[int, dict]] = list(enumerate(dict(c) for c in cfgs))
        self.scores: Dict[int, float] = {}
        self.extras: Dict[int, Any] = {}

    def lease(self) -> Optional[Tuple[int, dict]]:
        return self._q.pop(0) if self._q else None

    def complete(self, handle: int, score: float, extra: Any = None) -> None:
        self.scores[handle] = float(score)
        self.extras[handle] = extra

    def ordered_scores(self, n: int) -> List[float]:
        return [self.scores[i] for i in range(n)]


@register("vectorized")
class VectorizedResourceManager(ResourceManager):
    def __init__(self, n_parallel: int = 8, resource_prefix: str = "slot",
                 lane_refill: bool = False, max_flight_restarts: int = 2,
                 restart_backoff_s: float = 0.05,
                 finish_join_timeout_s: float = 30.0, **kwargs):
        super().__init__(**kwargs)
        self.n_slots = int(n_parallel)
        for i in range(self.n_slots):
            self.add_resource(f"{resource_prefix}{i}")
        self._pending: List[Job] = []
        self._last_target: Any = None
        self.lane_refill = bool(lane_refill)
        self._scheduler: Optional[LaneScheduler] = None
        self.n_batches = 0
        self.batch_sizes: List[int] = []
        self.n_streamed = 0        # results delivered mid-flight (refill mode)
        self.n_refill_flights = 0
        self._warned_no_stream = False
        # latched when a runner advertises a scheduler kwarg (e.g. **kwargs)
        # but never leases from it — all later flushes take the batch path
        self._streaming_broken = False
        self._flight_thread: Optional[threading.Thread] = None
        # crash-safety: supervised flight restarts + quarantine + journal
        self.supervisor = FlightSupervisor(
            max_restarts=max_flight_restarts, backoff_base_s=restart_backoff_s)
        self.finish_join_timeout_s = float(finish_join_timeout_s)
        self.journal: Any = None   # FlightJournal, wired by the Experiment
        self.n_flight_deaths = 0
        self.n_flight_restarts = 0
        self.n_quarantined = 0

    # -- Algorithm 1 surface ----------------------------------------------------
    def run(self, job: Job, target: Callable) -> None:
        # jobs stay PENDING while buffered: the straggler deadline clock only
        # starts when the batch actually executes (mark_running in the worker)
        self.bind(job.resource_id, job)
        with self._lock:
            self._last_target = target
            sch = self._scheduler
            if sch is not None and sch.offer(job):
                return  # spliced straight into the live streaming flight
            self._pending.append(job)
            full = len(self._pending) >= self.n_slots
        if full:
            self._flush(target)

    def release(self, res_id: Any) -> None:
        super().release(res_id)
        # an unbound slot coming back with jobs buffered == "no more proposals
        # are coming before a callback fires" -> run the partial population
        with self._lock:
            has_pending = bool(self._pending)
            target = self._last_target
        if has_pending and target is not None:
            self._flush(target)

    def _flush(self, target: Callable) -> None:
        """Claim the buffer atomically and start one batch/flight worker.

        All buffer handoff happens under the lock: a concurrent ``run()`` /
        ``release()`` pair can race into ``_flush`` freely — exactly one of
        them claims the batch (the other finds the buffer empty or a live
        flight absorbing it), so no job is ever double-flushed or stranded.
        """
        runner = getattr(target, "run_population", None)
        with self._lock:
            sch = self._scheduler
            if sch is not None:
                # a streaming flight is live: drain the buffer into it.  Offers
                # refused by a closing flight stay pending — the flight worker
                # re-flushes after it clears ``_scheduler``.
                self._pending = [j for j in self._pending if not sch.offer(j)]
                return
            batch, self._pending = self._pending, []
            if not batch:
                return
            self.n_batches += 1
            self.batch_sizes.append(len(batch))
            streaming = (
                self.lane_refill
                and not self._streaming_broken
                and runner is not None
                and accepts_kwarg(runner, "scheduler")
            )
            if self.lane_refill and not streaming and not self._warned_no_stream:
                # fall back to batch mode, but never silently: the user asked
                # for streaming and this target cannot do it
                self._warned_no_stream = True
                warnings.warn(
                    "lane_refill is enabled but the target does not accept a "
                    "'scheduler' kwarg on run_population; falling back to "
                    "batch-synchronous flights", stacklevel=2)
            if streaming:
                sch = LaneScheduler(
                    on_stream=self._note_streamed,
                    lifecycle=getattr(target, "lifecycle", None),
                )
                for job in batch:
                    sch.offer(job)
                self._scheduler = sch
                self.n_refill_flights += 1
        if streaming:
            self._start_streaming_worker(runner, target, sch)
        else:
            self._start_batch_worker(runner, target, batch)

    # -- batch-synchronous worker (legacy protocol) ------------------------------
    def _start_batch_worker(self, runner: Optional[Callable], target: Callable,
                            batch: List[Job]) -> None:
        def _worker():
            # anything no longer PENDING was killed/lost while buffered
            live = [j for j in batch if j.status == JobStatus.PENDING]
            if not live:
                return
            for job in live:
                job.mark_running()
            try:
                if runner is not None:
                    outs = self._run_batch(runner, [dict(j.config) for j in live])
                    if len(outs) != len(live):
                        raise ValueError(
                            f"run_population returned {len(outs)} results "
                            f"for {len(live)} configs"
                        )
                else:
                    # scalar fallback: per-job blast radius — one bad config
                    # must not take down its batch siblings
                    outs = []
                    for job in live:
                        try:
                            outs.append(target(dict(job.config)))
                        except Exception as e:
                            outs.append(e)
            except Exception as e:  # the one device program died: whole batch
                for job in live:
                    job.fail(f"{type(e).__name__}: {e}")
                return
            for job, out in zip(live, outs):
                try:
                    if isinstance(out, Exception):
                        job.fail(f"{type(out).__name__}: {out}")
                    else:
                        score, extra = out if isinstance(out, tuple) else (out, None)
                        job.finish(JobResult(score=float(score), extra=extra))
                except Exception as e:  # malformed result fails only its job
                    job.fail(f"{type(e).__name__}: {e}")

        threading.Thread(
            target=_worker, name=f"popbatch-{self.n_batches}", daemon=True
        ).start()

    # -- streaming worker (lane-refill protocol) ---------------------------------
    def _start_streaming_worker(self, runner: Callable, target: Callable,
                                sch: LaneScheduler) -> None:
        def _worker():
            import time as _time

            sup = self.supervisor
            attempt = 0
            err: Optional[Exception] = None
            doomed: List[Job] = []  # reclaimed but not requeued (exhausted)
            while True:
                err = None
                try:
                    self._run_batch(runner, [], scheduler=sch)
                except Exception as e:
                    err = e
                if err is None:
                    break
                # -- flight death: reclaim lanes, quarantine poison jobs,
                # restart with backoff (FlightSupervisor policy) ----------------
                with self._lock:
                    self.n_flight_deaths += 1
                msg = f"{type(err).__name__}: {err}"
                if self.journal is not None:
                    self.journal.append("flight_death", detail=msg)
                survivors: List[Job] = []
                for job in sch.reclaim_live():
                    job.flight_deaths = getattr(job, "flight_deaths", 0) + 1
                    if job.flight_deaths >= sup.poison_deaths:
                        # this lane was live across poison_deaths consecutive
                        # flight deaths: quarantine — fail for good, and flag
                        # the job so the Experiment skips its retry budget
                        job.quarantined = True
                        with self._lock:
                            self.n_quarantined += 1
                        if self.journal is not None:
                            self.journal.append(
                                "quarantine", job_id=job.job_id, detail=msg)
                        job.fail(
                            f"quarantined: lane died in {job.flight_deaths} "
                            f"consecutive flights: {msg}")
                    else:
                        survivors.append(job)
                if attempt >= sup.max_restarts:
                    doomed = survivors
                    break
                attempt += 1
                for job in survivors:
                    sch.requeue(job)
                self._on_flight_death(attempt)
                with self._lock:
                    self.n_flight_restarts += 1
                if self.journal is not None:
                    self.journal.append("flight_restart", step=attempt, detail=msg)
                _time.sleep(sup.delay_s(attempt))
            leftovers, orphans = sch.close()
            orphans = doomed + orphans
            with self._lock:
                self._scheduler = None
                if err is None and sch.n_leased == 0 and leftovers:
                    # the runner took a 'scheduler' kwarg (**kwargs?) but never
                    # leased a job: it cannot actually stream.  Without this
                    # latch the re-flush below would pick streaming again and
                    # livelock on zero-progress flights.
                    self._streaming_broken = True
                if err is None:
                    # offers that landed after the flight's last lease check
                    # seed the next flight instead of being stranded
                    self._pending = leftovers + self._pending
                has_pending = bool(self._pending)
                broken = self._streaming_broken
            if err is not None:
                msg = f"{type(err).__name__}: {err}"
                for job in orphans:
                    job.fail(f"streaming flight died mid-lane: {msg}")
                # never-leased jobs fail too (bounded per-lineage retries in
                # the Experiment), rather than looping a broken engine forever
                for job in leftovers:
                    job.fail(f"streaming flight died before lease: {msg}")
            else:
                for job in orphans:  # engine returned without completing a lease
                    job.fail("streaming flight ended without completing the lane")
                if broken and not self._warned_no_stream:
                    self._warned_no_stream = True
                    warnings.warn(
                        "lane_refill is enabled but the target's run_population "
                        "never leased from the scheduler; falling back to "
                        "batch-synchronous flights", stacklevel=2)
                if has_pending:
                    self._flush(target)

        t = threading.Thread(
            target=_worker, name=f"popflight-{self.n_batches}", daemon=True
        )
        with self._lock:
            self._flight_thread = t
        t.start()

    def _on_flight_death(self, attempt: int) -> None:
        """Subclass hook, called once per supervised restart (before the
        backoff sleep).  The sharded manager uses it to degrade the mesh
        (sharded -> vmapped) when the flight keeps dying."""

    def _note_streamed(self) -> None:
        # live counter: the experiment loop reads it while flights still run
        with self._lock:
            self.n_streamed += 1

    def _run_batch(self, runner: Callable, configs: List[dict],
                   scheduler: Optional[LaneScheduler] = None) -> List[Any]:
        """Execute one buffered batch (or streaming flight).  Subclass hook:
        the sharded manager passes its device mesh through here."""
        if scheduler is not None:
            return runner(configs, scheduler=scheduler)
        return runner(configs)

    def finish(self) -> None:
        """The experiment loop is done: close the live streaming flight now
        instead of letting it linger for its idle grace (and burn a polling
        loop until the grace expires), then join the flight worker so no
        thread is still mid-XLA-call when the caller tears the process down.
        Any jobs the close hands back were settled already — the loop only
        exits with nothing running — but they re-buffer defensively rather
        than being dropped.

        A worker still alive after ``finish_join_timeout_s`` is a *hung*
        flight (deadlocked lease loop, wedged XLA call): its leased jobs are
        failed so their callbacks fire, and a RuntimeError surfaces — a
        silent return here would let the caller tear down the process under
        a thread that still owns device buffers."""
        with self._lock:
            sch = self._scheduler
            worker = self._flight_thread
        orphans: List[Job] = []
        if sch is not None:
            leftovers, orphans = sch.close()
            if leftovers:
                with self._lock:
                    self._pending = leftovers + self._pending
        if worker is not None and worker is not threading.current_thread():
            worker.join(timeout=self.finish_join_timeout_s)
            if worker.is_alive():
                for job in orphans:
                    if not job.done:
                        job.fail(
                            f"streaming flight hung: worker still alive "
                            f"{self.finish_join_timeout_s:.1f}s after close")
                if self.journal is not None:
                    self.journal.append(
                        "flight_hung",
                        detail=f"join timeout {self.finish_join_timeout_s}s")
                raise RuntimeError(
                    f"streaming flight worker {worker.name!r} did not exit "
                    f"within {self.finish_join_timeout_s:.1f}s of close(); "
                    f"{len(orphans)} leased job(s) failed as hung")

    def kill(self, job: Job) -> None:
        # the batch thread cannot be interrupted; mark KILLED so the eventual
        # positional result is dropped (Job.finish fires exactly once)
        job.fail("killed by deadline", status=JobStatus.KILLED)
