"""Elastic resource pool — scale-out/in + node-failure semantics.

Wraps any base ResourceManager.  ``scale_out(ids)`` adds resources mid-flight
(the boto3/EC2-autoscaling analogue from §III-B1); ``fail_resource(id)``
removes one *while a job may be running on it* — the job is marked LOST and the
Experiment's retry policy re-proposes it.  This is the mechanism the
fault-tolerance integration tests drive.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from . import ResourceManager, register
from ..job import Job, JobStatus


@register("elastic")
class ElasticResourceManager(ResourceManager):
    def __init__(self, base: ResourceManager = None, **kwargs):
        super().__init__(**kwargs)
        if base is None:
            from .local import LocalResourceManager

            base = LocalResourceManager(n_parallel=kwargs.get("n_parallel", 1))
        self.base = base
        self.lost_jobs = []

    # delegate pool bookkeeping to the base manager -----------------------------
    def get_available(self) -> Optional[Any]:
        return self.base.get_available()

    def release(self, res_id: Any) -> None:
        self.base.release(res_id)

    def n_total(self) -> int:
        return self.base.n_total()

    def n_free(self) -> int:
        return self.base.n_free()

    def bind(self, res_id: Any, job: Job) -> None:
        self.base.bind(res_id, job)

    def run(self, job: Job, target: Any) -> None:
        self.base.run(job, target)

    def kill(self, job: Job) -> None:
        self.base.kill(job)

    # elasticity -----------------------------------------------------------------
    def scale_out(self, res_ids) -> None:
        for r in res_ids:
            self.base.add_resource(r)

    # common alias
    add_resources = scale_out

    def scale_in(self, res_ids) -> None:
        for r in res_ids:
            victim = self.base.remove_resource(r)
            if victim is not None:
                self.lost_jobs.append(victim)
                victim.fail(f"resource {r} removed", status=JobStatus.LOST)

    def fail_resource(self, res_id: Any) -> Optional[Job]:
        """Simulate a node failure: resource disappears, running job is LOST."""
        victim = self.base.remove_resource(res_id)
        if victim is not None:
            self.lost_jobs.append(victim)
            victim.fail(f"node failure on {res_id}", status=JobStatus.LOST)
        return victim
