"""BasicConfig + ``print_result`` — the paper's job-side protocol (§III-A1, Code 1/3).

A job receives its hyperparameters as a JSON file whose path is ``sys.argv[1]``;
it reports its score by printing a single tagged line to stdout.  The script
remains independently runnable (the config has defaults), which is the paper's
key usability claim: the SAME script works standalone and under the framework.
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, Optional

RESULT_TAG = "#Auptimizer:"


class BasicConfig(dict):
    """A dict with ``load``/``save`` helpers (paper §III-A1).

    ``BasicConfig(**defaults).load(sys.argv[1])`` is the adoption one-liner:
    defaults keep the script standalone-runnable; the framework's JSON file
    overrides them at job time.  Attribute access mirrors the released tool.
    """

    def __getattr__(self, key: str) -> Any:
        try:
            return self[key]
        except KeyError as e:  # pragma: no cover - attribute protocol
            raise AttributeError(key) from e

    def __setattr__(self, key: str, value: Any) -> None:
        self[key] = value

    def load(self, path: Optional[str] = None) -> "BasicConfig":
        """Merge JSON file at ``path`` over the defaults; returns self."""
        if path:
            with open(path, "r") as f:
                self.update(json.load(f))
        return self

    def load_argv(self) -> "BasicConfig":
        """Convenience: load from sys.argv[1] when present."""
        return self.load(sys.argv[1] if len(sys.argv) > 1 else None)

    def save(self, path: str) -> "BasicConfig":
        with open(path, "w") as f:
            json.dump(dict(self), f, indent=1, sort_keys=True, default=str)
        return self

    def to_json(self) -> str:
        return json.dumps(dict(self), sort_keys=True, default=str)


def print_result(result: Any, extra: Any = None, file=None) -> None:
    """Report a job's score back to the framework (paper Code 3, line 10).

    ``result`` is the scalar score (higher is better by convention; the
    experiment config's ``target`` field can flip it).  ``extra`` is the
    "arbitrary string passed back to Proposer" mentioned in §III-B2 — used
    e.g. by Hyperband to hand back a checkpoint path.
    """
    payload: Dict[str, Any] = {"score": float(result)}
    if extra is not None:
        payload["extra"] = extra
    out = file if file is not None else sys.stdout
    print(RESULT_TAG + json.dumps(payload), file=out, flush=True)


def parse_result(stdout_text: str) -> Dict[str, Any]:
    """Extract the last tagged result line from a job's stdout.

    Raises ValueError when the job never reported — the experiment marks such
    jobs FAILED rather than crashing the whole run.
    """
    last = None
    for line in stdout_text.splitlines():
        line = line.strip()
        if line.startswith(RESULT_TAG):
            last = line[len(RESULT_TAG):]
    if last is None:
        raise ValueError("job produced no result line (expected `print_result(...)`)")
    return json.loads(last)
