from .database import TrackingDB
from .visualizer import best_so_far, summarize_experiment, hyperparameter_table

__all__ = ["TrackingDB", "best_so_far", "summarize_experiment", "hyperparameter_table"]
