"""Result visualization (paper §III-C / §IV-D) — terminal/CSV oriented.

The released Auptimizer ships a matplotlib dashboard; in this container the
equivalents are text tables and CSV emitters that the benchmarks print, plus
the raw SQLite tables the user can query directly (the paper's own suggestion).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from .database import TrackingDB


def best_so_far(db: TrackingDB, exp_id: int, maximize: bool = True) -> List[float]:
    """Monotone best-score trace in job-completion order (paper Fig. 5)."""
    rows = [r for r in db.jobs(exp_id, status="finished") if r["score"] is not None]
    rows.sort(key=lambda r: (r["end_time"] or 0.0))
    out: List[float] = []
    cur = None
    for r in rows:
        s = r["score"]
        if cur is None or (s > cur if maximize else s < cur):
            cur = s
        out.append(cur)
    return out


def hyperparameter_table(db: TrackingDB, exp_id: int, names: List[str]) -> List[Dict[str, Any]]:
    """Per-job hyperparameter values + score (paper Fig. 4 raw data)."""
    rows = db.jobs(exp_id, status="finished")
    return [
        {**{n: r["config"].get(n) for n in names}, "score": r["score"], "job_id": r["job_id"]}
        for r in rows
    ]


def summarize_experiment(db: TrackingDB, exp_id: int, maximize: bool = True) -> Dict[str, Any]:
    exp = db.get_experiment(exp_id)
    jobs = db.jobs(exp_id)
    finished = [j for j in jobs if j["status"] == "finished" and j["score"] is not None]
    failed = [j for j in jobs if j["status"] in ("failed", "killed", "lost")]
    best = db.best_job(exp_id, maximize=maximize)
    durations = [
        (j["end_time"] - j["start_time"])
        for j in finished
        if j["end_time"] and j["start_time"]
    ]
    return {
        "exp_id": exp_id,
        "proposer": exp["exp_config"].get("proposer"),
        "n_jobs": len(jobs),
        "n_finished": len(finished),
        "n_failed": len(failed),
        "best_score": None if best is None else best["score"],
        "best_config": None if best is None else best["config"],
        "total_job_time_s": sum(durations),
        "mean_job_time_s": (sum(durations) / len(durations)) if durations else 0.0,
        "wall_time_s": (exp["end_time"] or 0) - (exp["start_time"] or 0),
    }


def format_table(rows: List[Dict[str, Any]], columns: Optional[List[str]] = None) -> str:
    if not rows:
        return "(empty)"
    cols = columns or list(rows[0].keys())
    widths = {c: max(len(str(c)), max(len(f"{r.get(c)}") for r in rows)) for c in cols}
    header = " | ".join(str(c).ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(" | ".join(f"{r.get(c)}".ljust(widths[c]) for c in cols) for r in rows)
    return f"{header}\n{sep}\n{body}"
