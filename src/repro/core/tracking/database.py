"""SQLite experiment tracking — the paper's Fig. 2 schema.

Tables: ``user``, ``experiment``, ``resource``, ``job``.  The database is the
experiment's source of truth: every proposal and every result lands here
*before* it is acted on, which is what makes crash-resume possible
(`Experiment.resume()` replays finished jobs into the proposer and re-queues
the ones that were mid-flight).

WAL mode + a single writer lock keep it safe under the async callback threads.
"""
from __future__ import annotations

import json
import os
import sqlite3
import threading
import time
from typing import Any, Dict, List, Optional

_SCHEMA = """
CREATE TABLE IF NOT EXISTS user (
    user_id   INTEGER PRIMARY KEY AUTOINCREMENT,
    name      TEXT UNIQUE NOT NULL
);
CREATE TABLE IF NOT EXISTS experiment (
    exp_id     INTEGER PRIMARY KEY AUTOINCREMENT,
    user_id    INTEGER REFERENCES user(user_id),
    exp_config TEXT NOT NULL,
    status     TEXT NOT NULL DEFAULT 'created',
    start_time REAL,
    end_time   REAL
);
CREATE TABLE IF NOT EXISTS resource (
    res_id   TEXT NOT NULL,
    exp_id   INTEGER,
    type     TEXT NOT NULL,
    status   TEXT NOT NULL DEFAULT 'free',
    detail   TEXT,
    PRIMARY KEY (res_id, exp_id)
);
CREATE TABLE IF NOT EXISTS job (
    job_id      INTEGER NOT NULL,
    exp_id      INTEGER NOT NULL REFERENCES experiment(exp_id),
    config      TEXT NOT NULL,
    resource_id TEXT,
    status      TEXT NOT NULL,
    score       REAL,
    extra       TEXT,
    error       TEXT,
    start_time  REAL,
    end_time    REAL,
    PRIMARY KEY (job_id, exp_id)
);
CREATE INDEX IF NOT EXISTS idx_job_exp ON job(exp_id, status);
-- write-ahead flight journal: scheduler ledger transitions, lane cursors,
-- snapshots, flight deaths/restarts/quarantines.  Append-only; --resume
-- reads it to reconstruct where every streaming lane was at the crash.
CREATE TABLE IF NOT EXISTS flight_journal (
    seq      INTEGER PRIMARY KEY AUTOINCREMENT,
    exp_id   INTEGER NOT NULL,
    time     REAL NOT NULL,
    kind     TEXT NOT NULL,
    job_id   INTEGER,
    lane     INTEGER,
    step     INTEGER,
    detail   TEXT
);
CREATE INDEX IF NOT EXISTS idx_journal_exp ON flight_journal(exp_id, kind, seq);
-- proposer state written ahead of each proposal batch (RNG bit-generator
-- state + counters), so a resumed proposer continues the exact draw sequence
-- the uninterrupted run would have produced.
CREATE TABLE IF NOT EXISTS proposer_state (
    exp_id  INTEGER PRIMARY KEY,
    state   TEXT NOT NULL,
    time    REAL NOT NULL
);
"""


class TrackingDB:
    def __init__(self, path: str = ":memory:"):
        self.path = path
        if path != ":memory:":
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._conn = sqlite3.connect(path, check_same_thread=False)
        self._conn.row_factory = sqlite3.Row
        self._lock = threading.RLock()
        with self._lock:
            if path != ":memory:":
                self._conn.execute("PRAGMA journal_mode=WAL")
            self._conn.execute("PRAGMA synchronous=NORMAL")
            self._conn.executescript(_SCHEMA)
            self._conn.commit()

    # -- user / experiment ---------------------------------------------------
    def get_or_create_user(self, name: str) -> int:
        with self._lock:
            self._conn.execute("INSERT OR IGNORE INTO user(name) VALUES (?)", (name,))
            self._conn.commit()
            row = self._conn.execute("SELECT user_id FROM user WHERE name=?", (name,)).fetchone()
            return int(row["user_id"])

    def create_experiment(self, exp_config: Dict[str, Any], user: str = "default") -> int:
        uid = self.get_or_create_user(user)
        with self._lock:
            cur = self._conn.execute(
                "INSERT INTO experiment(user_id, exp_config, status, start_time) VALUES (?,?,?,?)",
                (uid, json.dumps(exp_config, sort_keys=True, default=str), "running", time.time()),
            )
            self._conn.commit()
            return int(cur.lastrowid)

    def finish_experiment(self, exp_id: int, status: str = "finished") -> None:
        with self._lock:
            self._conn.execute(
                "UPDATE experiment SET status=?, end_time=? WHERE exp_id=?",
                (status, time.time(), exp_id),
            )
            self._conn.commit()

    def get_experiment(self, exp_id: int) -> Optional[Dict[str, Any]]:
        with self._lock:
            row = self._conn.execute(
                "SELECT * FROM experiment WHERE exp_id=?", (exp_id,)
            ).fetchone()
        if row is None:
            return None
        d = dict(row)
        d["exp_config"] = json.loads(d["exp_config"])
        return d

    def latest_experiment_id(self) -> Optional[int]:
        with self._lock:
            row = self._conn.execute("SELECT MAX(exp_id) AS m FROM experiment").fetchone()
        return None if row is None or row["m"] is None else int(row["m"])

    # -- resources ------------------------------------------------------------
    def register_resource(self, res_id: str, rtype: str, exp_id: int = 0, detail: str = "") -> None:
        with self._lock:
            self._conn.execute(
                "INSERT OR REPLACE INTO resource(res_id, exp_id, type, status, detail) VALUES (?,?,?,?,?)",
                (str(res_id), exp_id, rtype, "free", detail),
            )
            self._conn.commit()

    def set_resource_status(self, res_id: str, status: str, exp_id: int = 0) -> None:
        with self._lock:
            self._conn.execute(
                "UPDATE resource SET status=? WHERE res_id=? AND exp_id=?",
                (status, str(res_id), exp_id),
            )
            self._conn.commit()

    def list_resources(self, exp_id: int = 0, status: Optional[str] = None) -> List[Dict[str, Any]]:
        q = "SELECT * FROM resource WHERE exp_id=?"
        args: List[Any] = [exp_id]
        if status is not None:
            q += " AND status=?"
            args.append(status)
        with self._lock:
            rows = self._conn.execute(q, args).fetchall()
        return [dict(r) for r in rows]

    # -- jobs ------------------------------------------------------------------
    def record_job_start(self, exp_id: int, job_id: int, config_json: str, resource_id: str) -> None:
        with self._lock:
            self._conn.execute(
                "INSERT OR REPLACE INTO job(job_id, exp_id, config, resource_id, status, start_time)"
                " VALUES (?,?,?,?,?,?)",
                (job_id, exp_id, config_json, str(resource_id), "running", time.time()),
            )
            self._conn.commit()

    def record_job_end(
        self,
        exp_id: int,
        job_id: int,
        status: str,
        score: Optional[float],
        extra: Any = None,
        error: Optional[str] = None,
    ) -> None:
        with self._lock:
            self._conn.execute(
                "UPDATE job SET status=?, score=?, extra=?, error=?, end_time=?"
                " WHERE job_id=? AND exp_id=?",
                (
                    status,
                    score,
                    None if extra is None else json.dumps(extra, default=str),
                    error,
                    time.time(),
                    job_id,
                    exp_id,
                ),
            )
            self._conn.commit()

    def jobs(self, exp_id: int, status: Optional[str] = None) -> List[Dict[str, Any]]:
        q = "SELECT * FROM job WHERE exp_id=?"
        args: List[Any] = [exp_id]
        if status is not None:
            q += " AND status=?"
            args.append(status)
        q += " ORDER BY job_id"
        with self._lock:
            rows = self._conn.execute(q, args).fetchall()
        out = []
        for r in rows:
            d = dict(r)
            d["config"] = json.loads(d["config"])
            if d.get("extra"):
                try:
                    d["extra"] = json.loads(d["extra"])
                except (TypeError, json.JSONDecodeError):
                    pass
            out.append(d)
        return out

    def best_job(self, exp_id: int, maximize: bool = True) -> Optional[Dict[str, Any]]:
        order = "DESC" if maximize else "ASC"
        with self._lock:
            row = self._conn.execute(
                f"SELECT * FROM job WHERE exp_id=? AND score IS NOT NULL ORDER BY score {order} LIMIT 1",
                (exp_id,),
            ).fetchone()
        if row is None:
            return None
        d = dict(row)
        d["config"] = json.loads(d["config"])
        return d

    # -- flight journal / proposer state (crash-safe streaming) ----------------
    def journal_append(
        self,
        exp_id: int,
        kind: str,
        job_id: Optional[int] = None,
        lane: Optional[int] = None,
        step: Optional[int] = None,
        detail: Any = None,
    ) -> None:
        """Append one write-ahead journal row (lease / snapshot / retire /
        flight_death / restart / quarantine / resume ...)."""
        with self._lock:
            self._conn.execute(
                "INSERT INTO flight_journal(exp_id, time, kind, job_id, lane, step, detail)"
                " VALUES (?,?,?,?,?,?,?)",
                (
                    exp_id, time.time(), kind, job_id, lane, step,
                    None if detail is None else json.dumps(detail, default=str),
                ),
            )
            self._conn.commit()

    def journal_rows(self, exp_id: int, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        q = "SELECT * FROM flight_journal WHERE exp_id=?"
        args: List[Any] = [exp_id]
        if kind is not None:
            q += " AND kind=?"
            args.append(kind)
        q += " ORDER BY seq"
        with self._lock:
            rows = self._conn.execute(q, args).fetchall()
        out = []
        for r in rows:
            d = dict(r)
            if d.get("detail"):
                try:
                    d["detail"] = json.loads(d["detail"])
                except (TypeError, json.JSONDecodeError):
                    pass
            out.append(d)
        return out

    def save_proposer_state(self, exp_id: int, state: Dict[str, Any]) -> None:
        with self._lock:
            self._conn.execute(
                "INSERT OR REPLACE INTO proposer_state(exp_id, state, time) VALUES (?,?,?)",
                (exp_id, json.dumps(state, default=str), time.time()),
            )
            self._conn.commit()

    def load_proposer_state(self, exp_id: int) -> Optional[Dict[str, Any]]:
        with self._lock:
            row = self._conn.execute(
                "SELECT state FROM proposer_state WHERE exp_id=?", (exp_id,)
            ).fetchone()
        return None if row is None else json.loads(row["state"])

    def close(self) -> None:
        with self._lock:
            self._conn.close()


class FlightJournal:
    """Thin per-experiment adapter over the ``flight_journal`` table.

    The Experiment wires one of these onto any target / resource manager that
    exposes a ``journal`` attribute, so the streaming engine and the flight
    supervisor append ledger rows without holding an ``exp_id`` themselves.
    Appends are swallowed-on-error by design: journaling must never take down
    a healthy flight (the journal improves recovery, it is not the data path).
    """

    def __init__(self, db: TrackingDB, exp_id: int):
        self.db = db
        self.exp_id = int(exp_id)

    def append(self, kind: str, job_id: Optional[int] = None,
               lane: Optional[int] = None, step: Optional[int] = None,
               detail: Any = None) -> None:
        try:
            self.db.journal_append(self.exp_id, kind, job_id=job_id,
                                   lane=lane, step=step, detail=detail)
        except Exception:
            pass

    def rows(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        return self.db.journal_rows(self.exp_id, kind=kind)
