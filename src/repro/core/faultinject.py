"""Deterministic fault injection for the crash-safety paths.

Recovery code that only runs when something actually dies is recovery code
that never runs in CI.  This module lets tests (and the ``chaos`` CI lane)
arm *precise, reproducible* faults at well-defined points of the execution —
no random kill loops, no sleeps, no flakes — so every recovery path
(supervised flight restart, lane snapshot/restore, ``--resume``, poison-lane
quarantine) is exercised by construction.

A fault *plan* is parsed from a spec string (CLI ``--fault-spec`` or the
``REPRO_FAULT_SPEC`` env var — the env form is how the subprocess SIGKILL
harness arms a child process).  Clauses are ``;``-separated::

    raise@step=K[,times=N]     raise InjectedFault in the flight loop once the
                               global flight step reaches K (N firings, default 1)
    nan@lane=L,step=K          poison lane L's loss to NaN at flight step K
                               (sets the divergence latch — the engine's
                               ordinary divergence path takes over)
    kill@event=N               SIGKILL the process at the N-th streaming event
                               boundary (counted across flights, after any due
                               snapshot harvest — "crash at an arbitrary event
                               boundary")
    raise@issue=N              raise in the Experiment loop right before job N
                               is issued (the classic between-batches crash)

The instrumented sites call :func:`check` / :func:`poison_lanes`; both are
no-ops (one ``is None`` test) when no plan is armed, so production runs pay
nothing.  Fired clauses are recorded on the plan (``plan.fired``) for test
assertions.
"""
from __future__ import annotations

import dataclasses
import os
import signal
from typing import Any, Dict, List, Optional, Tuple

ENV_VAR = "REPRO_FAULT_SPEC"


class InjectedFault(RuntimeError):
    """Raised by an armed ``raise`` clause — stands in for a flight death."""


@dataclasses.dataclass
class _Clause:
    action: str          # "raise" | "kill" | "nan"
    site: str            # "flight-step" | "event" | "issue" | "lane-nan"
    cond: Dict[str, int]
    times: int           # firings left
    spec: str            # original clause text, for messages/telemetry


def _parse_clause(text: str) -> _Clause:
    action, _, rest = text.partition("@")
    action = action.strip().lower()
    if action not in ("raise", "kill", "nan"):
        raise ValueError(f"unknown fault action {action!r} in {text!r}")
    cond: Dict[str, int] = {}
    times = 1
    for part in filter(None, (p.strip() for p in rest.split(","))):
        key, _, val = part.partition("=")
        if not val:
            raise ValueError(f"malformed fault condition {part!r} in {text!r}")
        if key == "times":
            times = int(val)
        else:
            cond[key] = int(val)
    if action == "nan":
        if "lane" not in cond or "step" not in cond:
            raise ValueError(f"nan fault needs lane= and step=: {text!r}")
        site = "lane-nan"
    elif "event" in cond:
        site = "event"
    elif "issue" in cond:
        site = "issue"
    elif "step" in cond:
        site = "flight-step"
    else:
        raise ValueError(f"fault {text!r} needs a step=/event=/issue= condition")
    return _Clause(action=action, site=site, cond=cond, times=times, spec=text)


class FaultPlan:
    """A parsed, stateful fault plan.  Clauses fire at most ``times`` each;
    firings are appended to ``fired`` as ``(clause_spec, coords)``."""

    def __init__(self, spec: str):
        self.spec = spec
        self.clauses: List[_Clause] = [
            _parse_clause(c) for c in filter(None, (s.strip() for s in spec.split(";")))
        ]
        if not self.clauses:
            raise ValueError(f"empty fault spec {spec!r}")
        self.fired: List[Tuple[str, Dict[str, Any]]] = []

    def _fire(self, clause: _Clause, coords: Dict[str, Any]) -> None:
        clause.times -= 1
        self.fired.append((clause.spec, dict(coords)))
        if clause.action == "kill":
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedFault(f"injected fault {clause.spec!r} at {coords}")

    def check(self, site: str, **coords: int) -> None:
        """Fire any armed clause matching ``site`` whose threshold is reached.

        Thresholds compare ``>=`` on the site's coordinate (``step``, ``event``
        or ``issue``), so a site polled at coarse granularity (chunked flights,
        event boundaries) still fires at the first opportunity past K.
        """
        for clause in self.clauses:
            if clause.site != site or clause.times <= 0:
                continue
            key = {"flight-step": "step", "event": "event", "issue": "issue"}[site]
            if coords.get(key, -1) >= clause.cond[key]:
                self._fire(clause, coords)

    def poison_lanes(self, step: int) -> List[int]:
        """Lanes whose ``nan`` clause is due at flight step ``step`` (each
        clause fires once; the caller NaNs the lane's loss / sets the latch)."""
        out = []
        for clause in self.clauses:
            if clause.site == "lane-nan" and clause.times > 0 \
                    and step >= clause.cond["step"]:
                clause.times -= 1
                self.fired.append((clause.spec, {"step": step}))
                out.append(clause.cond["lane"])
        return out


_PLAN: Optional[FaultPlan] = None
_ENV_CHECKED = False


def arm(spec: str) -> FaultPlan:
    """Arm a fault plan for this process (replaces any previous plan)."""
    global _PLAN, _ENV_CHECKED
    _PLAN = FaultPlan(spec)
    _ENV_CHECKED = True
    return _PLAN


def disarm() -> None:
    global _PLAN, _ENV_CHECKED
    _PLAN = None
    _ENV_CHECKED = True  # an explicit disarm also wins over the env var


def get_plan() -> Optional[FaultPlan]:
    """The armed plan, if any.  Checks ``REPRO_FAULT_SPEC`` once, lazily, so a
    subprocess harness can arm a child by environment alone."""
    global _PLAN, _ENV_CHECKED
    if _PLAN is None and not _ENV_CHECKED:
        _ENV_CHECKED = True
        spec = os.environ.get(ENV_VAR, "").strip()
        if spec:
            _PLAN = FaultPlan(spec)
    return _PLAN


def check(site: str, **coords: int) -> None:
    """Module-level convenience: no-op unless a plan is armed."""
    plan = get_plan()
    if plan is not None:
        plan.check(site, **coords)
