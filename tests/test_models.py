"""Per-arch smoke tests (reduced configs) + train/decode consistency.

Every assigned architecture must: instantiate its reduced config, run one
forward/train step on CPU with finite loss and correct shapes, and (decoder
archs) produce decode-step logits consistent with the full forward pass.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cells, get_config, get_smoke_config
from repro.configs.base import SHAPES, ParallelConfig, TrainConfig
from repro.models import transformer as T
from repro.train.train_step import init_train_state, make_train_step


def _batch(cfg, B=2, S=16):
    if cfg.frontend != "none":
        return {
            "embeds": jnp.zeros((B, S, cfg.d_model), jnp.bfloat16),
            "targets": jnp.zeros((B, S), jnp.int32),
            "mask": jnp.ones((B, S), jnp.float32),
        }
    return {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "targets": jnp.zeros((B, S), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    tc = TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"))
    state = init_train_state(jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(tc))
    state, metrics = step(state, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params updated and finite
    leaf = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    if cfg.frontend != "none":
        logits, _ = T.forward(params, None, cfg, remat="none",
                              inputs_embeds=jnp.zeros((B, S, cfg.d_model), jnp.bfloat16))
    else:
        logits, _ = T.forward(params, jnp.zeros((B, S), jnp.int32), cfg, remat="none")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if not get_config(a).encoder_only])
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == full forward logits (same params)."""
    cfg = get_smoke_config(arch)
    if cfg.frontend != "none":
        pytest.skip("frontend stubs feed embeddings; decode consistency n/a")
    if cfg.has_moe:
        # forward uses capacity-dropping dispatch, decode is dropless; a huge
        # capacity factor makes the two exact so the path equality is testable
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S = 1, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, toks, cfg, remat="none")
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t : t + 1], t)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, t]), atol=0.08, rtol=0.08
        )


def test_forward_last_only_matches_full():
    cfg = get_smoke_config("starcoder2-3b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.arange(10, dtype=jnp.int32)[None, :] % cfg.vocab_size
    full, _ = T.forward(params, toks, cfg, remat="none")
    last, _ = T.forward(params, toks, cfg, remat="none", last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -1]), atol=1e-4)


def test_remat_matches_no_remat():
    cfg = get_smoke_config("phi3-mini-3.8b")
    tc0 = TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"))
    tc1 = TrainConfig(model=cfg, parallel=ParallelConfig(remat="full"))
    s0 = init_train_state(jax.random.PRNGKey(0), tc0)
    s1 = jax.tree.map(lambda a: a, s0)
    b = _batch(cfg)
    _, m0 = jax.jit(make_train_step(tc0))(s0, b)
    _, m1 = jax.jit(make_train_step(tc1))(s1, b)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-5)


def test_microbatch_matches_full_batch():
    """Gradient accumulation over microbatches ~= one big batch step."""
    cfg = get_smoke_config("starcoder2-3b")
    tc0 = TrainConfig(model=cfg, parallel=ParallelConfig(microbatch=0, grad_allreduce_dtype="float32"))
    tc1 = TrainConfig(model=cfg, parallel=ParallelConfig(microbatch=2, grad_allreduce_dtype="float32"))
    state0 = init_train_state(jax.random.PRNGKey(0), tc0)
    state1 = jax.tree.map(lambda a: a, state0)
    batch = _batch(cfg, B=4, S=16)
    s0, m0 = jax.jit(make_train_step(tc0))(state0, batch)
    s1, m1 = jax.jit(make_train_step(tc1))(state1, batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-5)
    a0 = jax.tree.leaves(s0["params"])[0].astype(jnp.float32)
    a1 = jax.tree.leaves(s1["params"])[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(a0), np.asarray(a1), atol=2e-3)


def test_param_counts_match_instantiated():
    """Analytic param_counts()['total'] == actual parameter count (full configs)."""
    for arch in ("starcoder2-3b", "qwen3-moe-30b-a3b", "falcon-mamba-7b", "deepseek-v2-lite-16b"):
        cfg = get_smoke_config(arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        n_actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        n_analytic = cfg.param_counts()["total"]
        # analytic count omits tiny norm vectors; allow 2%
        assert abs(n_actual - n_analytic) / n_actual < 0.02, (arch, n_actual, n_analytic)


def test_cell_skip_rules():
    """Shape-cell skips follow the assignment rules."""
    table = {a: dict((s.name, skip) for s, skip in cells(a)) for a in ARCH_IDS}
    # encoder-only: no decode cells
    assert table["hubert-xlarge"]["decode_32k"] is not None
    assert table["hubert-xlarge"]["long_500k"] is not None
    assert table["hubert-xlarge"]["prefill_32k"] is None
    # ssm / hybrid run long_500k
    assert table["falcon-mamba-7b"]["long_500k"] is None
    # full-attention archs skip long_500k
    for a in ("gemma2-9b", "phi3-mini-3.8b", "granite-34b", "pixtral-12b"):
        assert table[a]["long_500k"] is not None
    # everything runs train_4k
    for a in ARCH_IDS:
        assert table[a]["train_4k"] is None


def test_full_configs_match_assignment():
    """Spot-check the exact published dimensions of the full configs."""
    g = get_config("gemma2-9b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff, g.vocab_size) == \
        (42, 3584, 16, 8, 14336, 256000)
    assert g.attn_softcap == 50.0 and g.final_softcap == 30.0
    j = get_config("jamba-1.5-large-398b")
    assert (j.n_layers, j.d_model, j.n_experts, j.moe_top_k) == (72, 8192, 16, 2)
    assert j.has_mamba and j.has_attention
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.moe_top_k, q.vocab_size) == (128, 8, 151936)
    d = get_config("deepseek-v2-lite-16b")
    assert d.kv_lora_rank == 512 and d.has_moe
    f = get_config("falcon-mamba-7b")
    assert not f.has_attention and f.ssm_state == 16 and f.n_layers == 64
