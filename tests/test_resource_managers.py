"""Resource managers: subprocess (paper-faithful script protocol), mesh pool,
elastic pool with node failure + scale-out, and search-space properties."""
import os
import sys
import textwrap
import time

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.experiment import Experiment
from repro.core.resource.elastic import ElasticResourceManager
from repro.core.resource.local import LocalResourceManager
from repro.core.resource.mesh_pool import MeshPoolResourceManager
from repro.core.search_space import ParamSpec, SearchSpace

SPACE = [
    {"name": "x", "type": "float", "range": [-2.0, 2.0]},
    {"name": "y", "type": "float", "range": [-1.0, 3.0]},
]


def _exp_cfg(**over):
    cfg = {"proposer": "random", "parameter_config": SPACE, "n_samples": 6,
           "n_parallel": 2, "target": "max", "random_seed": 0}
    cfg.update(over)
    return cfg


# ------------------------------------------------------------- subprocess RM
def test_subprocess_script_protocol(tmp_path):
    """Paper Code 3: self-executable script reads argv[1] JSON, print_result."""
    script = tmp_path / "job.py"
    script.write_text(textwrap.dedent(f"""\
        #!{sys.executable}
        import sys
        sys.path.insert(0, {str(os.path.join(os.path.dirname(__file__), "..", "src"))!r})
        from repro.core.basic_config import BasicConfig, print_result
        config = BasicConfig(x=0.0, y=0.0)
        config.load(sys.argv[1] if len(sys.argv) > 1 else None)
        score = -((1 - config.x) ** 2 + 100 * (config.y - config.x ** 2) ** 2)
        print_result(score)
    """))
    script.chmod(0o755)
    exp = Experiment(
        _exp_cfg(resource="subprocess", workdir=str(tmp_path), n_samples=4),
        str(script),
    )
    best = exp.run()
    assert best is not None and np.isfinite(best["score"])
    statuses = [j.status.value for j in exp.job_log]
    assert statuses.count("finished") == 4


def test_subprocess_script_standalone(tmp_path):
    """The same script must run WITHOUT the framework (usability claim)."""
    import subprocess

    script = tmp_path / "job.py"
    script.write_text(textwrap.dedent(f"""\
        import sys
        sys.path.insert(0, {str(os.path.join(os.path.dirname(__file__), "..", "src"))!r})
        from repro.core.basic_config import BasicConfig, print_result
        config = BasicConfig(x=1.0, y=1.0).load(sys.argv[1] if len(sys.argv) > 1 else None)
        print_result(-((1 - config.x) ** 2 + 100 * (config.y - config.x ** 2) ** 2))
    """))
    out = subprocess.run([sys.executable, str(script)], capture_output=True, text=True)
    assert "#Auptimizer:" in out.stdout  # optimum of rosenbrock: score 0


# ------------------------------------------------------------- mesh pool RM
def test_mesh_pool_trials_see_their_slice():
    rm = MeshPoolResourceManager(pod_shape=(4, 4), slice_shape=(2, 2), virtual=True)
    assert rm.n_total() == 4
    seen = []

    def target(cfg, mesh_slice):
        seen.append((cfg["x"], mesh_slice.slice_id, len(mesh_slice.devices)))
        return cfg["x"]

    exp = Experiment(_exp_cfg(n_samples=8, n_parallel=4), target, resource_manager=rm)
    best = exp.run()
    assert best is not None
    assert len(seen) == 8
    assert all(n == 4 for _, _, n in seen), "each trial gets a full 2x2 slice"
    assert len({sid for _, sid, _ in seen}) >= 2, "trials spread across slices"


def test_mesh_pool_real_device_trial():
    """A trial actually jits on its slice's Mesh (1 device on this container)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    rm = MeshPoolResourceManager(pod_shape=(1, 1), slice_shape=(1, 1),
                                 devices=jax.devices())

    def target(cfg, mesh_slice):
        mesh = mesh_slice.mesh(("data", "model"))
        with mesh:
            x = jnp.full((4, 4), float(cfg["x"]))
            y = jax.jit(lambda a: (a * a).sum(),
                        in_shardings=NamedSharding(mesh, P()))(x)
        return float(y)

    exp = Experiment(_exp_cfg(n_samples=3, n_parallel=1), target, resource_manager=rm)
    best = exp.run()
    assert best is not None and best["score"] >= 0


# ------------------------------------------------------------- elastic RM
def test_elastic_node_failure_and_scale_out():
    inner = LocalResourceManager(n_parallel=2)
    rm = ElasticResourceManager(inner)

    def target(cfg):
        time.sleep(0.05)
        return cfg["x"]

    exp = Experiment(_exp_cfg(n_samples=10, n_parallel=2, max_retries=3),
                     target, resource_manager=rm)

    import threading

    chaos_err = []

    def chaos():
        try:
            time.sleep(0.1)
            rm.fail_resource("local0")        # node dies mid-experiment
            time.sleep(0.1)
            rm.scale_out(["extra0", "extra1"])  # scale-out replaces it
        except Exception as e:  # surface thread errors to the assertion below
            chaos_err.append(e)

    t = threading.Thread(target=chaos, daemon=True)
    t.start()
    best = exp.run()
    t.join()
    assert not chaos_err, chaos_err
    assert best is not None
    assert rm.n_total() == 3, "pool = 2 - 1 failed + 2 added"
    done = [j for j in exp.job_log if j.status.value == "finished"]
    assert len(done) >= 10, "all sampled configs eventually finish despite failure"


# ------------------------------------------------------------- search space
@given(st.data())
@settings(max_examples=100, deadline=None)
def test_param_spec_samples_in_bounds(data):
    kind = data.draw(st.sampled_from(["float", "int", "choice"]))
    if kind == "choice":
        values = data.draw(st.lists(st.integers(-5, 5), min_size=1, max_size=5))
        spec = ParamSpec("p", "choice", values)
    else:
        lo = data.draw(st.floats(-100, 100, allow_nan=False))
        width = data.draw(st.floats(0.001, 100, allow_nan=False))
        scale = data.draw(st.sampled_from(["linear", "log"]))
        if scale == "log":
            lo = abs(lo) + 0.001
        if kind == "int":
            width = max(width, 1.0)  # int specs need an integer inside the range
        spec = ParamSpec("p", kind, [lo, lo + width], scale=scale)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    for _ in range(20):
        v = spec.sample(rng)
        if kind == "choice":
            assert v in spec.range
        else:
            assert spec.range[0] <= v <= spec.range[1]
            if kind == "int":
                assert float(v) == int(v)


def test_search_space_grid_monotone_cover():
    spec = ParamSpec("lr", "float", [1e-4, 1e-1], scale="log", n_grid=4)
    lrs = spec.grid()
    assert len(lrs) == 4 and sorted(lrs) == lrs
    assert abs(lrs[0] - 1e-4) < 1e-9 and abs(lrs[-1] - 1e-1) < 1e-9
    # log spacing: constant ratio
    ratios = [lrs[i + 1] / lrs[i] for i in range(3)]
    assert max(ratios) / min(ratios) < 1.001
