"""Compile-once + vmapped population trial engine (the HPO hot path).

Covers the HParams-as-traced-input contract: N trials of one architecture
share a single compiled step; a whole population trains in one vmapped
program with divergence masking; the vectorized resource manager batches the
Experiment loop's jobs; retries are budgeted per job lineage.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.core.experiment import Experiment
from repro.core.proposer import make_proposer
from repro.core.resource.vectorized import VectorizedResourceManager
from repro.core.search_space import SearchSpace
from repro.data.pipeline import SyntheticLM
from repro.launch.hpo import PopulationTrial
from repro.optim.hparams import hparams_from_dict, stack_hparams
from repro.train import population as pop
from repro.train import train_step as ts

SEQ, BATCH, STEPS = 32, 4, 4


@pytest.fixture(scope="module")
def tc():
    cfg = get_smoke_config("starcoder2-3b")
    return TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                       total_steps=STEPS)


@pytest.fixture(scope="module")
def data(tc):
    return SyntheticLM(tc.model.vocab_size, SEQ, BATCH, seed=0)


def _hp(tc, **over):
    base = {"learning_rate": 1e-3, "weight_decay": 0.1, "b2": 0.95,
            "grad_clip": 1.0, "warmup_steps": 2, "total_steps": STEPS}
    base.update(over)
    return hparams_from_dict(base, tc)


# -- compile-once ---------------------------------------------------------------

def test_three_trials_one_compile(tc, data):
    ts.clear_step_cache()
    fn = ts.get_compiled_train_step(tc)
    losses = []
    for lr in (1e-3, 3e-3, 1e-2):
        st = ts.init_train_state(jax.random.PRNGKey(0), tc)
        for s in range(STEPS):
            st, m = fn(st, data.make_batch(s), _hp(tc, learning_rate=lr))
        losses.append(float(m["loss"]))
    assert ts.get_compiled_train_step(tc) is fn, "cache must return the same callable"
    assert fn._cache_size() == 1, "3 trials with distinct hparams must compile exactly once"
    assert len(set(losses)) == 3, "distinct lrs must produce distinct losses"


def test_hparam_step_matches_legacy_closure(tc, data):
    """Traced-hparams formulation is numerically identical to the closure."""
    legacy_tc = TrainConfig(model=tc.model, parallel=tc.parallel,
                            learning_rate=2e-3, warmup_steps=2,
                            total_steps=STEPS, weight_decay=0.05, b2=0.97,
                            grad_clip=0.5)
    s_a = ts.init_train_state(jax.random.PRNGKey(0), legacy_tc)
    s_b = ts.init_train_state(jax.random.PRNGKey(0), legacy_tc)
    legacy = jax.jit(ts.make_train_step(legacy_tc))
    hfn = ts.get_compiled_train_step(legacy_tc)
    hp = _hp(legacy_tc, learning_rate=2e-3, weight_decay=0.05, b2=0.97,
             grad_clip=0.5)
    for s in range(STEPS):
        s_a, m_a = legacy(s_a, data.make_batch(s))
        s_b, m_b = hfn(s_b, data.make_batch(s), hp)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]), rtol=1e-6)


# -- vmapped population ---------------------------------------------------------

def test_vmapped_matches_serial(tc, data):
    cfgs = [
        {"learning_rate": 1e-3, "weight_decay": 0.1, "b2": 0.95, "grad_clip": 1.0},
        {"learning_rate": 5e-3, "weight_decay": 0.0, "b2": 0.99, "grad_clip": 0.5},
        {"learning_rate": 2e-3, "weight_decay": 0.2, "b2": 0.9, "grad_clip": 2.0},
    ]
    hps = [_hp(tc, **c) for c in cfgs]
    fn = ts.get_compiled_train_step(tc)
    serial = []
    for hp in hps:
        st = ts.init_train_state(jax.random.PRNGKey(0), tc)
        for s in range(STEPS):
            st, m = fn(st, data.make_batch(s), hp)
        serial.append(-float(m["loss"]))

    pstep = pop.get_compiled_population_step(tc, len(hps))
    ps = pop.init_population_state(jax.random.PRNGKey(0), tc, len(hps))
    php = stack_hparams(hps)
    for s in range(STEPS):
        ps, _ = pstep(ps, data.make_batch(s), php)
    vec = np.asarray(pop.population_scores(ps))
    np.testing.assert_allclose(vec, np.asarray(serial), rtol=1e-5, atol=1e-6)


def test_divergence_freezes_one_trial_not_the_batch(tc, data):
    hps = [_hp(tc), _hp(tc, learning_rate=1e9, grad_clip=0.0), _hp(tc, learning_rate=2e-3)]
    pstep = pop.get_compiled_population_step(tc, 3)
    ps = pop.init_population_state(jax.random.PRNGKey(0), tc, 3)
    php = stack_hparams(hps)
    for s in range(STEPS):
        ps, _ = pstep(ps, data.make_batch(s), php)
    diverged = np.asarray(ps["diverged"])
    scores = np.asarray(pop.population_scores(ps))
    assert diverged.tolist() == [False, True, False]
    assert scores[1] == -1e9
    assert np.isfinite(scores[[0, 2]]).all() and (scores[[0, 2]] > -1e8).all()
    # healthy trials advanced their full budget; the sick one froze
    steps_done = np.asarray(ps["inner"]["opt"]["step"])
    assert steps_done[0] == STEPS and steps_done[2] == STEPS
    assert steps_done[1] < STEPS


def test_per_trial_budgets_coexist(tc, data):
    """hp.total_steps doubles as the step budget (Hyperband-style rungs)."""
    hps = [_hp(tc, total_steps=2), _hp(tc, total_steps=STEPS)]
    pstep = pop.get_compiled_population_step(tc, 2)
    ps = pop.init_population_state(jax.random.PRNGKey(0), tc, 2)
    php = stack_hparams(hps)
    for s in range(STEPS):
        ps, _ = pstep(ps, data.make_batch(s), php)
    steps_done = np.asarray(ps["inner"]["opt"]["step"])
    assert steps_done.tolist() == [2, STEPS]


# -- experiment integration -----------------------------------------------------

SPACE_JSON = [
    {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-2], "scale": "log"},
    {"name": "weight_decay", "type": "float", "range": [0.0, 0.2]},
]


def test_vectorized_experiment_batches_and_compiles_once():
    ts.clear_step_cache()
    pop.clear_population_cache()
    trial = PopulationTrial("starcoder2-3b", steps=2, batch=2, seq=16, seed=0,
                            population=3)
    rm = VectorizedResourceManager(n_parallel=3)
    exp = Experiment(
        {"proposer": "random", "parameter_config": SPACE_JSON, "n_samples": 7,
         "n_parallel": 3, "target": "max", "random_seed": 0},
        trial, resource_manager=rm,
    )
    best = exp.run()
    assert best is not None and best["score"] > -1e8
    assert sum(rm.batch_sizes) == 7
    assert max(rm.batch_sizes) == 3, "full populations must batch at K"
    tc, _ = trial._setup()
    # PopulationTrial defaults to per-trial data streams -> per_trial_batch mode
    assert pop.get_compiled_population_step(tc, 3, per_trial_batch=True)._cache_size() == 1, (
        "partial batches are padded to K: one compile for the whole experiment"
    )


def test_get_params_batched_drain():
    space = SearchSpace.from_json(SPACE_JSON)
    prop = make_proposer("random", space, n_samples=5)
    batch = prop.get_params(3)
    assert len(batch) == 3
    assert len(prop.get_params(10)) == 2, "drain stops at the sample budget"


def test_retry_budget_is_per_job_not_per_config():
    """Two proposals with identical params must not share a retry budget."""
    attempts = []

    def always_fail(cfg):
        attempts.append(cfg["job_id"])
        raise RuntimeError("boom")

    exp = Experiment(
        {"proposer": "grid", "n_samples": 2, "target": "max", "random_seed": 0,
         "n_parallel": 1, "max_retries": 1,
         # a two-value choice with identical values: grid proposes x=1.0 twice
         "parameter_config": [{"name": "x", "type": "choice", "range": [1.0, 1.0]}]},
        always_fail,
    )
    exp.run()
    # identical-param proposals: each lineage gets 1 original + 1 retry
    assert len(attempts) == 4, attempts
    assert exp.proposer.n_failed == 2
