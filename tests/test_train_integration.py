"""Integration: training learns, checkpoint/restore roundtrip, driver resume,
optimizer math, data pipeline determinism."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.train.train_step import init_train_state, make_train_step

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_loss_decreases_on_synthetic_lm():
    cfg = get_smoke_config("starcoder2-3b")
    tc = TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                     learning_rate=3e-3, warmup_steps=3, total_steps=40)
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    state = init_train_state(jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(tc))
    losses = []
    for s in range(40):
        state, m = step(state, data.make_batch(s))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, \
        f"model failed to learn: {losses[:3]} -> {losses[-3:]}"


def test_data_pipeline_deterministic_and_sharded():
    d = SyntheticLM(101, 32, 8, seed=3)
    a = d.make_batch(5)
    b = d.make_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.make_batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host shards are disjoint slices of the global batch distribution
    s0 = d.make_batch(5, shard=0, n_shards=2)
    s1 = d.make_batch(5, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_checkpointer_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.zeros((), jnp.int32), "nested": [jnp.ones(3)]}}
    for s in (10, 20, 30):
        ck.save(s, state, {"loss": 1.0 / s})
    assert ck.all_steps() == [20, 30], "gc keeps only the last `keep`"
    restored, manifest = ck.restore()
    assert manifest["step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    np.testing.assert_array_equal(np.asarray(restored["opt"]["nested"][0]), np.ones(3))


def test_checkpointer_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(1, {"a": jnp.zeros(4)})
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"a": jnp.zeros(2)})
    names = os.listdir(str(tmp_path))
    assert "step_00000005" in names and not any(n.endswith(".tmp") for n in names)


@pytest.mark.slow
def test_train_driver_crash_resume(tmp_path):
    """The launch/train.py driver: crash at step N, resume from checkpoint."""
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "starcoder2-3b",
            "--smoke", "--steps", "24", "--batch", "2", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "8", "--log-every", "50"]
    r1 = subprocess.run(base + ["--fail-at", "18"], env=env, capture_output=True, text=True)
    assert r1.returncode == 17, r1.stderr[-500:]
    r2 = subprocess.run(base, env=env, capture_output=True, text=True)
    assert r2.returncode == 0, r2.stderr[-500:]
    assert "resumed from checkpoint at step 16" in r2.stdout


def test_adamw_weight_decay_and_clip():
    """Gradient clipping caps the global norm; decay shrinks weights."""
    from repro.optim.adamw import adamw_update, init_opt_state

    cfg = get_smoke_config("starcoder2-3b")
    tc = TrainConfig(model=cfg, weight_decay=0.5, grad_clip=1e-9, learning_rate=1.0)
    params = {"w": jnp.ones((4, 4))}
    opt = init_opt_state(params, tc)
    grads = {"w": jnp.full((4, 4), 100.0)}
    new_params, _, metrics = adamw_update(grads, params, opt, 1e-3, tc)
    # with a tiny clip, the update is dominated by weight decay: w shrinks
    assert float(metrics["grad_norm"]) > 1.0
    assert float(jnp.abs(new_params["w"]).max()) < 1.0
