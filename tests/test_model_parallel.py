"""Tensor-parallel population engine: CLI surface, fused-kernel composition
matrix, and crash-resume from a width-2 snapshot.

The width-scaling score equivalences live in ``test_engine_matrix.py``
(``tp_cells``); this module covers the seams around them:

* the ``--model-parallel`` / ``--fused-attention`` / ``--fused-ssm`` CLI
  wiring, including every loud rejection of an unsupported composition;
* the {fused_rmsnorm, fused_attention} x {vmapped, sharded, chunked, ring,
  device-rules} composition matrix — every engine must accept the fused
  train step (the compile caches key on the static ModelConfig fields) and
  make the SAME rule decisions as its unfused twin;
* a supervised width-2 streaming flight killed mid-run must restore its
  lanes from width-2 snapshots and reproduce the uninterrupted scores.
"""
import json
import os

import numpy as np
import pytest

import jax

from harness import ladder, run_batch_cell
from repro.core import faultinject
from repro.launch.hpo import main

eight_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs the 8-virtual-device CPU mesh")

HEAVY = os.environ.get("REPRO_TP_SMOKE") == "1"


# -- CLI rejections: every unsupported composition fails loudly -------------------

BAD_ARGV = [
    # --model-parallel needs the sharded population engine
    ["--vectorize", "8", "--model-parallel", "2"],
    # ... and a width that makes sense
    ["--vectorize", "8", "--shard-population", "--model-parallel", "0"],
    # elastic flights lease their own widths through the pool
    ["--proposer", "asha", "--vectorize", "8", "--shard-population",
     "--inflight-stop", "--elastic-regrid", "--model-parallel", "2"],
    # the legacy baseline predates the kernel bank
    ["--legacy-recompile", "--fused-rmsnorm"],
    ["--legacy-recompile", "--fused-attention"],
    # per-module flags demand the module: starcoder2 has no SSM mixer,
    # falcon-mamba has no attention
    ["--arch", "starcoder2-3b", "--fused-ssm"],
    ["--arch", "falcon-mamba-7b", "--fused-attention"],
]


@pytest.mark.parametrize("argv", BAD_ARGV,
                         ids=[f"bad{i}" for i in range(len(BAD_ARGV))])
def test_unsupported_compositions_error_loudly(argv):
    with pytest.raises(SystemExit) as e:
        main(argv + ["--n-samples", "2", "--steps", "1"])
    assert e.value.code == 2  # argparse p.error


# -- fused-kernel x engine composition matrix -------------------------------------

# (engine name, chunk_steps, device_rules, sharded, data_ring)
ENGINES = [
    ("vmapped", 1, False, False, False),
    ("sharded", 1, False, True, False),
    ("chunked", 8, False, False, False),
    ("ring", 8, False, False, True),
    ("device-rules", 8, True, False, False),
]
FUSED_SETS = [
    {"fused_rmsnorm": True},
    {"fused_attention": True},
    {"fused_rmsnorm": True, "fused_attention": True},
]


@pytest.fixture(scope="module")
def cfgs():
    return ladder(6)


@pytest.fixture(scope="module")
def unfused_ref(cfgs):
    return run_batch_cell(cfgs)


def _engine_cell(cfgs, engine, fused):
    name, chunk, device, sharded, ring = engine
    mesh = None
    if sharded:
        if jax.device_count() < 2:
            pytest.skip("sharded cell needs a multi-device mesh")
        from repro.distributed.sharding import population_mesh
        mesh = population_mesh()
    return run_batch_cell(cfgs, chunk=chunk, device=device, mesh=mesh,
                          ring=ring, **fused)


@pytest.mark.parametrize("fused", FUSED_SETS,
                         ids=["rmsnorm", "attention", "both"])
@pytest.mark.parametrize("engine", ENGINES, ids=[e[0] for e in ENGINES])
def test_fused_flags_compose_with_every_engine(cfgs, unfused_ref, engine,
                                               fused):
    """Each fused flag (and their union) rides every population engine: the
    static ModelConfig fields key the compile caches so fused and reference
    programs never mix, the rung rule makes the SAME cuts, and scores stay
    within kernel tolerance of the unfused reference (the flash forward
    reassociates softmax reductions — looser than the 1e-6 engine bound)."""
    if not HEAVY and engine[0] not in ("vmapped", "sharded"):
        pytest.skip("heavier engine cells run under REPRO_TP_SMOKE=1")
    got = _engine_cell(cfgs, engine, fused)
    assert got["n_truncated"] == unfused_ref["n_truncated"]
    assert got["n_reclaimed"] == unfused_ref["n_reclaimed"]
    np.testing.assert_allclose(got["scores"], unfused_ref["scores"],
                               rtol=1e-4, atol=5e-4)


@eight_devices
def test_fused_flags_compose_with_model_parallel(cfgs, unfused_ref):
    """The full stack: fused rmsnorm + flash attention inside a width-2
    tensor-parallel shard_map — the Pallas kernels run on width-local shards
    (heads/W, ff/W) and the psum seams still restore the reference math."""
    from repro.distributed.sharding import population_mesh

    got = run_batch_cell(cfgs, mesh=population_mesh(width=2),
                         fused_rmsnorm=True, fused_attention=True)
    assert got["n_truncated"] == unfused_ref["n_truncated"]
    np.testing.assert_allclose(got["scores"], unfused_ref["scores"],
                               rtol=1e-4, atol=5e-4)


# -- CLI smoke: width-2 twin vs width-1 -------------------------------------------

def _cli(argv, capsys):
    assert main(argv) == 0
    return json.loads(capsys.readouterr().out)


@eight_devices
def test_cli_model_parallel_matches_width1_twin(capsys):
    """The CI smoke: the same ASHA search at --model-parallel 2 and width 1
    must land on the same best config with a best score within 1e-6, emit
    model-axis collective telemetry (> 0 at width 2, == 0 at width 1), and
    tag the engine string."""
    heavy = HEAVY
    base = ["--proposer", "asha", "--n-samples", "8" if heavy else "6",
            "--vectorize", "8", "--shard-population", "--inflight-stop",
            "--steps", "4" if heavy else "2", "--batch", "2", "--seq", "16"]
    w1 = _cli(base, capsys)
    w2 = _cli(base + ["--model-parallel", "2"], capsys)
    assert w1["engine"] == "sharded"
    assert w2["engine"] == "sharded+tp2"
    assert w1["model_axis_collectives"] == 0
    assert w2["model_axis_collectives"] > 0
    assert w2["model_parallel"] == 2
    assert w2["best_config"] == w1["best_config"]
    assert abs(w2["best_score"] - w1["best_score"]) <= 1e-6
    # the rung-segment telemetry must cover the whole flight
    assert w2["per_rung_step_time_s"]
    assert sum(seg[1] for seg in w2["per_rung_step_time_s"]) \
        == w2["trained_steps"]


# -- crash-resume from a width-2 snapshot -----------------------------------------

@eight_devices
def test_width2_flight_death_restores_from_width2_snapshots(tmp_path, capsys):
    """A supervised width-2 streaming flight dies mid-run (injected raise)
    and restarts: its lanes restore from snapshots harvested off the
    width-sharded state (the snapshot op gathers each lane to host layout,
    the restore splice re-partitions it onto the new flight's rows), and
    every score matches the uninterrupted width-2 run."""
    base = ["--proposer", "random", "--vectorize", "4", "--lane-refill",
            "--shard-population", "--model-parallel", "2",
            "--n-samples", "6", "--steps", "6", "--batch", "2",
            "--seq", "16", "--snapshot-every", "1"]
    try:
        ok = _cli(base + ["--db", str(tmp_path / "a.sqlite")], capsys)
        # the first cohort retires (and snapshots) at step 6; the raise lands
        # inside the refilled second cohort, so live lanes have snapshots
        crashed = _cli(base + ["--db", str(tmp_path / "b.sqlite"),
                               "--fault-spec", "raise@step=10,times=1"],
                       capsys)
    finally:
        faultinject.disarm()
    assert ok["engine"] == "sharded+tp2+refill"
    assert crashed["flight_deaths"] == 1
    assert crashed["flight_restarts"] == 1
    assert crashed["resumed_lanes"] >= 1
    assert max(crashed["resumed_from_steps"]) > 0, \
        "restored lanes restarted from step 0 instead of their snapshots"
    assert abs(crashed["best_score"] - ok["best_score"]) <= 1e-6
    assert crashed["best_config"] == ok["best_config"]
