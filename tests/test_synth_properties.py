"""Property tests for the synthetic-data generator (hypothesis-driven).

The fused scan engines rest on one contract: batch synthesis is a *pure
function* of ``(stream, step)`` whose host (NumPy) and device (jax.numpy)
executions are bit-identical.  These tests state that contract as properties
over randomized draws instead of the handful of pinned coordinates
``test_chunked.py`` checks:

* host/device bit-identity of ``synth_batch`` / ``synth_population_batch``
  at arbitrary streams (negative sentinels and 64-bit ids included);
* stream & step injectivity — distinct coordinates give distinct batches, so
  trials never silently share data and sentinels never collide with real
  streams;
* step-shift invariance — a lane's batch at cursor ``c`` is the same however
  the engine arrives there (per-step loop, fused chunk, population slab),
  which is exactly why chunked and per-step flights are bit-equal.

Skips cleanly where hypothesis is not installed (it is not baked into the
repro container; CI lanes that have it run the full property sweep).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.data.pipeline import (  # noqa: E402
    SyntheticLM,
    split_stream,
    split_streams,
    synth_batch,
    synth_population_batch,
)

SPEC = SyntheticLM(vocab_size=251, seq_len=8, global_batch=2, seed=3)

# streams cover negative sentinels, small trial ids, and >32-bit ids (the
# u64 wrap split_stream promises to keep far from real streams)
streams_st = st.integers(min_value=-(2 ** 33), max_value=2 ** 33)
steps_st = st.integers(min_value=0, max_value=1_000_000)


def _assert_batches_equal(host, dev):
    for key in host:
        np.testing.assert_array_equal(host[key], np.asarray(dev[key]))
        assert host[key].dtype == np.asarray(dev[key]).dtype


@settings(max_examples=25, deadline=None)
@given(stream=streams_st, step=steps_st)
def test_synth_batch_host_device_bit_identity(stream, step):
    host = synth_batch(SPEC, stream, step, xp=np)
    dev = synth_batch(SPEC, stream, jnp.asarray(step, jnp.int32), xp=jnp)
    _assert_batches_equal(host, dev)


@settings(max_examples=15, deadline=None)
@given(
    streams=st.lists(streams_st, min_size=1, max_size=4),
    steps=st.data(),
)
def test_synth_population_batch_lane_decomposition(streams, steps):
    """The population slab is exactly its lanes' independent batches — on
    host and device, at per-lane cursors."""
    per_lane = [steps.draw(steps_st) for _ in streams]
    lo, hi = split_streams(streams)
    host = synth_population_batch(
        SPEC, lo, hi, np.asarray(per_lane, np.int64), xp=np)
    dev = synth_population_batch(
        SPEC, jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(per_lane, jnp.int32), xp=jnp)
    _assert_batches_equal(host, dev)
    for i, (sid, cursor) in enumerate(zip(streams, per_lane)):
        lane = synth_batch(SPEC, sid, cursor, xp=np)
        for key in host:
            np.testing.assert_array_equal(host[key][i], lane[key])


@settings(max_examples=25, deadline=None)
@given(a=st.tuples(streams_st, steps_st), b=st.tuples(streams_st, steps_st))
def test_synth_coordinates_injective(a, b):
    """Distinct (stream, step) coordinates give distinct token batches: no
    silent data sharing between trials, steps, or sentinel padding lanes."""
    if a == b:
        ta = synth_batch(SPEC, a[0], a[1], xp=np)["tokens"]
        tb = synth_batch(SPEC, b[0], b[1], xp=np)["tokens"]
        np.testing.assert_array_equal(ta, tb)
    else:
        ta = synth_batch(SPEC, a[0], a[1], xp=np)["tokens"]
        tb = synth_batch(SPEC, b[0], b[1], xp=np)["tokens"]
        assert not np.array_equal(ta, tb)


@settings(max_examples=25, deadline=None)
@given(lane=st.integers(min_value=0, max_value=63), real=streams_st)
def test_sentinel_streams_never_collide_with_real(lane, real):
    """Idle/padding lanes draw from ``-(lane+1)``: the u64 wrap parks them at
    the top of the id space, disjoint from any non-negative trial stream."""
    lo, hi = split_stream(-(lane + 1))
    assert hi == 0xFFFFFFFF
    if real >= 0:
        assert (lo, hi) != split_stream(real)


@settings(max_examples=15, deadline=None)
@given(stream=streams_st, step=steps_st, shift=st.integers(0, 4096))
def test_step_shift_invariance(stream, step, shift):
    """The batch at cursor ``step + shift`` does not depend on how the engine
    got there: directly, or as an offset draw (steps0 + t inside a chunk) —
    the generator is stateless in its step coordinate."""
    direct = synth_batch(SPEC, stream, step + shift, xp=np)
    offset = synth_batch(
        SPEC, stream,
        jnp.asarray(step, jnp.int32) + jnp.asarray(shift, jnp.int32), xp=jnp)
    _assert_batches_equal(direct, offset)
