"""The trip-count-aware HLO analyzer must agree with hand-computed FLOPs on
real compiled programs (scan multiplication is the whole point)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyse_hlo
from repro.launch.hlo_stats import parse_collectives, shape_bytes


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    t = analyse_hlo(_compiled_text(lambda a, b: a @ b, a, b))
    want = 2 * 64 * 128 * 32
    assert abs(t.flops - want) / want < 0.01, (t.flops, want)


def test_scan_multiplies_flops_by_trip_count():
    a = jnp.zeros((32, 32), jnp.float32)

    def once(x):
        return x @ x

    def scanned(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    t1 = analyse_hlo(_compiled_text(once, a))
    t10 = analyse_hlo(_compiled_text(scanned, a))
    assert t1.flops > 0
    ratio = t10.flops / t1.flops
    assert 9.0 <= ratio <= 11.0, f"scan x10 should cost ~10x, got {ratio}"


def test_nested_scan_multiplies():
    a = jnp.zeros((16, 16), jnp.float32)

    def nested(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    def once(x):
        return x @ x

    t1 = analyse_hlo(_compiled_text(once, a))
    t12 = analyse_hlo(_compiled_text(nested, a))
    ratio = t12.flops / t1.flops
    assert 11.0 <= ratio <= 13.5, f"3x4 nested scans should cost ~12x, got {ratio}"


def test_bytes_track_memory_traffic():
    a = jnp.zeros((1024, 1024), jnp.float32)  # 4 MB
    t = analyse_hlo(_compiled_text(lambda x: x + 1.0, a))
    # read 4MB + write 4MB, modest overhead allowed
    assert 6e6 < t.bytes < 3e7, t.bytes


def test_kernel_scope_attribution():
    a = jnp.zeros((256, 256), jnp.float32)

    def f(x):
        with jax.named_scope("kernel_flash_attn"):
            y = x @ x
        return y + 1.0

    t = analyse_hlo(_compiled_text(f, a))
    want = 2 * 256**3
    assert abs(t.kernel_flops - want) / want < 0.05, (t.kernel_flops, want)
    assert t.kernel_bytes < t.bytes


def test_shape_bytes_parsing():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[4,4] junk f32[2]") == 40
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("f32[2,2]", f32_as_bf16=True) == 8


def test_model_axis_collective_count_gate():
    """Static gate on the lowered population step: a width-1 mesh must lower
    with ZERO all-reduces (lanes never communicate — width is the only source
    of collectives), and a width-2 mesh must carry at least one psum per
    sharded module per layer in the forward pass alone (starcoder2 smoke:
    2 layers x (attention g-seam + MLP g-seam) = 4), the f-seam backward
    psums and the grad-norm reduction on top of that."""
    if jax.device_count() < 8:
        pytest.skip("needs the 8-virtual-device CPU mesh")
    from repro.configs import get_smoke_config
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.data.pipeline import SyntheticLM
    from repro.distributed.sharding import population_mesh
    from repro.train.population import count_model_axis_collectives

    cfg = get_smoke_config("starcoder2-3b")
    tc = TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"), seed=0)
    data = SyntheticLM(cfg.vocab_size, 16, 2)
    c1 = count_model_axis_collectives(tc, 8, population_mesh(), data)
    c2 = count_model_axis_collectives(tc, 8, population_mesh(width=2), data)
    c4 = count_model_axis_collectives(tc, 8, population_mesh(width=4), data)
    assert c1 == 0, f"width-1 step lowered with {c1} all-reduces"
    assert c2 >= 4, f"width-2 step lowered only {c2} model-axis all-reduces"
    # at width 4 the 2 kv heads stop dividing: attention drops out of the
    # rules and only the MLP seams (+ gnorm) remain — strictly fewer psums
    assert 0 < c4 < c2, (c4, c2)


def test_collective_parser_on_synthetic_hlo():
    hlo = """
HloModule test

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(f32[16,16]{1,0} %p), replica_groups=[4,8]<=[32], to_apply=%add
  ROOT %cp = f32[16,16]{1,0} collective-permute(f32[16,16]{1,0} %ar), source_target_pairs={{0,1}}
}
"""
    st = parse_collectives(hlo, default_group=8)
    assert st.per_op["all-reduce"]["count"] == 1
    assert st.per_op["collective-permute"]["count"] == 1
    # all-reduce over groups of 8: wire = 2*(7/8)*1024 bytes
    assert abs(st.per_op["all-reduce"]["wire_bytes"] - 2 * (7 / 8) * 1024) < 1
    assert st.per_op["collective-permute"]["wire_bytes"] == 1024
