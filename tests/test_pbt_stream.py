"""Streaming PBT on the lane-refill engine + the unified lane-lifecycle ops.

Covers the lifecycle op layer (donor clone and single-lane splice, vmapped
and sharded), the streaming PBT proposer (sliding-window exploit/explore,
donor pinning, lifecycle passthrough through the Experiment), equivalence of
the streaming engine against the generation-barriered serial PBT driver under
shared RNG, and the PR's satellite regressions: the classic-PBT replay
double-issue fix and the loud lane-refill/shared-stream construction error.

conftest.py forces an 8-virtual-device CPU mesh; tests that need real
sharding skip on a single-device backend.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.core.experiment import Experiment
from repro.core.proposer import make_proposer
from repro.core.proposer.pbt import PBTLifecycle, PBTProposer
from repro.core.search_space import SearchSpace
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import population_mesh
from repro.launch.hpo import PopulationTrial, run_pbt_serial
from repro.optim.hparams import hparams_from_dict, stack_hparams
from repro.train import population as pop
from repro.train.train_step import init_train_state

SEQ, BATCH = 16, 2
ARCH = "starcoder2-3b"

SPACE_JSON = [
    {"name": "learning_rate", "type": "float", "range": [1e-4, 3e-3], "scale": "log"},
    {"name": "weight_decay", "type": "float", "range": [0.0, 0.2]},
]

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a multi-device (virtual CPU) mesh"
)


@pytest.fixture(scope="module")
def tc():
    cfg = get_smoke_config(ARCH)
    return TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                       total_steps=4)


def _trained_pstate(tc, k, steps=2):
    """K distinct lanes, stepped a couple of times so lanes differ."""
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(0), i)
                      for i in range(k)])
    pstate = pop.init_population_state_from_keys(keys, tc)
    step = pop.make_population_train_step(tc, per_trial_batch=False)
    data = SyntheticLM(tc.model.vocab_size, SEQ, BATCH, seed=0)
    hp = stack_hparams([
        hparams_from_dict({"learning_rate": 1e-3 * (i + 1), "total_steps": 8}, tc)
        for i in range(k)
    ])
    for s in range(steps):
        pstate, _ = step(pstate, data.make_batch(s), hp)
    return pstate, keys


# -- the lifecycle device ops -----------------------------------------------------

def test_lane_clone_copies_donor_bit_exact_and_leaves_others(tc):
    pstate, _ = _trained_pstate(tc, 4)
    ref = jax.tree.map(np.asarray, pstate)
    clone = pop.make_lane_clone(tc)
    mask = jnp.array([False, False, True, False])
    donor_idx = jnp.asarray([0, 1, 0, 3], jnp.int32)  # lane 2 <- donor 0
    out = clone(pstate, mask, donor_idx)
    for got, want in zip(jax.tree.leaves(out["inner"]),
                         jax.tree.leaves(ref["inner"])):
        got = np.asarray(got)
        # cloned lane: bit-identical to the donor (params AND opt state)
        np.testing.assert_array_equal(got[2], want[0])
        # every other lane untouched, bit for bit
        for lane in (0, 1, 3):
            np.testing.assert_array_equal(got[lane], want[lane])
    np.testing.assert_array_equal(
        np.asarray(out["last_loss"])[2], ref["last_loss"][0])
    assert not bool(np.asarray(out["diverged"])[2])


def test_lane_splice_updates_one_lane_only(tc):
    pstate, _ = _trained_pstate(tc, 4)
    ref = jax.tree.map(np.asarray, pstate)
    key = jax.random.PRNGKey(42)
    fresh = jax.tree.map(np.asarray, init_train_state(key, tc))
    splice = pop.get_compiled_lane_op(tc, 4, "splice")
    out = splice(pstate, jnp.asarray(1, jnp.int32), key)
    for got, want, f in zip(jax.tree.leaves(out["inner"]),
                            jax.tree.leaves(ref["inner"]),
                            jax.tree.leaves(fresh)):
        got = np.asarray(got)
        # the spliced lane is exactly one fresh init_train_state(key)
        np.testing.assert_array_equal(got[1], f)
        # all other lanes bit-identical — the single-lane contract
        for lane in (0, 2, 3):
            np.testing.assert_array_equal(got[lane], want[lane])
    assert np.isinf(np.asarray(out["last_loss"])[1])
    assert not bool(np.asarray(out["diverged"])[1])


@multi_device
def test_sharded_clone_across_mesh_boundaries(tc):
    """Donor and target lanes on different devices: the shard_map twin's
    all_gather must produce the same result as the vmapped op."""
    n = jax.device_count()
    k = max(n, 4)
    mesh = population_mesh()
    pstate, _ = _trained_pstate(tc, k)
    ref = jax.tree.map(np.asarray, pstate)
    mask = np.zeros(k, bool)
    donor_idx = np.arange(k)
    mask[k - 1] = True          # last lane (last device) ...
    donor_idx[k - 1] = 0        # ... clones lane 0 (first device)
    vmapped = pop.make_lane_clone(tc)(
        pstate, jnp.asarray(mask), jnp.asarray(donor_idx, jnp.int32))
    # re-derive the same (deterministic) trained state, placed on the mesh
    pstate2, _ = _trained_pstate(tc, k)
    pstate2 = pop.shard_population_state(pstate2, mesh)
    sharded = pop.get_compiled_lane_op(tc, k, "clone", mesh=mesh)(
        pstate2, jnp.asarray(mask), jnp.asarray(donor_idx, jnp.int32))
    for got, want in zip(jax.tree.leaves(sharded["inner"]),
                         jax.tree.leaves(vmapped["inner"])):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(jax.tree.leaves(sharded["inner"]),
                         jax.tree.leaves(ref["inner"])):
        np.testing.assert_array_equal(np.asarray(got)[k - 1], want[0])


@multi_device
def test_sharded_splice_matches_vmapped(tc):
    n = jax.device_count()
    k = max(n, 4)
    mesh = population_mesh()
    key = jax.random.PRNGKey(11)
    lane = k // 2  # an interior device's lane
    pstate, _ = _trained_pstate(tc, k)
    vmapped = pop.make_lane_splice(tc)(pstate, jnp.asarray(lane, jnp.int32), key)
    pstate2, _ = _trained_pstate(tc, k)
    pstate2 = pop.shard_population_state(pstate2, mesh)
    sharded = pop.get_compiled_lane_op(tc, k, "splice", mesh=mesh)(
        pstate2, jnp.asarray(lane, jnp.int32), key)
    for got, want in zip(jax.tree.leaves(sharded["inner"]),
                         jax.tree.leaves(vmapped["inner"])):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- the decision rule ------------------------------------------------------------

def _space():
    return SearchSpace.from_json(SPACE_JSON)


def test_lifecycle_window_quantile_rule():
    rng = np.random.default_rng(3)
    lc = PBTLifecycle(_space(), perturb=1.2, quantile=0.25, window=4, rng=rng)
    lc.member_cfgs = {m: {"learning_rate": 1e-3, "weight_decay": 0.1}
                      for m in range(4)}
    for m, score in enumerate([-1.0, -2.0, -3.0, -4.0]):
        lc.note_result(m, score)
    # best member keeps
    kind, donor, _ = lc.decide(0, lc.member_cfgs[0])
    assert kind == "keep" and donor is None
    # worst member clones the best, with perturbed hparams
    kind, donor, cfg = lc.decide(3, lc.member_cfgs[3])
    assert kind == "clone" and donor == 0
    assert cfg["learning_rate"] != lc.member_cfgs[0]["learning_rate"]
    # pin engages only once the proposer registers the clone job
    assert not lc.pinned(0)
    clone_cfg = dict(cfg, pbt_member=3, pbt_round=1, pbt_lifecycle="clone",
                     pbt_donor=0)
    lc.pin(clone_cfg)
    assert lc.pinned(0)
    assert lc.lease_blocked({"pbt_lifecycle": "keep", "pbt_member": 0})
    assert not lc.lease_blocked({"pbt_lifecycle": "keep", "pbt_member": 1})
    assert not lc.lease_blocked({"pbt_lifecycle": "clone", "pbt_member": 3})
    lc.clone_done(clone_cfg)
    assert not lc.pinned(0)
    lc.clone_done(clone_cfg)  # release is idempotent across retries
    assert not lc.pinned(0)


def test_lifecycle_diverged_member_never_donates():
    lc = PBTLifecycle(_space(), quantile=0.5, window=4,
                      rng=np.random.default_rng(0))
    lc.member_cfgs = {m: {"learning_rate": 1e-3, "weight_decay": 0.1}
                      for m in range(2)}
    lc.note_result(0, -1e9)  # diverged sentinel
    lc.note_result(1, -1e9)
    kind, donor, _ = lc.decide(1, lc.member_cfgs[1])
    assert kind == "keep" and donor is None  # nothing finite to clone


# -- streaming engine vs the generation-barriered serial driver -------------------

def _make_proposer(seed=7, k=4, rounds=3, **kw):
    return make_proposer("pbt", _space(), maximize=True, seed=seed,
                         population=k, n_generations=rounds, streaming=True,
                         quantile=0.25, **kw)


def _stream_scores(trial, k, rounds, seed=7, resource="vectorized"):
    exp = Experiment({
        "proposer": "pbt", "parameter_config": SPACE_JSON,
        "n_samples": k * rounds, "n_parallel": k, "target": "max",
        "seed": seed, "population": k, "n_generations": rounds,
        "streaming": True, "quantile": 0.25,
        "resource": resource, "lane_refill": True}, trial)
    got = {}
    exp.add_result_callback(lambda job: got.__setitem__(
        (job.config.get("pbt_member"), job.config.get("pbt_round")),
        job.result.score if job.result else None))
    exp.run()
    return exp, got


def test_streaming_pbt_matches_serial_generation_pbt():
    """The headline contract: PBT on the streaming lane engine reproduces the
    generation-barriered serial driver's scores for every (member, round)
    under shared RNG — with clones as device ops and ZERO weight checkpoints
    crossing the host boundary."""
    k, rounds, steps = 4, 3, 3
    serial_trial = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ,
                                   seed=0, per_trial_init=True)
    serial = run_pbt_serial(serial_trial, _make_proposer())
    assert serial_trial.n_host_ckpt_roundtrips > 0  # the baseline pays them

    trial = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ, seed=0,
                            population=k, per_trial_init=True)
    exp, got = _stream_scores(trial, k, rounds)
    assert set(got) == set(serial)
    np.testing.assert_allclose(
        [got[key] for key in sorted(serial)],
        [serial[key] for key in sorted(serial)], rtol=1e-5, atol=1e-6)
    # lifecycle passthrough wired the hook without explicit plumbing
    assert trial.lifecycle is exp.proposer.lifecycle_hook()
    assert trial.n_clones >= 1, "at least one exploit per run at quantile 0.25"
    assert trial.n_host_ckpt_roundtrips == 0, \
        "streaming PBT must never round-trip weights through the host"
    assert trial.n_lineage_resets == 0
    assert exp.rm.n_streamed == k * rounds
    assert all(j.done for j in exp.job_log)


@multi_device
def test_streaming_pbt_sharded_matches_vmapped():
    k, rounds, steps = jax.device_count(), 2, 2
    t1 = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ, seed=0,
                         population=k, per_trial_init=True)
    _, vmapped = _stream_scores(t1, k, rounds, resource="vectorized")
    t2 = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ, seed=0,
                         population=k, per_trial_init=True)
    _, sharded = _stream_scores(t2, k, rounds, resource="sharded")
    assert set(vmapped) == set(sharded)
    np.testing.assert_allclose(
        [sharded[key] for key in sorted(vmapped)],
        [vmapped[key] for key in sorted(vmapped)], rtol=1e-5, atol=1e-6)
    assert t2.n_host_ckpt_roundtrips == 0


def test_serial_driver_clones_read_generation_boundary_checkpoints():
    """Regression: with population 8 at seed 3 (6 steps/round), members 2 and
    6 clone donors 0 and 1 — donors with a LOWER member index, whose serial
    rounds run earlier in the generation loop.  The serial driver must
    restore the donor's generation-boundary snapshot (classic PBT barrier
    semantics, what the streaming engine's donor pin enforces), not the
    checkpoint the donor already advanced this generation — that bug showed
    up as a ~1e-3 score gap against the (correct) streaming engine."""
    k, rounds, steps = 8, 2, 6
    serial_trial = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ,
                                   seed=0, per_trial_init=True)
    prop = _make_proposer(seed=3, k=k, rounds=rounds)
    serial = run_pbt_serial(serial_trial, prop)
    clones = [(c["config"]["pbt_member"], c["config"]["pbt_donor"])
              for c in prop.history
              if c["config"].get("pbt_lifecycle") == "clone"]
    assert any(d < m for m, d in clones), \
        "workload must include a lower-index donor to exercise the snapshot"
    trial = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ, seed=0,
                            population=k, per_trial_init=True)
    _, got = _stream_scores(trial, k, rounds, seed=3)
    np.testing.assert_allclose(
        [got[key] for key in sorted(serial)],
        [serial[key] for key in sorted(serial)], rtol=1e-6, atol=1e-7)


def test_feed_with_all_rounds_queued_respects_round_order():
    """Regression: a raw feed (no Algorithm 1, no donor pins) can hold every
    round of every member up front.  The engine must still run each member's
    rounds in order and execute clones before same-round keeps re-activate
    their donors — without the guards, a member's round 2 could jump its own
    round 1 and a clone could copy post-round donor weights."""
    k, rounds, steps = 8, 2, 4
    from repro.core.resource.vectorized import QueueFeedScheduler

    serial_trial = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ,
                                   seed=0, per_trial_init=True)
    prop = _make_proposer(seed=3, k=k, rounds=rounds)
    serial = run_pbt_serial(serial_trial, prop)
    ordered = [c["config"] for c in prop.history]

    prop2 = _make_proposer(seed=3, k=k, rounds=rounds)
    trial = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ, seed=0,
                            population=k, per_trial_init=True,
                            refill_idle_grace_s=0.0,
                            lifecycle=prop2.lifecycle_hook())
    feed = QueueFeedScheduler(ordered)
    trial.run_population([], scheduler=feed)
    assert len(feed.scores) == len(ordered), "every queued round must complete"
    assert trial.n_lineage_resets == 0
    np.testing.assert_allclose(
        [feed.scores[i] for i in range(len(ordered))],
        [serial[(c["pbt_member"], c["pbt_round"])] for c in ordered],
        rtol=1e-6, atol=1e-7)


def test_pbt_streaming_cli_smoke():
    """The CI smoke entry (`REPRO_PBT_STREAM_SMOKE=1`) runs the heavier CLI
    variant; locally we keep a lighter always-on one."""
    from repro.launch.hpo import main

    heavy = os.environ.get("REPRO_PBT_STREAM_SMOKE") == "1"
    argv = ["--proposer", "pbt", "--vectorize", "4", "--pbt-streaming",
            "--n-samples", "8" if heavy else "4",
            "--steps", "2", "--batch", "2", "--seq", "16"]
    if heavy:
        argv.append("--pbt-async")
    assert main(argv) == 0


# -- satellite regressions --------------------------------------------------------

def test_lane_refill_with_shared_stream_target_fails_at_construction():
    trial = PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                            population=2, per_trial_streams=False)
    with pytest.raises(ValueError, match="per-trial data streams"):
        Experiment({
            "proposer": "random", "parameter_config": SPACE_JSON,
            "n_samples": 2, "n_parallel": 2, "target": "max",
            "resource": "vectorized", "lane_refill": True}, trial)


def _row(cfg, status="finished", score=0.0, job_id=0):
    row = {"config": dict(cfg), "status": status, "job_id": job_id}
    if status == "finished":
        row["score"] = score
    return row


def test_classic_pbt_replay_advances_generations_incrementally():
    """Replay of rows spanning two finished generations must land each row in
    its own generation (firing _exploit_explore between them), matching the
    live path's RNG consumption — the old replay dropped every row after the
    first generation and then re-issued it."""
    def _next(prop):
        for _ in range(3):  # a None is the generation barrier: retry
            c = prop.get_param()
            if c is not None:
                return c
        raise AssertionError("proposer stuck at the barrier")

    space = _space()
    live = PBTProposer(space, population=2, n_generations=3, seed=5)
    rows = []
    jid = 0
    for gen in range(2):
        cfgs = [_next(live) for _ in range(2)]
        for m, cfg in enumerate(cfgs):
            score = -1.0 * (gen + 1) * (m + 1)
            rows.append(_row(cfg, score=score, job_id=jid))
            jid += 1

            class _J:
                config = cfg

            live.update(score, _J)
    # force the live proposer through its (lazy) second barrier
    live_next = _next(live)
    assert live.gen == 2 and live_next["pbt_gen"] == 2

    resumed = PBTProposer(space, population=2, n_generations=3, seed=5)
    resumed.replay(rows)
    assert resumed.gen == live.gen, "replay must advance through BOTH generations"
    assert resumed.members == live.members, \
        "same RNG consumption => identical post-replay member configs"
    # the next proposal continues generation 2 — not a re-issue of gen 0
    nxt = resumed.get_param()
    assert nxt["pbt_gen"] == 2 and nxt["pbt_member"] == live_next["pbt_member"]
    assert {k: v for k, v in nxt.items()} == {k: v for k, v in live_next.items()}


def test_classic_pbt_replay_marks_running_members_issued():
    """A member whose job was mid-flight at the crash is re-queued by the
    Experiment; replay must mark it issued so _propose cannot double-issue
    the same (member, generation)."""
    space = _space()
    prop = PBTProposer(space, population=2, n_generations=2, seed=5)
    cfg0 = prop.get_param()
    rows = [_row(cfg0, status="running", job_id=0)]
    resumed = PBTProposer(space, population=2, n_generations=2, seed=5)
    resumed.replay(rows)
    assert cfg0["pbt_member"] in resumed.gen_issued
    nxt = resumed.get_param()
    assert nxt is not None and nxt["pbt_member"] != cfg0["pbt_member"], \
        "the running member must not be issued twice"


def test_streaming_pbt_replay_restores_rounds_and_outstanding():
    space = _space()
    live = _make_proposer(seed=9, k=2, rounds=3)
    c00, c10 = live.get_param(), live.get_param()
    rows = [_row(c00, score=-1.0, job_id=0), _row(c10, score=-2.0, job_id=1)]

    for cfg, sc in ((c00, -1.0), (c10, -2.0)):
        class _J:
            config = cfg

        live.update(sc, _J)
    c01 = live.get_param()
    rows.append(_row(c01, status="running", job_id=2))

    resumed = _make_proposer(seed=9, k=2, rounds=3)
    resumed.replay(rows)
    assert resumed.member_round == [1, 1]
    assert resumed.member_outstanding[c01["pbt_member"]]
    assert not resumed.finished()
    # the outstanding member is skipped; the other proposes its round 1
    nxt = resumed.get_param()
    assert nxt["pbt_member"] != c01["pbt_member"] and nxt["pbt_round"] == 1
