"""Cross-engine differential matrix.

One seeded ladder workload (``harness.ladder``) through every engine cell —
{vmapped, sharded} x {per-step, chunked} x {host-rule, device-rule} — in both
the batch (cohort rule) and streaming (staggered rule) protocols, plus the
serial reference.  The equivalence promises, asserted pairwise:

* within the vmapped family of one protocol: **bit-equal** scores, effective
  budgets and rule decisions across chunk sizes and host/device rules;
* sharded vs vmapped: scores within 1e-6 max abs diff, same rule decisions;
* population vs the serial driver (at the host-rule effective budgets):
  rtol 1e-5.

Each cell runs once per module (lazy, cached in a module fixture); the tests
just compare.  The in-scan rule updates are additionally unit-checked against
their host twins on randomized inputs, independent of any driver.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from harness import ladder, run_batch_cell, run_serial_reference, \
    run_streaming_cell, rung_hook
from repro.distributed.sharding import population_mesh

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a multi-device (virtual CPU) mesh"
)

# (cell name, chunk_steps, device_rules, sharded, data_ring)
CELLS = [
    ("vmapped-perstep-host", 1, False, False, False),
    ("vmapped-perstep-device", 1, True, False, False),
    ("vmapped-chunked-host", 8, False, False, False),
    ("vmapped-chunked-device", 8, True, False, False),
    ("vmapped-chunked-ring", 8, False, False, True),
    ("sharded-perstep-host", 1, False, True, False),
    ("sharded-perstep-device", 1, True, True, False),
    ("sharded-chunked-host", 8, False, True, False),
    ("sharded-chunked-device", 8, True, True, False),
    ("sharded-chunked-ring", 8, False, True, True),
]
REFERENCE = "vmapped-perstep-host"
VMAPPED = [c[0] for c in CELLS if not c[3] and c[0] != REFERENCE]
SHARDED = [c[0] for c in CELLS if c[3]]


@pytest.fixture(scope="module")
def cfgs():
    return ladder(6)


@pytest.fixture(scope="module")
def cells(cfgs):
    """Every matrix cell, computed once: ``cells[protocol][name]``."""
    mesh = population_mesh() if jax.device_count() > 1 else None
    out = {"batch": {}, "streaming": {}}
    for name, chunk, device, sharded, ring in CELLS:
        if sharded and mesh is None:
            continue
        m = mesh if sharded else None
        out["batch"][name] = run_batch_cell(
            cfgs, chunk=chunk, device=device, mesh=m, ring=ring)
        out["streaming"][name] = run_streaming_cell(
            cfgs, chunk=chunk, device=device, mesh=m, ring=ring)
    return out


def _cell(cells, protocol, name):
    if name not in cells[protocol]:
        pytest.skip("needs a multi-device (virtual CPU) mesh")
    return cells[protocol][name]


# -- vmapped family: bit-equality ------------------------------------------------


@pytest.mark.parametrize("name", VMAPPED)
@pytest.mark.parametrize("protocol", ["batch", "streaming"])
def test_vmapped_cells_bit_equal(cells, protocol, name):
    """Chunking and device rules are pure engine choices: same scores to the
    bit, same truncation/reclaim decisions, same effective budgets."""
    ref = cells[protocol][REFERENCE]
    got = cells[protocol][name]
    assert got["scores"] == ref["scores"]
    assert got["n_truncated"] == ref["n_truncated"]
    assert got["n_reclaimed"] == ref["n_reclaimed"]
    if protocol == "streaming":
        assert got["steps"] == ref["steps"]
        assert got["diverged"] == ref["diverged"]


# -- sharded family: 1e-6 scores, identical decisions ----------------------------


@multi_device
@pytest.mark.parametrize("name", SHARDED)
@pytest.mark.parametrize("protocol", ["batch", "streaming"])
def test_sharded_cells_match_vmapped(cells, protocol, name):
    ref = cells[protocol][REFERENCE]
    got = _cell(cells, protocol, name)
    np.testing.assert_allclose(got["scores"], ref["scores"],
                               rtol=0, atol=1e-6)
    assert got["n_truncated"] == ref["n_truncated"]
    assert got["n_reclaimed"] == ref["n_reclaimed"]
    if protocol == "streaming":
        assert got["steps"] == ref["steps"]


# -- tensor-parallel width family: width is layout, never math -------------------

# (cell name, chunk_steps, device_rules, width): per-step, fused-scan and
# in-scan-rule twins at width 2, plus width 4 (where 2 kv heads stop dividing
# so attention stays replicated and only the ff/inner dims shard)
TP_CELLS = [
    ("tp2-perstep-host", 1, False, 2),
    ("tp2-chunked-host", 8, False, 2),
    ("tp2-chunked-device", 8, True, 2),
    ("tp4-perstep-host", 1, False, 4),
]


@pytest.fixture(scope="module")
def tp_cells(cfgs):
    """Width-2/4 cells of the same ladder: the population axis folds into a
    two-level (pop, model) mesh and every lane's heads/ff/inner dims split
    over its W-device row."""
    if jax.device_count() < 8:
        pytest.skip("needs the 8-virtual-device CPU mesh")
    out = {"batch": {}, "streaming": {}}
    for name, chunk, device, width in TP_CELLS:
        mesh = population_mesh(width=width)
        out["batch"][name] = run_batch_cell(
            cfgs, chunk=chunk, device=device, mesh=mesh)
        out["streaming"][name] = run_streaming_cell(
            cfgs, chunk=chunk, device=device, mesh=mesh)
    return out


@multi_device
@pytest.mark.parametrize("name", [c[0] for c in TP_CELLS])
@pytest.mark.parametrize("protocol", ["batch", "streaming"])
def test_tp_width_cells_match_vmapped(cells, tp_cells, protocol, name):
    """The tentpole invariant: a width-W tensor-parallel flight reproduces
    the width-1 vmapped reference on the same trial set — scores within 1e-6,
    identical rule decisions (truncations, reclaims, retirement steps).  The
    model axis changes *where* each einsum's operands live, never the math
    (the psum seams restore full activations at the Megatron cut points)."""
    ref = cells[protocol][REFERENCE]
    got = tp_cells[protocol][name]
    np.testing.assert_allclose(got["scores"], ref["scores"],
                               rtol=0, atol=1e-6)
    assert got["n_truncated"] == ref["n_truncated"]
    assert got["n_reclaimed"] == ref["n_reclaimed"]
    if protocol == "streaming":
        assert got["steps"] == ref["steps"]
        assert got["diverged"] == ref["diverged"]


@multi_device
def test_tp_widths_agree_with_each_other(tp_cells):
    """Widths 2 and 4 of the same cell agree with each other too (not just
    each with the reference): the partitioning is associativity-stable at
    these shapes."""
    a = tp_cells["batch"]["tp2-perstep-host"]
    b = tp_cells["batch"]["tp4-perstep-host"]
    np.testing.assert_allclose(a["scores"], b["scores"], rtol=0, atol=1e-6)


# -- serial reference ------------------------------------------------------------


def test_streaming_matches_serial_reference(cells, cfgs):
    """The serial driver, cut at each trial's effective (possibly truncated)
    budget, reproduces the streaming engine's scores trial-for-trial."""
    ref = cells["streaming"][REFERENCE]
    serial = run_serial_reference(cfgs, ref["steps"])
    np.testing.assert_allclose(ref["scores"], serial, rtol=1e-5, atol=1e-6)


def test_rule_cuts_actually_fired(cells):
    """The workload is only a differential test if the rung rule bites: at
    least one lane must be truncated in each protocol's reference cell."""
    assert cells["batch"][REFERENCE]["n_truncated"] >= 1
    assert cells["streaming"][REFERENCE]["n_truncated"] >= 1
    steps = cells["streaming"][REFERENCE]["steps"]
    assert any(0 < s < 8 for s in steps), \
        "some lane must retire mid-ladder (truncated short of max budget)"


# -- prefetch ring: host-fed scans must be indistinguishable ---------------------


@pytest.mark.parametrize("protocol", ["batch", "streaming"])
def test_ring_cell_actually_used_the_ring(cells, protocol):
    """The ring cells only differentially test the host-fed path if the fill
    thread really produced windows — a silently disabled ring would pass the
    bit-equality assertions by running the in-scan engine."""
    got = cells[protocol]["vmapped-chunked-ring"]
    assert got["ring_fills"] >= 1
    assert 0.0 <= got["overlap_frac"] <= 1.0


# -- the headline dispatch claim -------------------------------------------------


def test_device_rules_collapse_ladder_to_one_dispatch(cells):
    """With the rule in the scan, chunk boundaries stop clamping to event
    gaps: the whole 8-step ladder is ONE device call in both protocols
    (streaming's initial mass fill rides the free virgin rebuild), while the
    host-rule chunked path pays one dispatch per rung gap."""
    assert cells["batch"]["vmapped-chunked-device"]["dispatches"] == 1
    assert cells["streaming"]["vmapped-chunked-device"]["dispatches"] == 1
    assert cells["batch"]["vmapped-chunked-host"]["dispatches"] > 1
    assert cells["streaming"]["vmapped-chunked-host"]["dispatches"] > 1


# -- in-scan rule updates vs their host twins (randomized, driver-free) ----------


def test_cohort_rule_update_matches_host_on_random_cases():
    from repro.train.population import cohort_rule_state, cohort_rule_update

    rng = np.random.default_rng(7)
    k = 8
    for _ in range(25):
        hook = rung_hook()
        step = int(rng.choice(hook.boundaries + [3]))  # off-boundary = no-op
        budgets = rng.choice([0.0, 2.0, 4.0, 8.0], k)
        # eighths are f32-exact; repeats force tie-breaks, inf forces skips
        losses = rng.choice([0.5, 0.625, 0.75, 0.75, 1.0, np.inf], k)
        diverged = rng.random(k) < 0.25
        want = hook(step, losses, budgets, diverged)
        rules = cohort_rule_state(budgets, np.zeros(k), np.zeros(k),
                                  hook.boundaries, hook.eta)
        got = cohort_rule_update(
            rules, jnp.asarray(losses, jnp.float32), jnp.asarray(diverged),
            jnp.full((k,), step, jnp.int32))
        np.testing.assert_array_equal(np.asarray(got["budgets"], np.float64),
                                      want)


def test_staggered_rule_update_matches_host_on_random_cases():
    """Two hooks, one random tape: the host ``observe`` and the in-scan
    update must make identical cuts AND leave identical rung histories —
    including simultaneous boundary hits, which the device resolves with the
    same lane-order appends as the host loop."""
    from repro.train.population import staggered_rule_state, \
        staggered_rule_update

    rng = np.random.default_rng(11)
    k = 8
    host, dev = rung_hook(), rung_hook()
    spec = dev.device_rule()
    for _ in range(25):
        budgets = rng.choice([0.0, 2.0, 4.0, 8.0], k)
        # live lanes sit anywhere inside their budget (the driver invariant)
        local = np.array([rng.integers(0, int(b) + 1) for b in budgets])
        losses = rng.choice([0.5, 0.625, 0.75, 0.75, 1.0, np.inf], k)
        diverged = rng.random(k) < 0.25
        want = host.observe(local, losses, budgets, diverged)
        hist, counts = spec.lower_history(64)
        rules = staggered_rule_state(budgets, np.zeros(k), np.zeros(k),
                                     spec.boundaries, spec.eta, hist, counts)
        got = staggered_rule_update(
            rules, jnp.asarray(losses, jnp.float32), jnp.asarray(diverged),
            jnp.asarray(local, jnp.int32))
        spec.absorb_history(got["hist"], got["counts"])
        np.testing.assert_array_equal(np.asarray(got["budgets"], np.float64),
                                      want)
    assert dev._rung_history == host._rung_history
    assert host.n_truncated > 0, "the tape must exercise at least one cut"


def test_window_quantile_matches_host_thresholds():
    from repro.core.proposer.pbt import window_quantile

    rng = np.random.default_rng(3)
    for _ in range(25):
        w = int(rng.integers(4, 17))
        n = int(rng.integers(1, w + 1))
        q = float(rng.choice([0.25, 0.4, 0.5]))
        ring = np.zeros(w, np.float32)
        ring[:n] = rng.choice(np.arange(-8, 8, 0.25), n).astype(np.float32)
        scores = sorted(float(x) for x in ring[:n])
        kq = max(1, int(q * n))
        lo, hi = window_quantile(jnp.asarray(ring), jnp.asarray(n),
                                 jnp.float32(q), xp=jnp)
        assert float(lo) == scores[kq - 1]
        assert float(hi) == sorted(scores, reverse=True)[kq - 1]


def test_device_rules_smoke_cli(capsys):
    """The CI smoke entry (`REPRO_DEVRULES_SMOKE=1`) runs the heavier CLI
    with --device-rules; locally a lighter variant stays always-on.  Either
    way the first cohort's whole ladder must cost ONE device dispatch."""
    import json
    import os

    from repro.launch.hpo import main

    heavy = os.environ.get("REPRO_DEVRULES_SMOKE") == "1"
    argv = ["--proposer", "asha", "--vectorize", "4", "--inflight-stop",
            "--lane-refill", "--chunk-steps", "64" if heavy else "16",
            "--device-rules", "--n-samples", "6" if heavy else "4",
            "--steps", "8" if heavy else "4", "--batch", "2", "--seq", "16"]
    assert main(argv) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["engine"].endswith("+devrules"), out["engine"]
    assert out["ladder_device_dispatches"] == 1, out
    assert out["dispatches_per_step"] < 1.0, out
