"""Crash-safe streaming flights: snapshot/restore lane ops, deterministic
fault injection, supervised flight restart + quarantine, and crash-resume
equivalence.

The acceptance contract of the robustness PR: a streaming ``--lane-refill``
flight killed at an arbitrary point and resumed (in-process flight restart,
or a full ``--resume`` from the tracking DB + lane-snapshot store) must
produce per-trial scores bit-identical to the uninterrupted run, with
resumed lanes restarting from their snapshot step instead of step 0.  Faults
are injected deterministically (``repro.core.faultinject``) so every
recovery path runs by construction — no random kill loops, no flakes.

conftest.py forces an 8-virtual-device CPU mesh; tests that need real
sharding skip on a single-device backend.
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, LaneSnapshotStore
from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.core import faultinject
from repro.core.experiment import Experiment
from repro.core.faultinject import FaultPlan, InjectedFault, _parse_clause
from repro.core.job import Job, JobStatus
from repro.core.resource.vectorized import (
    FlightSupervisor,
    VectorizedResourceManager,
)
from repro.core.tracking.database import TrackingDB
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import population_mesh
from repro.launch.hpo import SPACE, PopulationTrial
from repro.optim.hparams import hparams_from_dict, stack_hparams
from repro.train import population as pop

SEQ, BATCH, STEPS = 16, 2, 4
ARCH = "starcoder2-3b"

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a multi-device (virtual CPU) mesh"
)


@pytest.fixture(autouse=True)
def _no_armed_faults():
    """Fault plans are process-global: never leak one across tests."""
    faultinject.disarm()
    yield
    faultinject.disarm()


@pytest.fixture(scope="module")
def tc():
    cfg = get_smoke_config(ARCH)
    return TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                       total_steps=STEPS)


def _trained_pstate(tc, k=2, steps=2):
    """A k-lane population state advanced a few steps so lanes differ from
    init (and from each other: per-lane fold_in keys + distinct hparams)."""
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(0), jnp.arange(k, dtype=jnp.uint32))
    pstate = pop.init_population_state_from_keys(keys, tc)
    step = pop.make_population_train_step(tc, per_trial_batch=False)
    data = SyntheticLM(tc.model.vocab_size, SEQ, BATCH, seed=0)
    hp = stack_hparams([
        hparams_from_dict({"learning_rate": 1e-3 * (i + 1),
                           "total_steps": STEPS}, tc)
        for i in range(k)
    ])
    for s in range(steps):
        pstate, _ = step(pstate, data.make_batch(s), hp)
    return pstate, keys


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


# -- lane snapshot / restore ops --------------------------------------------------

def test_lane_snapshot_restore_round_trip(tc):
    """snapshot(lane 0) spliced into lane 1 of a fresh flight is bit-identical
    to the source lane; every other lane of the target is untouched."""
    pstate, keys = _trained_pstate(tc)
    snap_fn = pop.get_compiled_lane_op(tc, 2, "snapshot")
    restore_fn = pop.get_compiled_lane_op(tc, 2, "restore")

    snap = jax.device_get(snap_fn(pstate, jnp.asarray(0, jnp.int32)))
    # snapshot leaves carry no population axis: same shape as one lane
    for s, p in zip(_leaves(snap["inner"]), _leaves(pstate["inner"])):
        assert s.shape == p.shape[1:]
        np.testing.assert_array_equal(s, p[0])

    fresh = pop.init_population_state_from_keys(keys, tc)
    fresh_leaves = _leaves(fresh["inner"])  # restore donates its input state
    out = restore_fn(fresh, jnp.asarray(1, jnp.int32), jax.device_put(snap))
    for got, src in zip(_leaves(out["inner"]), _leaves(pstate["inner"])):
        np.testing.assert_array_equal(got[1], src[0])  # restored lane
    for got, kept in zip(_leaves(out["inner"]), fresh_leaves):
        np.testing.assert_array_equal(got[0], kept[0])  # untouched lane
    np.testing.assert_array_equal(
        np.asarray(out["last_loss"])[1], np.asarray(pstate["last_loss"])[0])
    assert bool(out["diverged"][1]) == bool(pstate["diverged"][0])


def test_lane_snapshot_is_read_only(tc):
    """The snapshot op must NOT donate its input: the live flight state is
    still usable (and unchanged) after a harvest."""
    pstate, _ = _trained_pstate(tc)
    before = _leaves(pstate)
    snap_fn = pop.get_compiled_lane_op(tc, 2, "snapshot")
    s1 = jax.device_get(snap_fn(pstate, jnp.asarray(0, jnp.int32)))
    # a second call on the same buffers would die if they had been donated
    s2 = jax.device_get(snap_fn(pstate, jnp.asarray(0, jnp.int32)))
    for a, b in zip(_leaves(s1), _leaves(s2)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(before, _leaves(pstate)):
        np.testing.assert_array_equal(a, b)


@multi_device
def test_sharded_lane_snapshot_restore_matches_vmapped(tc):
    """The sharded twins agree bit-for-bit with the single-device ops, with
    the lane living on an arbitrary device of the mesh."""
    mesh = population_mesh()
    k = len(list(mesh.devices.flat))
    pstate, keys = _trained_pstate(tc, k=k)
    lane = k - 1  # owned by the last device on the 1-D pop mesh

    vsnap = jax.device_get(
        pop.get_compiled_lane_op(tc, k, "snapshot")(
            pstate, jnp.asarray(lane, jnp.int32)))
    sstate = pop.shard_population_state(pstate, mesh)
    ssnap = jax.device_get(
        pop.get_compiled_lane_op(tc, k, "snapshot", mesh=mesh)(
            sstate, jnp.asarray(lane, jnp.int32)))
    for a, b in zip(_leaves(vsnap), _leaves(ssnap)):
        np.testing.assert_array_equal(a, b)

    fresh = pop.shard_population_state(
        pop.init_population_state_from_keys(keys, tc), mesh)
    out = pop.get_compiled_lane_op(tc, k, "restore", mesh=mesh)(
        fresh, jnp.asarray(0, jnp.int32), jax.device_put(ssnap))
    for got, src in zip(_leaves(out["inner"]), _leaves(pstate["inner"])):
        np.testing.assert_array_equal(got[0], src[lane])


# -- fault-spec grammar -----------------------------------------------------------

def test_fault_spec_parsing_sites():
    assert _parse_clause("raise@step=20").site == "flight-step"
    assert _parse_clause("raise@issue=5").site == "issue"
    assert _parse_clause("kill@event=3").site == "event"
    c = _parse_clause("nan@lane=2,step=7")
    assert c.site == "lane-nan" and c.cond == {"lane": 2, "step": 7}
    assert _parse_clause("raise@step=4,times=3").times == 3
    for bad in ("boom@step=1", "raise@", "raise@step", "nan@step=3", "raise@lr=1"):
        with pytest.raises(ValueError):
            _parse_clause(bad)
    with pytest.raises(ValueError):
        FaultPlan("  ;  ")


def test_fault_plan_fires_at_threshold_then_exhausts():
    plan = FaultPlan("raise@step=5")
    plan.check("flight-step", step=4)      # below threshold: no-op
    plan.check("event", event=99)          # wrong site: no-op
    with pytest.raises(InjectedFault):
        plan.check("flight-step", step=7)  # >= semantics: first poll past K
    plan.check("flight-step", step=8)      # times exhausted: no-op
    assert plan.fired == [("raise@step=5", {"step": 7})]


def test_fault_plan_poison_lanes_and_multiclause():
    plan = FaultPlan("nan@lane=1,step=4; raise@step=100")
    assert plan.poison_lanes(3) == []
    assert plan.poison_lanes(4) == [1]
    assert plan.poison_lanes(5) == []      # each nan clause fires once
    plan.check("flight-step", step=50)     # the raise clause is independent
    with pytest.raises(InjectedFault):
        plan.check("flight-step", step=100)


def test_fault_env_arming(monkeypatch):
    """A subprocess harness arms a child by environment alone."""
    monkeypatch.setenv(faultinject.ENV_VAR, "raise@step=3")
    monkeypatch.setattr(faultinject, "_PLAN", None)
    monkeypatch.setattr(faultinject, "_ENV_CHECKED", False)
    plan = faultinject.get_plan()
    assert plan is not None and plan.clauses[0].site == "flight-step"
    faultinject.disarm()
    assert faultinject.get_plan() is None  # explicit disarm wins over env


# -- supervisor backoff -----------------------------------------------------------

def test_flight_supervisor_backoff_doubles_and_caps():
    sup = FlightSupervisor(max_restarts=5, backoff_base_s=0.1,
                           backoff_cap_s=0.4, seed=7)
    delays = [sup.delay_s(a) for a in range(1, 6)]
    for a, d in zip(range(1, 6), delays):
        lo = min(0.4, 0.1 * 2 ** (a - 1))
        assert lo <= d <= lo * 1.25 + 1e-9  # exponential base + bounded jitter
    assert max(delays) <= 0.4 * 1.25 + 1e-9
    # deterministic: same seed -> same jitter sequence
    sup2 = FlightSupervisor(max_restarts=5, backoff_base_s=0.1,
                            backoff_cap_s=0.4, seed=7)
    assert delays == [sup2.delay_s(a) for a in range(1, 6)]


# -- checkpointer hardening -------------------------------------------------------

def test_checkpoint_atomic_replace_and_old_fallback(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, {"w": np.arange(3.0)})
    ck.save(1, {"w": np.arange(3.0) * 2})   # same step: atomic replace
    assert ck.all_steps() == [1]
    state, _ = ck.restore(1)
    np.testing.assert_array_equal(state["w"], np.arange(3.0) * 2)
    assert not os.path.exists(str(tmp_path / "step_00000001.old"))
    # crash between _write's two renames: only the .old copy survives —
    # all_steps must count it and restore must fall back to it
    os.rename(str(tmp_path / "step_00000001"),
              str(tmp_path / "step_00000001.old"))
    assert ck.all_steps() == [1]
    state, _ = ck.restore()
    np.testing.assert_array_equal(state["w"], np.arange(3.0) * 2)


def test_checkpoint_all_steps_skips_junk(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(2, {"w": np.zeros(1)})
    (tmp_path / "step_garbage").mkdir()
    (tmp_path / "step_12extra").write_text("junk")
    (tmp_path / "step_00000009.tmp").mkdir()  # partial write: ignored silently
    with pytest.warns(UserWarning, match="non-checkpoint"):
        assert ck.all_steps() == [2]


def test_lane_snapshot_store_disk_round_trip(tmp_path):
    snap = {"inner": {"w": np.arange(4.0)}, "local": np.int64(6)}
    meta = {"local": 6, "stream": 3, "applied": 6, "applied0": 0, "budget": 12}
    store = LaneSnapshotStore(root=str(tmp_path))
    store.put(3, snap, meta)
    assert store.n_persisted == 1
    # a different store instance (a resumed process) reads it back from disk
    fresh = LaneSnapshotStore(root=str(tmp_path))
    assert fresh.lineages() == [3]
    got, gmeta = fresh.get(3)
    np.testing.assert_array_equal(got["inner"]["w"], snap["inner"]["w"])
    assert int(gmeta["local"]) == 6 and int(gmeta["budget"]) == 12
    fresh.forget(3)
    assert fresh.get(3) is None
    assert LaneSnapshotStore(root=str(tmp_path)).get(3) is None  # gone on disk


# -- hung-flight detection --------------------------------------------------------

def test_finish_raises_on_hung_streaming_worker():
    """A worker still alive after the join timeout is a hung flight: its
    leased jobs fail loudly and finish() raises instead of returning under a
    live thread."""
    import threading

    release = threading.Event()
    leased = threading.Event()

    class HangingTarget:
        def run_population(self, configs, scheduler=None, mesh=None):
            scheduler.lease()
            leased.set()
            release.wait(30.0)       # wedged XLA call stand-in

    rm = VectorizedResourceManager(n_parallel=1, lane_refill=True,
                                   finish_join_timeout_s=0.2)
    job = Job(0, {"x": 0}, "slot0", lambda j: None)
    rm._busy[job.resource_id] = None
    rm.run(job, HangingTarget())
    assert leased.wait(10.0), "streaming worker never leased the job"
    with pytest.raises(RuntimeError, match="did not exit"):
        rm.finish()
    assert job.done and job.status == JobStatus.FAILED
    assert "hung" in job.result.error
    release.set()  # unwedge so the worker thread exits


# -- crash -> restart -> restore equivalence (in-process) -------------------------

def _run_streaming_pair(fault, snapshot_every=1, steps=12):
    """Two jobs on a 2-lane supervised streaming flight; returns
    ``{stream: (status, score)}`` plus the trial and manager for telemetry."""
    faultinject.disarm()
    if fault:
        faultinject.arm(fault)
    store = LaneSnapshotStore()
    trial = PopulationTrial(ARCH, steps=steps, batch=BATCH, seq=SEQ, seed=0,
                            population=2, refill_idle_grace_s=0.1,
                            snapshot_every=snapshot_every, snapshots=store)
    rm = VectorizedResourceManager(n_parallel=2, lane_refill=True,
                                   restart_backoff_s=0.001)
    jobs = [Job(i, {"learning_rate": 1e-3 * (i + 1), "stream": 100 + i},
                f"slot{i}", lambda j: None) for i in range(2)]
    for j in jobs:
        rm._busy[j.resource_id] = None
        rm.run(j, trial)
    for j in jobs:
        assert j.wait(300.0), "streaming flight timed out"
    return ({j.config["stream"]: (j.status, j.result.score if j.result else None)
             for j in jobs}, trial, rm)


def test_flight_death_restores_lanes_and_scores_match():
    """THE recovery-equivalence gate, in-process: a flight killed mid-stream
    (injected raise) restarts under supervision, both lanes restore from
    their last snapshot (not step 0), and every trial's score is
    bit-identical to the uninterrupted run."""
    base, t0, _ = _run_streaming_pair(None)
    assert all(st == JobStatus.FINISHED for st, _ in base.values())
    faulted, t1, rm1 = _run_streaming_pair("raise@step=10,times=1")
    assert faulted == base, "scores differ after crash-restore"
    assert rm1.n_flight_deaths == 1 and rm1.n_flight_restarts == 1
    assert t1.n_lane_restores == 2
    assert t1.resumed_from_steps and all(s > 0 for s in t1.resumed_from_steps)
    assert t1.n_snapshots >= 2


def test_nan_poison_retires_lane_with_sentinel():
    """A poisoned lane takes the ordinary divergence path: sentinel score,
    the other lane unharmed."""
    base, _, _ = _run_streaming_pair(None)
    poisoned, trial, rm = _run_streaming_pair("nan@lane=0,step=4")
    assert rm.n_flight_deaths == 0  # a NaN lane is not a flight death
    assert poisoned[100] == (JobStatus.FINISHED, trial.DIVERGED_SCORE)
    assert poisoned[101] == base[101]


# -- classic (non-streaming) crash-resume: the between-batches crash --------------

def _asha_cfg(n_samples=8):
    return {
        "proposer": "asha", "parameter_config": SPACE,
        "n_samples": n_samples, "n_parallel": 1, "target": "max",
        "seed": 11, "min_iter": 1, "max_iter": 4, "eta": 2.0,
        "resource": "local",
    }


def _score_fn(cfg):
    # deterministic stand-in for training: depends on the drawn params AND
    # the rung budget, so promotions score differently at higher rungs
    return (float(np.log10(cfg["learning_rate"]))
            + 0.1 * float(cfg.get("n_iterations", 1)))


def test_classic_asha_crash_resume_no_double_issue(tmp_path):
    """Controller killed between batches (``raise@issue=N``): the resumed
    ASHA run replays the DB + proposer-state WAL, re-queues the mid-flight
    job ONCE, and lands on the same best as an uninterrupted run with the
    same total number of proposals."""
    base_db = TrackingDB(str(tmp_path / "base.sqlite"))
    exp = Experiment(_asha_cfg(), _score_fn, db=base_db)
    best_base = exp.run()
    rows_base = [r for r in base_db.jobs(exp.exp_id)]
    assert all(r["status"] == "finished" for r in rows_base)

    crash_db = TrackingDB(str(tmp_path / "crash.sqlite"))
    faultinject.arm("raise@issue=5")
    exp2 = Experiment(_asha_cfg(), _score_fn, db=crash_db)
    with pytest.raises(InjectedFault):
        exp2.run()
    faultinject.disarm()

    exp3 = Experiment.resume(crash_db, _score_fn)
    best_res = exp3.run()

    assert best_res["score"] == best_base["score"]
    assert {k: v for k, v in best_res["config"].items() if k != "job_id"} \
        == {k: v for k, v in best_base["config"].items() if k != "job_id"}
    rows = crash_db.jobs(exp3.exp_id)
    # a row the resume re-queued is marked lost("controller crash") and re-run
    # under a new id; net finished work must equal the uninterrupted run's —
    # nothing double-issued, nothing dropped
    finished = [r for r in rows if r["status"] == "finished"]
    lost = [r for r in rows if r["status"] == "lost"]
    assert len(finished) == len(rows_base)
    assert all(r.get("error") == "controller crash" for r in lost)
    assert sorted(r["score"] for r in finished) \
        == sorted(r["score"] for r in rows_base)


# -- SIGKILL + --resume CLI harness (subprocess) ----------------------------------

def _hpo_cli(tmp, db, extra, env_extra=None, timeout=900):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # single-device child: no mesh needed
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    env.update(env_extra or {})
    cmd = [sys.executable, "-m", "repro.launch.hpo",
           "--proposer", "random", "--vectorize", "4", "--lane-refill",
           "--n-samples", "8", "--steps", "12", "--batch", "2", "--seq", "16",
           "--db", db] + extra
    return subprocess.run(cmd, cwd=str(tmp), env=env, capture_output=True,
                          text=True, timeout=timeout)


def _scores_by_stream(db_path):
    db = TrackingDB(db_path)
    eid = db.latest_experiment_id()
    return {r["config"].get("stream", r["job_id"]): r["score"]
            for r in db.jobs(eid) if r["status"] == "finished"}


def test_cli_sigkill_then_resume_is_score_equivalent(tmp_path):
    """The full crash story, host-death included: the CLI run is SIGKILLed at
    an event boundary (fault armed via environment, as the chaos CI lane does
    it), ``--resume`` restores the surviving lanes from their on-disk
    snapshots, and per-trial scores match the uninterrupted run exactly."""
    base = _hpo_cli(tmp_path, str(tmp_path / "base.sqlite"),
                    ["--snapshot-every", "1"])
    assert base.returncode == 0, base.stderr[-2000:]

    db = str(tmp_path / "t.sqlite")
    killed = _hpo_cli(tmp_path, db, ["--snapshot-every", "1"],
                      env_extra={faultinject.ENV_VAR: "kill@event=3"})
    assert killed.returncode in (-signal.SIGKILL, 128 + signal.SIGKILL), \
        f"expected SIGKILL, got rc={killed.returncode}\n{killed.stderr[-2000:]}"
    assert os.path.isdir(db + ".lanes"), "no lane snapshots persisted"

    resumed = _hpo_cli(tmp_path, db, ["--resume"])
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    out = json.loads(resumed.stdout[resumed.stdout.index("{"):])
    assert out["resumed"] is True
    assert out["resumed_lanes"] >= 1
    assert max(out["resumed_from_steps"]) > 0, \
        "resumed lanes restarted from step 0 instead of their snapshots"

    a = _scores_by_stream(str(tmp_path / "base.sqlite"))
    b = _scores_by_stream(db)
    assert set(a) == set(b)
    worst = max(abs(a[k] - b[k]) for k in a)
    assert worst <= 1e-6, f"kill+resume diverged from uninterrupted: {worst}"


def test_cli_sigkill_mid_window_then_resume_restores_ring_cursors(tmp_path):
    """The ring-fed flight (``--data-ring``) dies mid-window and resumes:
    lane snapshots carry each lane's data cursor, so the restored flight
    re-keys the prefetch ring mid-stream and reproduces the uninterrupted
    ring run's scores exactly — the host feed position is part of the
    crash-safe state, not just the weights."""
    ring = ["--chunk-steps", "8", "--data-ring"]
    base = _hpo_cli(tmp_path, str(tmp_path / "base.sqlite"),
                    ring + ["--snapshot-every", "1"])
    assert base.returncode == 0, base.stderr[-2000:]

    db = str(tmp_path / "t.sqlite")
    killed = _hpo_cli(tmp_path, db, ring + ["--snapshot-every", "1"],
                      env_extra={faultinject.ENV_VAR: "kill@event=3"})
    assert killed.returncode in (-signal.SIGKILL, 128 + signal.SIGKILL), \
        f"expected SIGKILL, got rc={killed.returncode}\n{killed.stderr[-2000:]}"
    assert os.path.isdir(db + ".lanes"), "no lane snapshots persisted"

    resumed = _hpo_cli(tmp_path, db, ring + ["--resume"])
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    out = json.loads(resumed.stdout[resumed.stdout.index("{"):])
    assert out["resumed"] is True
    assert out["resumed_lanes"] >= 1
    assert max(out["resumed_from_steps"]) > 0, \
        "resumed lanes restarted from step 0 instead of their snapshots"
    assert out["engine"].endswith("+ring"), out["engine"]
    assert out["ring_fills"] >= 1
    assert 0.0 <= out["overlap_frac"] <= 1.0

    a = _scores_by_stream(str(tmp_path / "base.sqlite"))
    b = _scores_by_stream(db)
    assert set(a) == set(b)
    worst = max(abs(a[k] - b[k]) for k in a)
    assert worst <= 1e-6, \
        f"ring kill+resume diverged from uninterrupted: {worst}"
