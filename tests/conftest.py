import importlib.util
import os
import sys

# tests run on a virtual 8-device CPU mesh so the sharded population engine
# (shard_map over the population axis) is exercised for real; this must be set
# before jax initializes.  The dry-run's 512-device flag stays process-local
# to launch/dryrun.py.  Keep kernels on the ref path by default.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# -- per-test timeout guard ---------------------------------------------------
# The streaming population engines run flush/flight worker *threads*; a
# regression that deadlocks one must fail the suite, not hang CI.  Prefer the
# real pytest-timeout plugin when installed (requirements-dev.txt); otherwise
# fall back to a faulthandler watchdog that dumps every thread's stack and
# aborts the process.  Tune with PYTEST_TIMEOUT (seconds, 0 disables).
_HAVE_PYTEST_TIMEOUT = importlib.util.find_spec("pytest_timeout") is not None
_TEST_TIMEOUT_S = float(os.environ.get("PYTEST_TIMEOUT") or "600")


def pytest_configure(config):
    if not (_HAVE_PYTEST_TIMEOUT and _TEST_TIMEOUT_S > 0):
        return
    try:
        explicit = config.getoption("timeout")
    except ValueError:  # plugin present but disabled (-p no:timeout)
        return
    if explicit is None:  # 0 is an explicit opt-out (e.g. pdb sessions)
        config.option.timeout = _TEST_TIMEOUT_S
        config.option.timeout_method = "thread"


if not _HAVE_PYTEST_TIMEOUT and _TEST_TIMEOUT_S > 0:
    import faulthandler

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_protocol(item, nextitem):
        faulthandler.dump_traceback_later(_TEST_TIMEOUT_S, exit=True)
        try:
            yield
        finally:
            faulthandler.cancel_dump_traceback_later()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


ROSENBROCK_SPACE = [
    {"name": "x", "type": "float", "range": [-2.0, 2.0]},
    {"name": "y", "type": "float", "range": [-1.0, 3.0]},
]


def rosenbrock(cfg):
    x, y = float(cfg["x"]), float(cfg["y"])
    return -((1 - x) ** 2 + 100 * (y - x * x) ** 2)


@pytest.fixture
def rosenbrock_problem():
    return ROSENBROCK_SPACE, rosenbrock
