import os
import sys

# tests must see the real 1-CPU container (the dry-run's 512-device flag is
# process-local to launch/dryrun.py); keep kernels on the ref path by default.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


ROSENBROCK_SPACE = [
    {"name": "x", "type": "float", "range": [-2.0, 2.0]},
    {"name": "y", "type": "float", "range": [-1.0, 3.0]},
]


def rosenbrock(cfg):
    x, y = float(cfg["x"]), float(cfg["y"])
    return -((1 - x) ** 2 + 100 * (y - x * x) ** 2)


@pytest.fixture
def rosenbrock_problem():
    return ROSENBROCK_SPACE, rosenbrock
