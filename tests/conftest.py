import os
import sys

# tests run on a virtual 8-device CPU mesh so the sharded population engine
# (shard_map over the population axis) is exercised for real; this must be set
# before jax initializes.  The dry-run's 512-device flag stays process-local
# to launch/dryrun.py.  Keep kernels on the ref path by default.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


ROSENBROCK_SPACE = [
    {"name": "x", "type": "float", "range": [-2.0, 2.0]},
    {"name": "y", "type": "float", "range": [-1.0, 3.0]},
]


def rosenbrock(cfg):
    x, y = float(cfg["x"]), float(cfg["y"])
    return -((1 - x) ** 2 + 100 * (y - x * x) ** 2)


@pytest.fixture
def rosenbrock_problem():
    return ROSENBROCK_SPACE, rosenbrock
