"""Shared cross-engine differential harness.

One seeded multi-rung ladder workload, pushed through every population-engine
cell — {vmapped, sharded} x {per-step, chunked} x {host-rule, device-rule} —
in both the batch protocol (cohort rung rule) and the streaming lane-refill
protocol (staggered/async-SHA rule), plus the serial-driver reference.
``test_engine_matrix.py`` asserts the pairwise equivalence promises over
these cells; the ad-hoc pairwise checks this replaces lived in
``test_chunked.py`` / ``test_lane_refill.py``.

Every cell runs the SAME workload at the SAME population size (``LANES`` —
the conftest-forced virtual-device count, so vmapped and sharded cells share
one K and the comparison is lane-for-lane).  Each cell gets a fresh
``InFlightSuccessiveHalving`` hook; rule telemetry (truncations, reclaims)
rides back with the scores so the matrix can assert the device twins make
the *same decisions*, not just converge to close numbers.
"""
import numpy as np

from repro.core.proposer.early_stop import InFlightSuccessiveHalving
from repro.core.resource.vectorized import QueueFeedScheduler
from repro.launch.hpo import PopulationTrial

SEQ, BATCH = 16, 2
ARCH = "starcoder2-3b"
STEPS_PER_UNIT = 2
# one K for every cell: equals the 8-virtual-device CPU mesh conftest forces,
# so the sharded cells need no padding and compare lane-for-lane with vmapped
LANES = 8
ETA, MIN_ITER, MAX_ITER = 2.0, 2, 8


def ladder(n=6):
    """The seeded workload: geometric LRs with budgets cycling 2/4/8 steps,
    so both rung boundaries (2 and 4) fire with a mixed cohort — some lanes
    end exactly at a boundary, some pass through, some get cut."""
    lrs = np.geomspace(3e-4, 4e-3, n)
    budgets = ([1, 2, 4, 1, 2, 4] * ((n + 5) // 6))[:n]
    return [{"learning_rate": float(lr), "stream": i, "n_iterations": int(b)}
            for i, (lr, b) in enumerate(zip(lrs, budgets))]


def rung_hook():
    """A fresh rung rule per cell: boundaries {2, 4} under an 8-step cap."""
    return InFlightSuccessiveHalving(eta=ETA, min_iter=MIN_ITER,
                                     max_iter=MAX_ITER)


def _trial(chunk, device, ring=False, **kw):
    """Extra ``kw`` forwards to ``PopulationTrial`` (fused-kernel flags,
    ``model_parallel``) so fused/TP cells reuse the same workload."""
    return PopulationTrial(ARCH, steps=STEPS_PER_UNIT, batch=BATCH, seq=SEQ,
                           seed=0, population=LANES, early_stop=rung_hook(),
                           refill_idle_grace_s=0.0, chunk_steps=chunk,
                           device_rules=device, data_ring=ring,
                           ring_windows=2, **kw)


def run_batch_cell(cfgs, chunk=1, device=False, mesh=None, ring=False, **kw):
    """Batch protocol: one synchronized flight, cohort rung rule
    (``InFlightSuccessiveHalving.__call__`` on host, ``cohort_rule_update``
    in-scan with ``device=True``).  ``ring=True`` feeds the fused scans from
    the host-filled prefetch ring (``--data-ring``) — the host synth adapter
    must reproduce the in-scan synthesis exactly.  A two-level ``mesh``
    (``population_mesh(width=W)``) runs the width-W tensor-parallel engine."""
    trial = _trial(chunk, device, ring=ring, **kw)
    scores = trial.run_population(list(cfgs), mesh=mesh)
    return {
        "scores": scores,
        "n_truncated": trial.early_stop.n_truncated,
        "n_reclaimed": trial.early_stop.n_reclaimed,
        "dispatches": trial.n_dispatches,
        "train_steps": trial.n_train_steps,
        "ring_fills": trial.n_ring_fills,
        "overlap_frac": trial.ring_overlap_frac,
    }


def run_streaming_cell(cfgs, chunk=1, device=False, mesh=None, ring=False,
                       **kw):
    """Streaming protocol: lane-refill flight fed by a fixed queue, staggered
    rung rule (``observe`` on host, ``staggered_rule_update`` in-scan)."""
    trial = _trial(chunk, device, ring=ring, **kw)
    feed = QueueFeedScheduler(list(cfgs))
    trial.run_population([], mesh=mesh, scheduler=feed)
    n = len(cfgs)
    assert len(feed.scores) == n, "every queued config must stream a result"
    return {
        "scores": feed.ordered_scores(n),
        "steps": [feed.extras[i]["steps"] for i in range(n)],
        "diverged": [feed.extras[i]["diverged"] for i in range(n)],
        "n_truncated": trial.early_stop.n_truncated,
        "n_reclaimed": trial.early_stop.n_reclaimed,
        "dispatches": trial.n_dispatches,
        "train_steps": trial.n_train_steps,
        "ring_fills": trial.n_ring_fills,
        "overlap_frac": trial.ring_overlap_frac,
    }


def _elastic_trial(chunk):
    return PopulationTrial(ARCH, steps=STEPS_PER_UNIT, batch=BATCH, seq=SEQ,
                           seed=0, population=LANES, early_stop=rung_hook(),
                           refill_idle_grace_s=0.0, chunk_steps=chunk,
                           elastic_regrid=True)


def run_elastic_batch_cell(cfgs, chunk=1, pool=False):
    """Elastic batch protocol (``--elastic-regrid``): cohort rung rule with
    lane regrids at each boundary.  ``pool=True`` leases device slices
    through an ``ElasticLanePool`` so survivors re-layout onto the two-level
    ``(pop, model)`` mesh; ``pool=False`` is the vmapped elastic engine
    (pure lane compaction, bit-comparable to the fixed-width cells)."""
    from repro.core.resource.sharded import ElasticLanePool

    trial = _elastic_trial(chunk)
    elastic = ElasticLanePool() if pool else None
    scores = trial.run_population(list(cfgs), elastic=elastic)
    return {
        "scores": scores,
        "n_truncated": trial.early_stop.n_truncated,
        "n_reclaimed": trial.early_stop.n_reclaimed,
        "dispatches": trial.n_dispatches,
        "train_steps": trial.n_train_steps,
        "regrids": trial.n_regrids,
        "lane_width_history": trial.lane_width_history,
        "pool_widths": elastic.width_history if pool else None,
    }


def run_elastic_streaming_cell(cfgs, chunk=1, pool=False):
    """Elastic streaming protocol: lane-refill flight whose tail regrids once
    the feed drains and live lanes fall to half the pod or fewer."""
    from repro.core.resource.sharded import ElasticLanePool

    trial = _elastic_trial(chunk)
    elastic = ElasticLanePool() if pool else None
    feed = QueueFeedScheduler(list(cfgs))
    trial.run_population([], scheduler=feed, elastic=elastic)
    n = len(cfgs)
    assert len(feed.scores) == n, "every queued config must stream a result"
    return {
        "scores": feed.ordered_scores(n),
        "steps": [feed.extras[i]["steps"] for i in range(n)],
        "diverged": [feed.extras[i]["diverged"] for i in range(n)],
        "n_truncated": trial.early_stop.n_truncated,
        "n_reclaimed": trial.early_stop.n_reclaimed,
        "dispatches": trial.n_dispatches,
        "train_steps": trial.n_train_steps,
        "regrids": trial.n_regrids,
        "lane_width_history": trial.lane_width_history,
        "pool_widths": elastic.width_history if pool else None,
    }


def run_serial_reference(cfgs, eff_steps):
    """Serial-driver scores measured at the population cells' effective
    budgets: the compile-once per-trial loop, cut at each trial's (possibly
    rung-truncated) step count — the ground truth every engine must match."""
    trial = PopulationTrial(ARCH, steps=STEPS_PER_UNIT, batch=BATCH, seq=SEQ,
                            seed=0)
    return [trial.serial_score_at(dict(c), steps=st)
            for c, st in zip(cfgs, eff_steps)]
