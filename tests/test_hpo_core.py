"""Tests for the paper's contribution: Proposer / ResourceManager / Experiment
(Algorithm 1), BasicConfig protocol, tracking DB, fault tolerance."""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core.basic_config import BasicConfig, parse_result, print_result
from repro.core.experiment import Experiment
from repro.core.proposer import available_proposers, make_proposer
from repro.core.search_space import SearchSpace
from repro.core.tracking.database import TrackingDB

ALL_PROPOSERS = ["random", "grid", "gp", "tpe", "hyperband", "bohb", "asha", "pbt", "cmaes"]


# ------------------------------------------------------------------ BasicConfig
def test_basic_config_roundtrip(tmp_path):
    cfg = BasicConfig(x=-5.0, y=5.0, job_id=0)
    path = str(tmp_path / "job.json")
    cfg.save(path)
    loaded = BasicConfig(x=0.0, y=0.0, z="default").load(path)
    assert loaded.x == -5.0 and loaded.y == 5.0 and loaded.z == "default"
    assert loaded["job_id"] == 0  # paper Code 1 carries job_id


def test_basic_config_standalone():
    """The paper's usability claim: defaults keep the script standalone."""
    cfg = BasicConfig(lr=0.001, epochs=10).load(None)
    assert cfg.lr == 0.001


def test_print_result_protocol(capsys):
    print_result(0.93)
    out = capsys.readouterr().out
    payload = parse_result(out)
    assert payload["score"] == 0.93 and "extra" not in payload
    print_result(0.5, extra={"ckpt": "m0"})
    payload = parse_result(capsys.readouterr().out)
    assert payload["score"] == 0.5 and payload["extra"] == {"ckpt": "m0"}
    with pytest.raises(ValueError):
        parse_result("no result here")


# ------------------------------------------------------------------ proposers
@pytest.mark.parametrize("name", ALL_PROPOSERS)
def test_proposer_improves_rosenbrock(name, rosenbrock_problem):
    space_json, fn = rosenbrock_problem
    exp = Experiment(
        {"proposer": name, "parameter_config": space_json, "n_samples": 16,
         "n_parallel": 4, "target": "max", "random_seed": 0},
        fn,
    )
    best = exp.run()
    assert best is not None
    # random baseline at 16 samples lands well above -400; all must clear it
    assert best["score"] > -400.0, (name, best["score"])
    assert -2.0 <= best["config"]["x"] <= 2.0
    assert -1.0 <= best["config"]["y"] <= 3.0


def test_registry_lists_at_least_nine():
    # paper Table I: Auptimizer integrates 9 HPO algorithms
    assert len(available_proposers()) >= 9


def test_grid_covers_product():
    space = SearchSpace.from_json([
        {"name": "a", "type": "float", "range": [0, 1], "n_grid": 3},
        {"name": "b", "type": "choice", "range": [10, 20]},
    ])
    prop = make_proposer("grid", space, maximize=True)
    seen = set()
    while not prop.finished():
        cfg = prop.get_param()
        if cfg is None:
            break
        seen.add((round(cfg["a"], 6), cfg["b"]))

        class J:  # minimal job stub
            config = cfg
        prop.update(0.0, J)
    assert len(seen) == 6


def test_proposers_respect_bounds(rosenbrock_problem):
    space_json, _ = rosenbrock_problem
    space = SearchSpace.from_json(space_json)
    for name in ("random", "tpe", "gp"):
        prop = make_proposer(name, space, maximize=True, n_samples=12, random_seed=1)
        for _ in range(12):
            cfg = prop.get_param()
            if cfg is None:
                break
            assert -2.0 <= cfg["x"] <= 2.0, name
            assert -1.0 <= cfg["y"] <= 3.0, name

            class J:
                config = cfg
            prop.update(float(np.random.rand()), J)


def test_hyperband_budget_allocation(rosenbrock_problem):
    """Hyperband must propose n_iterations budgets and promote survivors."""
    space_json, fn = rosenbrock_problem
    budgets = []

    def target(cfg):
        budgets.append(cfg["n_iterations"])
        return fn(cfg)

    exp = Experiment(
        {"proposer": "hyperband", "parameter_config": space_json, "n_samples": 20,
         "n_parallel": 2, "target": "max", "random_seed": 0, "max_iter": 9, "eta": 3},
        target,
    )
    exp.run()
    assert len(set(budgets)) > 1, "hyperband should use multiple budget rungs"


# ------------------------------------------------------------------ experiment / RM
def test_parallel_jobs_actually_overlap(rosenbrock_problem):
    space_json, fn = rosenbrock_problem
    live = {"now": 0, "max": 0}
    lock = threading.Lock()

    def target(cfg):
        with lock:
            live["now"] += 1
            live["max"] = max(live["max"], live["now"])
        time.sleep(0.05)
        with lock:
            live["now"] -= 1
        return fn(cfg)

    exp = Experiment(
        {"proposer": "random", "parameter_config": space_json, "n_samples": 12,
         "n_parallel": 4, "target": "max", "random_seed": 0},
        target,
    )
    exp.run()
    assert live["max"] >= 2, "n_parallel=4 should overlap jobs"


def test_failed_jobs_retry_then_surface(rosenbrock_problem):
    space_json, fn = rosenbrock_problem
    calls = {}

    def flaky(cfg):
        key = round(cfg["x"], 6)
        calls[key] = calls.get(key, 0) + 1
        if calls[key] == 1:
            raise RuntimeError("transient failure")
        return fn(cfg)

    exp = Experiment(
        {"proposer": "random", "parameter_config": space_json, "n_samples": 6,
         "n_parallel": 2, "target": "max", "random_seed": 0, "max_retries": 2},
        flaky,
    )
    best = exp.run()
    assert best is not None and best["score"] > -1e8
    assert all(n >= 2 for n in calls.values()), "every config retried after failure"


def test_straggler_deadline_kills(rosenbrock_problem):
    space_json, fn = rosenbrock_problem
    def slow_then_fast(cfg):
        if cfg["job_id"] == 0:
            time.sleep(5.0)  # straggler
        return fn(cfg)

    exp = Experiment(
        {"proposer": "random", "parameter_config": space_json, "n_samples": 4,
         "n_parallel": 2, "target": "max", "random_seed": 0,
         "job_deadline_s": 0.5, "max_retries": 0},
        slow_then_fast,
    )
    t0 = time.time()
    exp.run()
    assert time.time() - t0 < 4.0, "deadline must reap the straggler"
    statuses = [j.status.value for j in exp.job_log]
    assert "killed" in statuses


def test_tracking_db_records_everything(tmp_path, rosenbrock_problem):
    space_json, fn = rosenbrock_problem
    db_path = str(tmp_path / "track.db")
    exp = Experiment(
        {"proposer": "random", "parameter_config": space_json, "n_samples": 5,
         "n_parallel": 1, "target": "max", "random_seed": 0, "db_path": db_path},
        fn,
    )
    exp.run()
    db = TrackingDB(db_path)
    eid = db.latest_experiment_id()
    rows = db.jobs(eid)
    assert len(rows) == 5
    assert all(r["status"] == "finished" and r["score"] is not None for r in rows)
    assert db.get_experiment(eid)["end_time"] is not None


def test_experiment_resume_after_crash(tmp_path, rosenbrock_problem):
    """Paper fault-tolerance: resume replays history and re-queues mid-flight jobs."""
    space_json, fn = rosenbrock_problem
    db_path = str(tmp_path / "resume.db")
    db = TrackingDB(db_path)
    exp = Experiment(
        {"proposer": "random", "parameter_config": space_json, "n_samples": 8,
         "n_parallel": 1, "target": "max", "random_seed": 0},
        fn, db=db,
    )
    # simulate a crash: record an experiment with 3 finished jobs + 1 running
    exp.exp_id = db.create_experiment(exp.exp_config, "tester")
    for i in range(3):
        cfg = exp.proposer.get_param()
        cfg["job_id"] = i
        db.record_job_start(exp.exp_id, i, json.dumps(cfg), "local0")
        db.record_job_end(exp.exp_id, i, "finished", fn(cfg), None, None)
    crash_cfg = exp.proposer.get_param()
    crash_cfg["job_id"] = 3
    db.record_job_start(exp.exp_id, 3, json.dumps(crash_cfg), "local0")
    # resume into a fresh controller
    exp2 = Experiment.resume(db, fn)
    best = exp2.run()
    rows = db.jobs(exp2.exp_id)
    done = [r for r in rows if r["status"] == "finished"]
    assert len(done) >= 8, f"resume must complete the remaining budget, got {len(done)}"
    # the mid-flight config was re-queued and re-run
    rerun = [r for r in done if abs(r["config"]["x"] - crash_cfg["x"]) < 1e-9]
    assert rerun, "mid-flight job must be re-queued on resume"
    assert best is not None


def test_switching_proposers_is_config_only(rosenbrock_problem):
    """Paper flexibility claim: same target code, one config word changes."""
    space_json, fn = rosenbrock_problem
    results = {}
    for name in ("random", "tpe", "gp"):
        exp_cfg = {"proposer": name, "parameter_config": space_json,
                   "n_samples": 10, "n_parallel": 2, "target": "max", "random_seed": 3}
        results[name] = Experiment(exp_cfg, fn).run()["score"]
    assert all(np.isfinite(v) for v in results.values())
