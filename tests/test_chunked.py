"""Fused multi-step scan engine (chunked execution) + device batch synthesis.

Covers the chunked-dispatch contract end to end: the counter-based
``synth_batch`` generator produces bit-identical batches under NumPy and XLA
(including the negative sentinel streams padding lanes ride on); a fused
``lax.scan`` chunk reproduces the per-step population loop bit-for-bit
(vmapped and sharded); the drivers align chunk boundaries with rung /
retirement / PBT-round event steps; a divergence latch set mid-chunk freezes
the lane without corrupting the flight; the point-to-point (ring-``ppermute``)
sharded clone matches the vmapped clone; and repeated chunked runs do not
grow the compile cache (compile-leak guard).

conftest.py forces an 8-virtual-device CPU mesh.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.core.experiment import Experiment
from repro.core.proposer.early_stop import InFlightSuccessiveHalving
from repro.core.resource.vectorized import QueueFeedScheduler
from repro.data.pipeline import (
    SyntheticLM,
    split_stream,
    split_streams,
    synth_batch,
    synth_population_batch,
)
from repro.distributed.sharding import population_mesh
from repro.launch.hpo import PopulationTrial, _pow2_floor
from repro.optim.hparams import hparams_from_dict, stack_hparams
from repro.train import population as pop

SEQ, BATCH = 16, 2
ARCH = "starcoder2-3b"

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a multi-device (virtual CPU) mesh"
)


@pytest.fixture(scope="module")
def tc():
    cfg = get_smoke_config(ARCH)
    return TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                       total_steps=8)


@pytest.fixture(scope="module")
def data(tc):
    return SyntheticLM(tc.model.vocab_size, SEQ, BATCH, seed=0)


def _keys(k):
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(0), jnp.arange(k, dtype=jnp.uint32))


def _php(tc, lrs, budgets):
    return stack_hparams([
        hparams_from_dict({"learning_rate": lr, "total_steps": b}, tc)
        for lr, b in zip(lrs, budgets)
    ])


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# -- device batch synthesis -------------------------------------------------------


def test_synth_batch_device_host_bit_identity(data):
    """The headline data contract: one generator, two executors, same bits —
    including the negative sentinel streams idle/padding lanes consume."""
    for stream in (0, 1, 7, 12345, -1, -3):
        host = data.make_batch(5, stream=stream)
        dev = jax.jit(
            lambda st, s=stream: synth_batch(data, s, st, xp=jnp)
        )(jnp.asarray(5, jnp.int32))
        for k in host:
            np.testing.assert_array_equal(host[k], np.asarray(dev[k]))
            assert host[k].dtype == np.asarray(dev[k]).dtype


def test_synth_population_batch_per_lane_cursors(data):
    """Per-lane steps + streams: each lane's slab equals its own make_batch,
    on host and on device, sentinels included."""
    streams = [0, 9, -1, -2]
    steps = np.asarray([0, 3, 1, 7])
    lo, hi = split_streams(streams)
    host = data.make_population_batch(steps, streams)
    dev = jax.jit(
        lambda st: synth_population_batch(data, lo, hi, st, xp=jnp)
    )(jnp.asarray(steps, jnp.int32))
    for k in host:
        np.testing.assert_array_equal(host[k], np.asarray(dev[k]))
    for i, (s, st) in enumerate(zip(streams, steps)):
        np.testing.assert_array_equal(
            host["tokens"][i], data.make_batch(int(st), stream=s)["tokens"])


def test_synth_streams_independent_and_deterministic(data):
    a = data.make_batch(5, stream=1)
    assert not np.array_equal(a["tokens"], data.make_batch(5, stream=2)["tokens"])
    assert not np.array_equal(a["tokens"], data.make_batch(6, stream=1)["tokens"])
    np.testing.assert_array_equal(a["tokens"], data.make_batch(5, stream=1)["tokens"])
    # sentinel streams are distinct from each other and from real streams
    m1 = data.make_batch(1, stream=-1)["tokens"]
    m2 = data.make_batch(1, stream=-2)["tokens"]
    assert not np.array_equal(m1, m2)
    assert not np.array_equal(m1, data.make_batch(1)["tokens"])


# -- scan-vs-loop bit equality ----------------------------------------------------


def test_scan_chunk_matches_per_step_loop_bitwise(tc, data):
    k, t_chunk = 4, 8
    streams = [0, 5, -1, 7]
    lo, hi = split_streams(streams)
    php = _php(tc, [1e-3, 3e-3, 2e-3, 5e-3], [8, 5, 8, 8])
    pstep = pop.get_compiled_population_step(tc, k, per_trial_batch=True)
    ps = pop.init_population_state_from_keys(_keys(k), tc)
    for s in range(t_chunk):
        ps, _ = pstep(ps, data.make_population_batch(s, streams), php)
    scan = pop.get_compiled_population_scan_step(tc, k, data, t_chunk)
    ps2 = pop.init_population_state_from_keys(_keys(k), tc)
    ps2, metrics = scan(ps2, php, jnp.zeros(k, jnp.int32),
                        jnp.asarray(lo), jnp.asarray(hi))
    assert _tree_equal(ps, ps2), "fused scan must be bit-identical to the loop"
    # stacked metrics: one entry per step of the chunk, per lane
    assert np.asarray(metrics["loss"]).shape == (t_chunk, k)
    # mid-chunk budget end: lane 1 (budget 5) froze inside the chunk
    assert np.asarray(ps2["inner"]["opt"]["step"]).tolist() == [8, 5, 8, 8]


@multi_device
def test_sharded_scan_chunk_matches_vmapped_loop_bitwise(tc, data):
    mesh = population_mesh()
    k, t_chunk = pop.pad_population(jax.device_count(), mesh), 4
    streams = list(range(3)) + [-(i + 1) for i in range(k - 3)]
    lo, hi = split_streams(streams)
    php = _php(tc, [2e-3] * k, [4, 4, 4] + [0] * (k - 3))
    pstep = pop.get_compiled_population_step(tc, k, per_trial_batch=True)
    ps = pop.init_population_state_from_keys(_keys(k), tc)
    for s in range(t_chunk):
        ps, _ = pstep(ps, data.make_population_batch(s, streams), php)
    scan = pop.get_compiled_population_scan_step(tc, k, data, t_chunk, mesh=mesh)
    ps2 = pop.shard_population_state(
        pop.init_population_state_from_keys(_keys(k), tc), mesh)
    ps2, _ = scan(ps2, php, jnp.zeros(k, jnp.int32),
                  jnp.asarray(lo), jnp.asarray(hi))
    assert _tree_equal(ps, ps2)


def test_scan_chunk_shared_stream_mode(tc, data):
    """per_trial_batch=False twin: one broadcast batch synthesized on device."""
    k, t_chunk = 2, 4
    php = _php(tc, [1e-3, 4e-3], [4, 4])
    pstep = pop.get_compiled_population_step(tc, k, per_trial_batch=False)
    ps = pop.init_population_state_from_keys(_keys(k), tc)
    for s in range(t_chunk):
        ps, _ = pstep(ps, data.make_batch(s), php)
    scan = pop.get_compiled_population_scan_step(
        tc, k, data, t_chunk, per_trial_batch=False)
    lo, hi = split_stream(0)
    ps2 = pop.init_population_state_from_keys(_keys(k), tc)
    ps2, _ = scan(ps2, php, jnp.asarray(0, jnp.int32),
                  jnp.uint32(lo), jnp.uint32(hi))
    assert _tree_equal(ps, ps2)


def test_divergence_latch_mid_chunk(tc, data):
    """A lane going NaN inside a chunk freezes there (budget masking keeps the
    rest training) and the latch/score match the per-step loop exactly."""
    k, t_chunk = 2, 8
    streams = [0, 1]
    lo, hi = split_streams(streams)
    php = _php(tc, [1e-3, 1e9], [8, 8])  # lane 1 diverges immediately
    pstep = pop.get_compiled_population_step(tc, k, per_trial_batch=True)
    ps = pop.init_population_state_from_keys(_keys(k), tc)
    for s in range(t_chunk):
        ps, _ = pstep(ps, data.make_population_batch(s, streams), php)
    scan = pop.get_compiled_population_scan_step(tc, k, data, t_chunk)
    ps2 = pop.init_population_state_from_keys(_keys(k), tc)
    ps2, _ = scan(ps2, php, jnp.zeros(k, jnp.int32),
                  jnp.asarray(lo), jnp.asarray(hi))
    assert np.asarray(ps2["diverged"]).tolist() == [False, True]
    assert _tree_equal(ps, ps2)
    assert int(np.asarray(ps2["inner"]["opt"]["step"])[1]) < t_chunk


# -- driver equivalence: chunk boundaries on event steps --------------------------


def _ladder(n):
    lrs = np.geomspace(3e-4, 4e-3, n)
    budgets = ([1, 2, 4, 1, 2, 4] * ((n + 5) // 6))[:n]
    return [{"learning_rate": float(lr), "stream": i, "n_iterations": int(b)}
            for i, (lr, b) in enumerate(zip(lrs, budgets))]


def _hook():
    return InFlightSuccessiveHalving(eta=2.0, min_iter=2, max_iter=8)


# NOTE: the pairwise chunked-vs-per-step equivalence checks (batch flights
# with rung boundaries, streaming refill, sharded streaming) moved into the
# cross-engine matrix — tests/test_engine_matrix.py — which covers
# {vmapped, sharded} x {per-step, chunked} x {host-rule, device-rule} against
# one shared workload (tests/harness.py).  This module keeps the chunk
# machinery's own contracts: device batch synthesis, scan-vs-loop equality,
# divergence retirement, pow2 decomposition, clone ops, cache hygiene.


def test_streaming_divergent_lane_retires_under_chunking():
    """A diverged lane is noticed at a chunk-granular poll, retired with the
    sentinel score, and its lane refills — same scores as per-step."""
    cfgs = _ladder(4)
    cfgs[1]["learning_rate"] = 1e9  # diverges at its first step
    cfgs[1]["grad_clip"] = 0.0
    outs = {}
    for chunk in (1, 8):
        t = PopulationTrial(ARCH, steps=4, batch=BATCH, seq=SEQ, seed=0,
                            population=2, refill_idle_grace_s=0.0,
                            chunk_steps=chunk)
        feed = QueueFeedScheduler(cfgs)
        t.run_population([], scheduler=feed)
        outs[chunk] = (feed.ordered_scores(len(cfgs)),
                       [feed.extras[i]["diverged"] for i in range(len(cfgs))])
    assert outs[1] == outs[8]
    assert outs[8][0][1] == PopulationTrial.DIVERGED_SCORE
    assert outs[8][1][1] is True


def test_streaming_pbt_chunked_matches_per_step():
    """PBT rounds are host-known events: the chunked streaming engine makes
    the same keep/clone decisions and scores as the per-step engine."""
    from repro.launch import hpo

    def run(chunk):
        argv = ["--proposer", "pbt", "--vectorize", "4", "--pbt-streaming",
                "--n-samples", "8", "--steps", "2", "--batch", "2",
                "--seq", "16", "--per-trial-init",
                "--chunk-steps", str(chunk)]
        import io
        from contextlib import redirect_stdout
        buf = io.StringIO()
        with redirect_stdout(buf):
            assert hpo.main(argv) == 0
        import json
        return json.loads(buf.getvalue())

    a, b = run(1), run(8)
    assert a["best_score"] == b["best_score"]
    assert a["pbt_clones"] == b["pbt_clones"]
    assert a["pbt_keeps"] == b["pbt_keeps"]
    assert b["dispatches_per_step"] < 1.0


# -- chunk-size decomposition -----------------------------------------------------


def test_pow2_floor_chunk_decomposition():
    assert [_pow2_floor(n) for n in (1, 2, 3, 4, 5, 7, 8, 9, 100)] == \
        [1, 2, 2, 4, 4, 4, 8, 8, 64]
    # greedy decomposition of a gap covers it exactly, never overshooting
    for gap in (1, 3, 5, 7, 11, 13):
        s, sizes = 0, []
        while s < gap:
            t = _pow2_floor(min(gap - s, 8))
            sizes.append(t)
            s += t
        assert s == gap and all(x <= 8 for x in sizes)


# -- point-to-point sharded clone -------------------------------------------------


@multi_device
def test_ppermute_clone_matches_vmapped_clone_all_donor_pairs(tc):
    """The ring-ppermute donor transfer is bit-equal to the vmapped clone for
    every (target, donor) pair, including donors crossing mesh boundaries."""
    mesh = population_mesh()
    k = pop.pad_population(jax.device_count(), mesh)
    vclone = pop.make_lane_clone(tc)
    sclone = pop.get_compiled_lane_op(tc, k, "clone", mesh=mesh)
    base = pop.init_population_state_from_keys(_keys(k), tc)
    for target, donor in [(0, k - 1), (k - 1, 0), (1, 2), (3, 3),
                          (k // 2, k // 2 - 1)]:
        mask = np.zeros(k, bool)
        mask[target] = True
        didx = np.arange(k)
        didx[target] = donor
        want = vclone(base, jnp.asarray(mask), jnp.asarray(didx, jnp.int32))
        got = sclone(
            pop.shard_population_state(
                pop.init_population_state_from_keys(_keys(k), tc), mesh),
            jnp.asarray(mask), jnp.asarray(didx, jnp.int32))
        assert _tree_equal(want, got), (target, donor)


# -- compile-leak guard -----------------------------------------------------------


def test_chunked_runs_do_not_grow_compile_cache():
    """clear_population_cache() covers the scan programs, and repeated chunked
    runs reuse them instead of compiling fresh entries."""
    pop.clear_population_cache()
    assert len(pop._POP_CACHE) == 0
    cfgs = _ladder(4)

    def run():
        t = PopulationTrial(ARCH, steps=2, batch=BATCH, seq=SEQ, seed=0,
                            population=2, early_stop=_hook(),
                            refill_idle_grace_s=0.0, chunk_steps=8)
        feed = QueueFeedScheduler(cfgs)
        t.run_population([], scheduler=feed)

    run()
    n_first = len(pop._POP_CACHE)
    assert n_first > 0
    for _ in range(2):
        run()
    assert len(pop._POP_CACHE) == n_first, \
        "repeated chunked flights must not leak compile-cache entries"
    pop.clear_population_cache()
    assert len(pop._POP_CACHE) == 0


def test_chunk_steps_smoke_cli():
    """The CI smoke entry (`REPRO_CHUNK_SMOKE=1`) runs the heavier CLI with
    --lane-refill --chunk-steps 8; locally a lighter variant stays always-on."""
    import os

    from repro.launch.hpo import main

    heavy = os.environ.get("REPRO_CHUNK_SMOKE") == "1"
    argv = ["--proposer", "asha", "--vectorize", "4", "--inflight-stop",
            "--lane-refill", "--chunk-steps", "8",
            "--n-samples", "6" if heavy else "4",
            "--steps", "8" if heavy else "4", "--batch", "2", "--seq", "16"]
    assert main(argv) == 0


def test_pbt_decision_lag_telemetry_gated_is_zero():
    """Gated rounds decide round r strictly from round r-1 results: every
    decision-lag sample is 0.  (The bench's pbt_async_quality row relies on
    this baseline.)"""
    from repro.core.proposer import make_proposer
    from repro.core.search_space import SearchSpace

    space = SearchSpace.from_json([
        {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-2],
         "scale": "log"}])
    prop = make_proposer("pbt", space, maximize=True, seed=0, population=3,
                         n_generations=3, streaming=True, sync_rounds=True)
    trial = PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                            population=3, per_trial_init=True)
    exp = Experiment({
        "proposer": "pbt", "parameter_config": [
            {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-2],
             "scale": "log"}],
        "n_samples": 9, "n_parallel": 3, "target": "max", "seed": 0,
        "population": 3, "n_generations": 3, "streaming": True,
        "sync_rounds": True, "resource": "vectorized", "lane_refill": True},
        trial)
    exp.run()
    hook = exp.proposer.lifecycle_hook()
    assert len(hook.decision_lags) > 0
    assert set(hook.decision_lags) == {0}
