"""Device-resident prefetch ring (``repro.data.ring``) and its host adapters.

Unit level: the ``HostDataset`` adapters (synth oracle + array-backed), the
serial ``HostPrefetcher``, the ring's fill/consume fence protocol, and the
ring scan's bit-equality against the in-scan-synth engine — vmapped and
sharded.  The driver-level equivalences (full flights through ``--data-ring``)
live in ``test_engine_matrix.py`` and ``test_crash_safety.py``.
"""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import (
    ArrayHostDataset,
    HostPrefetcher,
    SynthHostDataset,
    SyntheticLM,
    synth_population_batch,
    split_streams,
)
from repro.data.ring import PrefetchRing
from repro.optim.hparams import hparams_from_dict, stack_hparams
from repro.train.population import (
    init_population_state,
    make_population_ring_scan_step,
    make_population_scan_step,
)

SEQ, BATCH, K = 16, 2, 4


def _spec():
    return SyntheticLM(vocab_size=256, seq_len=SEQ, global_batch=BATCH)


def _tc():
    cfg = get_smoke_config("starcoder2-3b")
    return TrainConfig(model=cfg, total_steps=8)


# -- host adapters ---------------------------------------------------------------


def test_synth_host_dataset_matches_in_scan_synthesis():
    """The bit-equality oracle: ``SynthHostDataset.lane_block`` under NumPy
    must produce exactly the token slab ``synth_population_batch`` computes
    under XLA for the same (stream, step) coordinates."""
    spec = _spec()
    ds = SynthHostDataset(spec)
    streams = [3, 11, -5, 7]
    steps = [0, 4, 9, 2]
    block = ds.lane_block(streams, steps)
    assert block.shape == (4, BATCH, SEQ + 1)
    assert block.dtype == np.int32
    lo, hi = split_streams(streams)
    want = synth_population_batch(
        spec, jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(steps, jnp.int32), xp=jnp)
    np.testing.assert_array_equal(block[:, :, :-1],
                                  np.asarray(want["tokens"]))
    np.testing.assert_array_equal(block[:, :, 1:],
                                  np.asarray(want["targets"]))


def test_array_host_dataset_reads_consecutive_rows():
    n, stride = 64, 997
    toks = np.arange(n * (SEQ + 1), dtype=np.int32).reshape(n, SEQ + 1)
    ds = ArrayHostDataset(toks, global_batch=BATCH)
    block = ds.lane_block([0, 1], [0, 2])
    assert block.shape == (2, BATCH, SEQ + 1)
    np.testing.assert_array_equal(block[0], toks[:BATCH])
    start = (stride + 2 * BATCH) % n
    np.testing.assert_array_equal(
        block[1], toks[(start + np.arange(BATCH)) % n])


@pytest.mark.parametrize("make_ds", [
    lambda: SynthHostDataset(_spec()),
    lambda: ArrayHostDataset(
        np.arange(64 * (SEQ + 1), dtype=np.int32).reshape(64, SEQ + 1),
        global_batch=BATCH),
], ids=["synth", "array"])
def test_lane_window_bit_equals_stacked_lane_blocks(make_ds):
    """The ring's fill thread prefers the one-call vectorized window build;
    it must produce exactly the bytes of n stacked ``lane_block`` calls."""
    ds = make_ds()
    streams = [3, 11, -5, 7]
    steps = [0, 4, 9, 2]
    n = 5
    got = ds.lane_window(streams, np.asarray(steps, np.int64), n)
    want = np.stack([
        ds.lane_block(streams, [s + t for s in steps]) for t in range(n)])
    assert got.shape == (n, len(streams), BATCH, SEQ + 1)
    np.testing.assert_array_equal(got, want)


def test_array_host_dataset_wraps_around():
    n = 5  # not a multiple of the batch: forces wraparound reads
    toks = np.arange(n * (SEQ + 1), dtype=np.int32).reshape(n, SEQ + 1)
    ds = ArrayHostDataset(toks, global_batch=BATCH)
    for step in range(7):
        block = ds.lane_block([0], [step])
        start = (step * BATCH) % n
        np.testing.assert_array_equal(
            block[0], toks[(start + np.arange(BATCH)) % n])


# -- serial prefetcher -----------------------------------------------------------


def test_host_prefetcher_returns_identical_batches():
    spec = _spec()
    feed = HostPrefetcher(lambda s: spec.make_batch(s, stream=5))
    for s in range(6):
        got = feed.pop(s)
        if s + 1 < 6:
            feed.prefetch(s + 1)
        want = spec.make_batch(s, stream=5)
        for key in want:
            np.testing.assert_array_equal(np.asarray(got[key]),
                                          np.asarray(want[key]))


def test_host_prefetcher_tolerates_step_mismatch():
    spec = _spec()
    feed = HostPrefetcher(lambda s: spec.make_batch(s, stream=5))
    feed.prefetch(3)  # staged for the wrong step
    got = feed.pop(7)
    want = spec.make_batch(7, stream=5)
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(want["tokens"]))


# -- ring fence protocol ---------------------------------------------------------


def test_ring_fills_ahead_and_blocks_at_capacity():
    spec = _spec()
    ring = PrefetchRing(SynthHostDataset(spec), population=K, win_steps=4,
                        windows=2)
    try:
        ring.set_lanes(list(range(K)), [0] * K, at_step=0)
        assert ring.wait_filled(0, 8) == 8  # both windows fill unprompted
        deadline = time.time() + 2.0
        while ring.n_fills < 2 and time.time() < deadline:
            time.sleep(0.01)
        assert ring.n_fills == 2, "filler must stop at capacity, not spin"
        ring.consume_to(4)  # frees one window
        assert ring.wait_filled(4, 8) == 8
    finally:
        ring.stop()


def test_ring_set_lanes_invalidates_prefetched_windows():
    spec = _spec()
    ring = PrefetchRing(SynthHostDataset(spec), population=K, win_steps=2,
                        windows=2)
    try:
        ring.set_lanes(list(range(K)), [0] * K, at_step=0)
        ring.wait_filled(0, 4)
        assert ring.n_invalidations == 0
        ring.set_lanes(list(range(K, 2 * K)), [3] * K, at_step=2)
        assert ring.n_invalidations == 1
        ring.wait_filled(2, 2)
        with ring.reserve() as slots:
            got = np.asarray(slots)[2 % ring.capacity]
        want = SynthHostDataset(spec).lane_block(
            list(range(K, 2 * K)), [3 + 2] * K)
        np.testing.assert_array_equal(got, want)
    finally:
        ring.stop()


def test_ring_set_lanes_same_table_keeps_prefetch():
    """Re-keying with an UNCHANGED lane table (hp-only event boundaries)
    must be a no-op: no invalidation, prefetched windows kept."""
    spec = _spec()
    ring = PrefetchRing(SynthHostDataset(spec), population=K, win_steps=2,
                        windows=2)
    try:
        streams = list(range(K))
        ring.set_lanes(streams, [0] * K, at_step=0)
        ring.wait_filled(0, 4)
        fills = ring.n_fills
        ring.set_lanes(streams, [0] * K, at_step=2)
        assert ring.n_invalidations == 0
        assert ring.wait_filled(2, 2) >= 2  # still filled, no refill wait
        assert ring.n_fills == fills
    finally:
        ring.stop()


def test_ring_stop_unblocks_waiters():
    spec = _spec()
    ring = PrefetchRing(SynthHostDataset(spec), population=K, win_steps=2,
                        windows=2)
    errs = []

    def waiter():
        try:
            ring.wait_filled(10_000)  # lanes never set: would block forever
        except RuntimeError as e:
            errs.append(e)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    ring.stop()
    t.join(timeout=5.0)
    assert not t.is_alive() and len(errs) == 1


def test_ring_fill_errors_propagate_to_consumer():
    class Broken:
        seq_len, global_batch = SEQ, BATCH

        def lane_block(self, streams, steps):
            raise ValueError("boom")

    ring = PrefetchRing(Broken(), population=K, win_steps=2, windows=2)
    try:
        ring.set_lanes(list(range(K)), [0] * K, at_step=0)
        with pytest.raises(RuntimeError, match="ring fill failed"):
            ring.wait_filled(0)
    finally:
        ring.stop()


def test_ring_overlap_frac_bounds():
    spec = _spec()
    ring = PrefetchRing(SynthHostDataset(spec), population=K, win_steps=2,
                        windows=2)
    try:
        assert ring.overlap_frac == 1.0  # no fills yet
        ring.set_lanes(list(range(K)), [0] * K, at_step=0)
        ring.wait_filled(0, 4)
        assert 0.0 <= ring.overlap_frac <= 1.0
    finally:
        ring.stop()


# -- ring scan vs in-scan synthesis ----------------------------------------------


def _population(tc, k):
    pstate = init_population_state(jax.random.PRNGKey(0), tc, k)
    hp = stack_hparams([hparams_from_dict(
        {"learning_rate": 1e-3, "n_iterations": 8}, tc)] * k)
    return pstate, hp


def test_ring_scan_bit_equals_in_scan_synth_across_wraparound():
    """Two chunks through the ring — the second wraps the ring — must leave
    the population state bit-identical to the in-scan-synth fused scan."""
    tc = _tc()
    spec = SyntheticLM(vocab_size=tc.model.vocab_size, seq_len=SEQ,
                       global_batch=BATCH)
    chunk = 4
    streams = [2, 9, -3, 15]
    lo, hi = (jnp.asarray(w) for w in split_streams(streams))

    pstate_a, hp = _population(tc, K)
    scan = jax.jit(make_population_scan_step(tc, spec, chunk),
                   donate_argnums=0)
    for c in range(2):
        steps0 = jnp.full((K,), c * chunk, jnp.int32)
        pstate_a, _ = scan(pstate_a, hp, steps0, lo, hi)

    pstate_b, hp = _population(tc, K)
    ring = PrefetchRing(SynthHostDataset(spec), population=K,
                        win_steps=chunk, windows=2)
    try:
        ring.set_lanes(streams, [0] * K, at_step=0)
        rscan = jax.jit(
            make_population_ring_scan_step(tc, spec, chunk, ring.capacity),
            donate_argnums=0)
        for c in range(2):
            s = c * chunk
            ring.wait_filled(s, chunk)
            with ring.reserve() as slots:
                pstate_b, _ = rscan(pstate_b, hp, slots,
                                    jnp.asarray(s % ring.capacity, jnp.int32))
            ring.consume_to(s + chunk)
        assert ring.n_fills >= 2
    finally:
        ring.stop()

    for la, lb in zip(jax.tree_util.tree_leaves(pstate_a),
                      jax.tree_util.tree_leaves(pstate_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device (virtual CPU) mesh")
def test_sharded_ring_scan_matches_vmapped():
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.distributed.sharding import population_mesh
    from repro.train.population import (
        make_sharded_population_ring_scan_step, shard_population_state)

    tc = _tc()
    k = jax.device_count()
    spec = SyntheticLM(vocab_size=tc.model.vocab_size, seq_len=SEQ,
                       global_batch=BATCH)
    chunk = 4
    streams = list(range(1, k + 1))
    mesh = population_mesh()

    pstate_v, hp = _population(tc, k)
    ring = PrefetchRing(SynthHostDataset(spec), population=k,
                        win_steps=chunk, windows=2)
    try:
        ring.set_lanes(streams, [0] * k, at_step=0)
        rscan = jax.jit(
            make_population_ring_scan_step(tc, spec, chunk, ring.capacity),
            donate_argnums=0)
        ring.wait_filled(0, chunk)
        with ring.reserve() as slots:
            pstate_v, _ = rscan(pstate_v, hp, slots,
                                jnp.asarray(0, jnp.int32))
    finally:
        ring.stop()

    pstate_s, hp = _population(tc, k)
    pstate_s = shard_population_state(pstate_s, mesh)
    sharding = NamedSharding(mesh, PartitionSpec(None, "pop", None, None))
    ring = PrefetchRing(SynthHostDataset(spec), population=k,
                        win_steps=chunk, windows=2, sharding=sharding)
    try:
        ring.set_lanes(streams, [0] * k, at_step=0)
        sscan = jax.jit(
            make_sharded_population_ring_scan_step(
                tc, mesh, spec, chunk, ring.capacity),
            donate_argnums=0)
        ring.wait_filled(0, chunk)
        with ring.reserve() as slots:
            pstate_s, _ = sscan(pstate_s, hp, slots,
                                jnp.asarray(0, jnp.int32))
    finally:
        ring.stop()

    np.testing.assert_allclose(
        np.asarray(pstate_s["last_loss"], np.float32),
        np.asarray(pstate_v["last_loss"], np.float32), atol=1e-6, rtol=0)


def test_data_ring_smoke_cli():
    """The CI smoke entry (`REPRO_RING_SMOKE=1`) runs the heavier CLI with
    --lane-refill --chunk-steps 8 --data-ring; locally a lighter variant
    stays always-on."""
    import os

    from repro.launch.hpo import main

    heavy = os.environ.get("REPRO_RING_SMOKE") == "1"
    argv = ["--proposer", "asha", "--vectorize", "4", "--inflight-stop",
            "--lane-refill", "--chunk-steps", "8", "--data-ring",
            "--n-samples", "6" if heavy else "4",
            "--steps", "8" if heavy else "4", "--batch", "2", "--seq", "16"]
    assert main(argv) == 0
