"""Property tests for the logical-axis sharding layer (hypothesis) and the
mesh-slice resource pool."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec

import jax

from repro.core.resource.mesh_pool import MeshSlice, tile_pod
from repro.distributed.sharding import build_pspec, make_rules

# the container has 1 real device; build a fake mesh over a device array of
# labels for pspec math (Mesh only needs .shape through our code path)


class _FakeMesh:
    def __init__(self, shape_map):
        self.shape = shape_map


RULES = make_rules(("data", "model"))
MESH = _FakeMesh({"data": 16, "model": 16})

LOGICAL = ["batch", "embed", "vocab", "heads", "kv_heads", "ff", "expert",
           "act_seq", "act_seq_attn", "act_embed", None]


@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 8, 16, 24, 32, 128, 256, 4096]),
                  min_size=1, max_size=5),
    names=st.lists(st.sampled_from(LOGICAL), min_size=1, max_size=5),
)
@settings(max_examples=200, deadline=None)
def test_build_pspec_legality(dims, names):
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    spec = build_pspec(dims, names, RULES, MESH)
    used = []
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            assert a not in used, "mesh axis used twice in one tensor"
            used.append(a)
            prod *= MESH.shape[a]
        assert dim % prod == 0, "sharded dim must divide evenly"


def test_heads_take_priority_over_seq():
    # divisible heads: heads get the model axis, seq stays replicated
    spec = build_pspec((32, 4096, 16, 128),
                       ("batch", "act_seq_attn", "heads", None), RULES, MESH)
    assert spec == PartitionSpec("data", None, "model", None)
    # non-divisible heads (starcoder2's 24): Ulysses fallback — seq gets model
    spec = build_pspec((32, 4096, 24, 128),
                       ("batch", "act_seq_attn", "heads", None), RULES, MESH)
    assert spec == PartitionSpec("data", "model", None, None)


def test_fsdp_weight_spec():
    spec = build_pspec((3072, 24, 128), ("embed", "heads", "head"), RULES, MESH)
    assert spec == PartitionSpec("data", None, None)  # 24 heads can't shard
    spec = build_pspec((3072, 12288), ("embed", "ff"), RULES, MESH)
    assert spec == PartitionSpec("data", "model")


def test_multipod_rules_fold_pod_axis():
    rules = make_rules(("pod", "data", "model"))
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = build_pspec((256, 4096), ("batch", None), rules, mesh)
    assert spec == PartitionSpec(("pod", "data"), None)
    # FSDP params also fold pod in
    spec = build_pspec((8192, 24576), ("embed", "ff"), rules, mesh)
    assert spec == PartitionSpec(("pod", "data"), "model")


# ------------------------------------------------------------------ mesh slices
@given(
    pr=st.sampled_from([1, 2, 4, 8, 16]),
    pc=st.sampled_from([1, 2, 4, 8, 16]),
    sr=st.sampled_from([1, 2, 4]),
    sc=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=100, deadline=None)
def test_tile_pod_partitions_exactly(pr, pc, sr, sc):
    if pr % sr or pc % sc:
        with pytest.raises(ValueError):
            tile_pod((pr, pc), (sr, sc), virtual=True)
        return
    slices = tile_pod((pr, pc), (sr, sc), virtual=True)
    assert len(slices) == (pr // sr) * (pc // sc)
    seen = set()
    for s in slices:
        assert len(s.devices) == sr * sc
        for d in s.devices:
            assert d not in seen, "chip assigned to two slices"
            seen.add(d)
    assert len(seen) == pr * pc, "every chip assigned"


def test_real_device_slice_builds_mesh():
    slices = tile_pod((1, 1), (1, 1), devices=jax.devices())
    m = slices[0].mesh(("data", "model"))
    assert isinstance(m, Mesh)
    assert m.size == 1
