"""Mesh-sharded population engine, per-trial data streams, in-flight stops.

Covers the distributed half of the population-engine story: the K-trial
population axis splits over a device mesh (``shard_map``) with scores equal
to the single-device vmapped engine; every trial consumes an independent
seeded data stream that matches the serial driver trial-for-trial; and the
ASHA/Hyperband rung rule truncates losing lanes' budgets mid-flight so a
flight ends as soon as the survivors finish.

conftest.py forces an 8-virtual-device CPU mesh (``XLA_FLAGS``); tests that
need real sharding skip on a single-device backend.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.core.experiment import Experiment
from repro.core.proposer import make_proposer
from repro.core.proposer.early_stop import InFlightSuccessiveHalving
from repro.core.resource.sharded import ShardedPopulationResourceManager
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import population_mesh
from repro.launch.hpo import PopulationTrial
from repro.optim.hparams import hparams_from_dict, stack_hparams
from repro.train import population as pop

SEQ, BATCH, STEPS = 16, 2, 4

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a multi-device (virtual CPU) mesh"
)


@pytest.fixture(scope="module")
def tc():
    cfg = get_smoke_config("starcoder2-3b")
    return TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                       total_steps=STEPS)


def _cfgs(n):
    rng = np.random.default_rng(1)
    return [
        {"learning_rate": float(lr), "weight_decay": float(rng.uniform(0, 0.2)),
         "stream": i}
        for i, lr in enumerate(np.geomspace(1e-4, 1e-2, n))
    ]


# -- sharded engine ---------------------------------------------------------------

@multi_device
def test_sharded_matches_vmapped(tc):
    """K trials over N devices score identically to K trials on one device."""
    n = jax.device_count()
    trial = PopulationTrial("starcoder2-3b", steps=STEPS, batch=BATCH, seq=SEQ,
                            seed=0, population=n)
    cfgs = _cfgs(n)
    vmapped = trial.run_population(cfgs)
    sharded = trial.run_population(cfgs, mesh=population_mesh())
    np.testing.assert_allclose(sharded, vmapped, rtol=1e-5, atol=1e-6)
    assert np.isfinite(vmapped).all() and (np.asarray(vmapped) > -1e8).all()


@multi_device
def test_sharded_partial_batch_pads_to_mesh(tc):
    """A batch smaller than the mesh pads with 0-budget lanes, scores intact."""
    n = jax.device_count()
    trial = PopulationTrial("starcoder2-3b", steps=STEPS, batch=BATCH, seq=SEQ,
                            seed=0, population=n)
    cfgs = _cfgs(n)
    full = trial.run_population(cfgs, mesh=population_mesh())
    part = trial.run_population(cfgs[: n - 1], mesh=population_mesh())
    np.testing.assert_allclose(part, full[: n - 1], rtol=1e-5, atol=1e-6)


@multi_device
def test_sharded_step_rejects_indivisible_population(tc):
    mesh = population_mesh()
    k = mesh.size + 1
    with pytest.raises(ValueError, match="does not divide"):
        pop.get_compiled_sharded_population_step(tc, k, mesh=mesh)
    assert pop.pad_population(k, mesh) == 2 * mesh.size
    assert pop.pad_population(mesh.size, mesh) == mesh.size


# -- per-trial data streams -------------------------------------------------------

def test_stream_zero_is_legacy_shared_stream():
    d = SyntheticLM(64, SEQ, BATCH, seed=3)
    np.testing.assert_array_equal(
        d.make_batch(5)["tokens"], d.make_batch(5, stream=0)["tokens"]
    )


def test_streams_are_independent_and_deterministic():
    d = SyntheticLM(64, SEQ, BATCH, seed=3)
    a, b = d.make_batch(5, stream=1), d.make_batch(5, stream=2)
    assert not np.array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(
        a["tokens"], d.make_batch(5, stream=1)["tokens"]
    )
    pb = d.make_population_batch(5, streams=[0, 1, 2])
    assert pb["tokens"].shape == (3, BATCH, SEQ)
    np.testing.assert_array_equal(pb["tokens"][1], a["tokens"])


def test_per_trial_streams_match_serial_trial_for_trial(tc):
    """Serial driver and vmapped population consume identical per-trial data."""
    cfgs = _cfgs(3)
    trial = PopulationTrial("starcoder2-3b", steps=STEPS, batch=BATCH, seq=SEQ,
                            seed=0, population=3)
    serial = [trial(c) for c in cfgs]
    vec = trial.run_population(cfgs)
    np.testing.assert_allclose(vec, serial, rtol=1e-5, atol=1e-6)


def test_same_hparams_distinct_streams_distinct_scores(tc):
    cfgs = [{"learning_rate": 1e-3, "stream": 0}, {"learning_rate": 1e-3, "stream": 9}]
    trial = PopulationTrial("starcoder2-3b", steps=STEPS, batch=BATCH, seq=SEQ,
                            seed=0, population=2)
    s = trial.run_population(cfgs)
    assert s[0] != s[1], "independent streams must yield distinct trajectories"
    shared = PopulationTrial("starcoder2-3b", steps=STEPS, batch=BATCH, seq=SEQ,
                             seed=0, population=2, per_trial_streams=False)
    s = shared.run_population(cfgs)
    assert s[0] == s[1], "--shared-stream mode: identical hparams, identical data"


# -- in-flight early stopping -----------------------------------------------------

def test_inflight_hook_truncates_losers_at_boundary():
    hook = InFlightSuccessiveHalving(eta=2.0, min_iter=2, max_iter=8)
    assert hook.boundaries == [2, 4]
    budgets = np.array([8.0, 8.0, 8.0, 8.0])
    losses = np.array([1.0, 3.0, 2.0, 4.0])
    out = hook(2, losses, budgets, np.zeros(4, bool))
    # keep ceil(4/2)=2 best (lanes 0, 2); truncate lanes 1, 3 to step 2
    assert out.tolist() == [8.0, 2.0, 8.0, 2.0]
    assert hook.n_truncated == 2
    # non-boundary steps and already-stopped lanes are left alone
    assert hook(3, losses, out, np.zeros(4, bool)).tolist() == out.tolist()


def test_inflight_hook_ignores_padding_and_diverged():
    hook = InFlightSuccessiveHalving(eta=2.0, min_iter=2, max_iter=8)
    budgets = np.array([8.0, 8.0, 0.0, 8.0])  # lane 2 = padding
    losses = np.array([1.0, 2.0, np.inf, 3.0])
    diverged = np.array([False, False, False, True])
    out = hook(2, losses, budgets, diverged)
    # lane 2 (padding) untouched; lane 3's dead budget reclaimed (diverged);
    # of the two ranked lanes, only the best keeps its budget at eta=2
    assert out.tolist() == [8.0, 2.0, 0.0, 2.0]
    assert hook.n_truncated == 1 and hook.n_reclaimed == 1


def test_inflight_stop_frees_lanes_early(tc):
    """A losing long-budget lane is cut at the rung, ending the flight early."""
    k = 4
    trial = PopulationTrial("starcoder2-3b", steps=1, batch=BATCH, seq=SEQ,
                            seed=0, population=k,
                            early_stop=InFlightSuccessiveHalving(
                                eta=2.0, min_iter=2, max_iter=8))
    # three short rung-0 lanes with sane lrs + one 8-step lane with an lr so
    # hot it diverges before the step-2 boundary: the rule reclaims its dead
    # budget there (loss ordering at 2 warmup-scaled steps is stream noise,
    # so a merely-bad finite lr cannot be cut reliably at this geometry)
    cfgs = [dict(c, n_iterations=2) for c in _cfgs(3)]
    cfgs.append({"learning_rate": 1e9, "grad_clip": 0.0, "stream": 3,
                 "n_iterations": 8})
    scores = trial.run_population(cfgs)
    # the bad lane is cut by the rung rule, or reclaimed if it diverged first
    assert trial.early_stop.n_truncated + trial.early_stop.n_reclaimed >= 1
    assert trial.last_flight_steps < 8, "flight must end before the full budget"
    assert all(s > -1e8 for s in scores[:3]), "healthy lanes still report scores"


def test_asha_inflight_experiment_end_to_end():
    """Vectorized ASHA with mid-flight stops: all jobs accounted, lanes reused."""
    prop_space = [
        {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-2], "scale": "log"},
    ]
    trial = PopulationTrial("starcoder2-3b", steps=1, batch=BATCH, seq=SEQ,
                            seed=0, population=4)
    exp = Experiment(
        {"proposer": "asha", "parameter_config": prop_space, "n_samples": 6,
         "n_parallel": 4, "target": "max", "random_seed": 0, "max_iter": 8,
         "min_iter": 2, "eta": 2.0, "resource": "vectorized"},
        trial,
    )
    trial.early_stop = exp.proposer.inflight_hook(steps_per_unit=1)
    best = exp.run()
    assert best is not None and best["score"] > -1e8
    assert exp.proposer.finished()
    assert exp.rm.n_batches >= 2, "freed lanes must take follow-up batches"


# -- sharded resource manager -----------------------------------------------------

@multi_device
def test_sharded_rm_mesh_aware_slots_and_flush():
    n_dev = jax.device_count()
    rm = ShardedPopulationResourceManager(n_parallel=n_dev + 1)
    assert rm.n_slots % n_dev == 0 and rm.n_slots >= n_dev + 1
    assert rm.mesh.size == n_dev
    # resource ids name the device slice and the lane on it
    res = rm.get_available()
    assert "slice[" in str(res) and "/lane" in str(res)

    seen = {}

    class Target:
        def run_population(self, configs, mesh=None):
            seen["mesh"] = mesh
            return [1.0] * len(configs)

    from repro.core.job import Job

    done = []
    jobs = [Job(i, {"x": i}, None, done.append) for i in range(2)]
    for j in jobs:
        j.resource_id = rm.get_available()
        rm.run(j, Target())
    rm.release(rm.get_available())  # partial-batch flush signal
    for j in jobs:
        j.wait(5.0)
    assert seen["mesh"] is rm.mesh
    assert all(j.result.score == 1.0 for j in jobs)


@multi_device
def test_sharded_experiment_end_to_end():
    trial = PopulationTrial("starcoder2-3b", steps=1, batch=BATCH, seq=SEQ,
                            seed=0, population=jax.device_count())
    exp = Experiment(
        {"proposer": "random", "parameter_config": [
            {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-2],
             "scale": "log"}],
         "n_samples": 5, "n_parallel": jax.device_count(), "target": "max",
         "random_seed": 0, "resource": "sharded"},
        trial,
    )
    best = exp.run()
    assert best is not None and best["score"] > -1e8
