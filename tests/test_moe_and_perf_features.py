"""Perf-feature correctness: shard_map MoE path, grouped dispatch, ZeRO-1
sharding trees, and the capacity/drop semantics."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.distributed.sharding import build_sharding, make_rules, sharding_context
from repro.models import moe as M
from repro.models import transformer as T
from repro.train.train_step import init_train_state, train_state_specs


def _setup(arch="qwen3-moe-30b-a3b", capacity=100.0):
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=capacity)
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
    return cfg, p, x


def test_shard_map_moe_matches_plain():
    cfg, p, x = _setup()
    y0, a0 = M.moe_apply(p, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh, make_rules(("data", "model"))):
        y1, a1 = jax.jit(lambda p, x: M.moe_apply(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=3e-5)
    np.testing.assert_allclose(float(a1), float(a0), atol=1e-5)


def test_shard_map_moe_grads_match_plain():
    cfg, p, x = _setup()
    g0 = jax.grad(lambda x: (M.moe_apply(p, x, cfg)[0] ** 2).sum())(x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh, make_rules(("data", "model"))):
        g1 = jax.jit(jax.grad(lambda x: (M.moe_apply(p, x, cfg)[0] ** 2).sum()))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0), atol=3e-4, rtol=3e-4)


def test_grouped_dispatch_matches_global():
    cfg, p, x = _setup()
    y0, a0 = M.moe_apply(p, x, cfg)
    yG, aG = M.moe_apply(p, x, dataclasses.replace(cfg, moe_groups=4))
    np.testing.assert_allclose(np.asarray(yG), np.asarray(y0), atol=3e-5)
    np.testing.assert_allclose(float(aG), float(a0), atol=1e-5)


def test_capacity_drops_tokens():
    """With a tiny capacity factor, some assignments must actually drop
    (outputs differ from the dropless path) — the Switch semantics."""
    cfg, p, x = _setup(capacity=0.25)
    y_cap, _ = M.moe_apply(p, x, cfg)
    y_free, _ = M.moe_apply(p, x, cfg, dropless=True)
    assert float(jnp.abs(y_cap - y_free).max()) > 1e-4


def test_dropless_ignores_groups_and_ctx():
    """Decode path (dropless) must stay exact regardless of grouping/ctx."""
    cfg, p, x = _setup()
    y0, _ = M.moe_apply(p, x, cfg, dropless=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh, make_rules(("data", "model"))):
        y1, _ = jax.jit(lambda: M.moe_apply(
            p, x, dataclasses.replace(cfg, moe_groups=4), dropless=True))()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=3e-5)


def test_shard_map_moe_skips_when_experts_unshardable():
    """E=6 doesn't divide a 4-way model axis -> plain path, still correct."""
    cfg, p, x = _setup()
    cfg6 = dataclasses.replace(cfg, n_experts=6, moe_top_k=2)
    p6 = M.moe_init(jax.random.PRNGKey(0), cfg6)
    y0, _ = M.moe_apply(p6, x, cfg6)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:  # pretend the model axis is 4-way for the dispatch check
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 4}

    # the dispatch predicate itself
    assert cfg6.n_experts % FakeMesh.shape["model"] != 0
    with sharding_context(mesh, make_rules(("data", "model"))):
        y1, _ = jax.jit(lambda: M.moe_apply(p6, x, cfg6))()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=3e-5)


def test_zero1_vs_fsdp_sharding_trees():
    """ZeRO-1: params replicated over data axes, optimizer still sharded."""
    cfg = get_smoke_config("starcoder2-3b")
    tc = TrainConfig(model=cfg, parallel=ParallelConfig())
    state_shapes = jax.eval_shape(
        functools.partial(init_train_state, tc=tc), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    specs = train_state_specs(tc)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(("data", "model"))

    fsdp = build_sharding(state_shapes, specs, rules, mesh)
    rules_z1 = dict(rules, embed=())
    z1_params = build_sharding(state_shapes["params"], specs["params"], rules_z1, mesh)
    z1_opt = build_sharding(state_shapes["opt"], specs["opt"], rules, mesh)

    def specs_of(tree):
        return [s.spec for s in jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))]

    # on a 1x1 mesh everything is legal; the *intent* differs: zero1 params
    # must never reference the data axis
    for s in specs_of(z1_params):
        assert "data" not in jax.tree.leaves(tuple(s)), s
    # fsdp opt == zero1 opt (both data-sharded)
    assert specs_of(fsdp["opt"]) == specs_of(z1_opt)


def test_moe_arch_smoke_with_sharding_ctx():
    """Full MoE arch train step under a sharding context (shard_map engaged)."""
    from repro.train.train_step import make_train_step

    cfg = get_smoke_config("deepseek-v2-lite-16b")
    tc = TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"))
    state = init_train_state(jax.random.PRNGKey(0), tc)
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "targets": jnp.zeros((2, 16), jnp.int32),
        "mask": jnp.ones((2, 16), jnp.float32),
    }
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(("data", "model"))
    step = make_train_step(tc)

    def fn(state, batch):
        with sharding_context(mesh, rules):
            return step(state, batch)

    state, m = jax.jit(fn)(state, batch)
    assert np.isfinite(float(m["loss"]))
