"""Continuous lane-refill engine + the PR's bugfix sweep.

Covers the streaming half of the population-engine story: a retired lane
(budget exhausted, rung-truncated, diverged) is reset *inside* the compiled
program (``make_reset_lanes``) and immediately leases the next proposal,
with its result streamed out the moment the lane retires instead of at
flight end.  Plus regression tests for the satellite fixes: per-trial init
seeds, the serial fallback-stream collision, sentinel padding streams, and
the vectorized manager's flush blast radius / double-flush races.

conftest.py forces an 8-virtual-device CPU mesh; tests that need real
sharding skip on a single-device backend.
"""
import os
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.core.experiment import Experiment
from repro.core.job import Job, JobStatus
from repro.core.proposer.early_stop import InFlightSuccessiveHalving
from repro.core.resource.vectorized import (
    LaneScheduler,
    QueueFeedScheduler,
    VectorizedResourceManager,
)
from repro.data.pipeline import SyntheticLM
from repro.launch.hpo import PopulationTrial
from repro.optim.hparams import hparams_from_dict, stack_hparams
from repro.train import population as pop

SEQ, BATCH, STEPS = 16, 2, 4
ARCH = "starcoder2-3b"

@pytest.fixture(scope="module")
def tc():
    cfg = get_smoke_config(ARCH)
    return TrainConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                       total_steps=STEPS)


# the shared streaming-feed adapter (fixed queue, flight ends when drained)
FeedScheduler = QueueFeedScheduler


def _cfgs(n, budgets=None):
    rng = np.random.default_rng(1)
    out = [
        {"learning_rate": float(lr), "weight_decay": float(rng.uniform(0, 0.2)),
         "stream": i}
        for i, lr in enumerate(np.geomspace(1e-4, 1e-2, n))
    ]
    if budgets is not None:
        for c, b in zip(out, budgets):
            c["n_iterations"] = b
    return out


# -- the traced reset op ----------------------------------------------------------

def test_reset_lanes_reinitializes_masked_lanes_only(tc):
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(0), jnp.arange(2, dtype=jnp.uint32))
    pstate = pop.init_population_state_from_keys(keys, tc)
    fresh = pop.init_population_state_from_keys(keys, tc)
    step = pop.make_population_train_step(tc, per_trial_batch=False)
    data = SyntheticLM(tc.model.vocab_size, SEQ, BATCH, seed=0)
    hp = stack_hparams([hparams_from_dict({"learning_rate": 1e-3,
                                           "total_steps": STEPS}, tc)] * 2)
    for s in range(2):
        pstate, _ = step(pstate, data.make_batch(s), hp)
    # lanes trained: both differ from fresh init now
    p0 = jax.tree.leaves(pstate["inner"]["params"])[0]
    f0 = jax.tree.leaves(fresh["inner"]["params"])[0]
    assert not np.array_equal(np.asarray(p0[0]), np.asarray(f0[0]))

    reset = pop.make_reset_lanes(tc)
    mask = jnp.array([False, True])
    out = reset(pstate, mask, keys)
    # lane 1 is bit-identical to a fresh from-keys init; lane 0 untouched
    for got, want in zip(jax.tree.leaves(out["inner"]), jax.tree.leaves(fresh["inner"])):
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    for got, kept in zip(jax.tree.leaves(out["inner"]), jax.tree.leaves(pstate["inner"])):
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(kept[0]))
    assert not bool(out["diverged"][1])
    assert np.isinf(np.asarray(out["last_loss"])[1])
    assert np.asarray(out["last_loss"])[0] == np.asarray(pstate["last_loss"])[0]


# -- streaming engine equivalence -------------------------------------------------

def test_refilled_lane_matches_fresh_flight_and_serial():
    """The headline refill contract: a config spliced into a *used* lane
    mid-flight scores bit-for-bit what it scores as an initial lane of a
    fresh flight (same stream, same init key), and matches the serial
    driver trial-for-trial."""
    cfgs = [
        {"learning_rate": 1e-3, "stream": 0, "n_iterations": 2},
        {"learning_rate": 2e-3, "stream": 1, "n_iterations": 4},
        {"learning_rate": 3e-3, "stream": 2, "n_iterations": 2},
    ]
    trial = PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                            population=2, refill_idle_grace_s=0.0,
                            per_trial_init=True)
    sch = FeedScheduler(cfgs)
    assert trial.run_population([], scheduler=sch) == []
    assert len(sch.scores) == 3
    assert trial.n_refills >= 1, "config 2 must have refilled a freed lane"
    # config 2 rode a refilled lane; rerun it as an initial lane of a fresh
    # flight — identical compiled program, identical init path => bit-equal
    fresh = trial.run_population([cfgs[2]])
    assert sch.scores[2] == fresh[0]
    # and the serial driver (same stream id + same folded init key) agrees
    serial = trial(dict(cfgs[2]))
    np.testing.assert_allclose(sch.scores[2], serial, rtol=1e-5, atol=1e-6)
    # streamed telemetry: per-job effective budgets ride in extra
    assert sch.extras[0]["steps"] == 2 and sch.extras[1]["steps"] == 4


# NOTE: streaming-vs-batch and sharded-vs-vmapped score equivalence moved
# into the cross-engine matrix (tests/test_engine_matrix.py), which runs one
# shared ladder workload through every engine cell — including the serial
# reference this module's headline refill test still checks directly.


def test_streaming_requires_per_trial_streams():
    trial = PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                            population=2, per_trial_streams=False)
    with pytest.raises(ValueError, match="per-trial data streams"):
        trial.run_population([], scheduler=FeedScheduler([]))
    with pytest.raises(ValueError, match="streaming mode"):
        PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                        population=2).run_population(
            [{"learning_rate": 1e-3}], scheduler=FeedScheduler([]))


def test_streaming_truncation_via_staggered_rung_rule():
    """A sick long-budget lane is freed mid-flight: either the rung rule cuts
    it against the history of better completers, or — at this geometry, where
    a couple of warmup-scaled steps cannot separate losses reliably — it
    diverges and its dead budget is reclaimed.  Either way the lane retires
    far short of its 8-step budget while the healthy lanes score normally."""
    hook = InFlightSuccessiveHalving(eta=2.0, min_iter=2, max_iter=8)
    cfgs = [dict(c, n_iterations=2) for c in _cfgs(3)]
    cfgs.append({"learning_rate": 1e9, "grad_clip": 0.0, "stream": 3,
                 "n_iterations": 8})
    trial = PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                            population=2, refill_idle_grace_s=0.0,
                            early_stop=hook)
    sch = FeedScheduler(cfgs)
    trial.run_population([], scheduler=sch)
    assert len(sch.scores) == 4
    # the bad lane was truncated by the rung rule or froze on divergence
    assert hook.n_truncated >= 1 or sch.extras[3]["diverged"]
    assert sch.extras[3]["steps"] < 8
    assert all(sch.scores[i] > -1e8 for i in range(3))


def test_observe_staggered_rung_history_rule():
    hook = InFlightSuccessiveHalving(eta=2.0, min_iter=2, max_iter=8)
    budgets = np.array([8.0, 8.0, 0.0, 8.0])
    # lane 0 at its rung-2 boundary with the best loss seen there: survives
    out = hook.observe([2, 1, 0, 3], [1.0, 2.0, np.inf, 3.0],
                       budgets, np.zeros(4, bool))
    assert out.tolist() == budgets.tolist() and hook.n_truncated == 0
    # lane 1 reaches the same rung later with a worse loss: cut to the rung
    out = hook.observe([3, 2, 0, 4], [1.0, 2.0, np.inf, 3.0],
                       out, np.zeros(4, bool))
    assert out.tolist() == [8.0, 2.0, 0.0, 8.0] and hook.n_truncated == 1
    # diverged and idle lanes are never ranked
    out2 = hook.observe([2, 2, 2, 2], [0.1, 0.2, 0.3, 0.4],
                        np.array([0.0, 8.0, 8.0, 8.0]),
                        np.array([False, True, False, False]))
    assert out2[0] == 0.0 and out2[1] == 8.0


# -- streaming through Algorithm 1 ------------------------------------------------

def test_streaming_experiment_with_asha_and_refill():
    trial = PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                            population=4)
    exp = Experiment(
        {"proposer": "asha", "parameter_config": [
            {"name": "learning_rate", "type": "float", "range": [1e-4, 1e-2],
             "scale": "log"}],
         "n_samples": 6, "n_parallel": 4, "target": "max", "random_seed": 0,
         "max_iter": 8, "min_iter": 2, "eta": 2.0,
         "resource": "vectorized", "lane_refill": True},
        trial,
    )
    trial.early_stop = exp.proposer.inflight_hook(steps_per_unit=1)
    settled = []
    exp.add_result_callback(lambda job: settled.append(job.job_id))
    best = exp.run()
    assert best is not None and best["score"] > -1e8
    assert exp.proposer.finished()
    assert exp.rm.n_streamed > 0, "results must stream out mid-flight"
    assert exp.rm.n_refill_flights >= 1
    assert len(settled) == len(set(settled)) >= 6, "every job settles exactly once"
    # every logged job reached a terminal state — nothing stranded in a lane
    assert all(j.done for j in exp.job_log)


def test_lane_refill_smoke_cli():
    """The CI smoke entry (`REPRO_LANE_REFILL_SMOKE=1`) runs the full CLI with
    --lane-refill; locally we keep a lighter always-on variant."""
    from repro.launch.hpo import main

    heavy = os.environ.get("REPRO_LANE_REFILL_SMOKE") == "1"
    argv = ["--proposer", "asha", "--vectorize", "4", "--inflight-stop",
            "--lane-refill", "--n-samples", "6" if heavy else "4",
            "--steps", "2", "--batch", "2", "--seq", "16"]
    assert main(argv) == 0


# -- LaneScheduler / manager races ------------------------------------------------

def _job(i, cb=lambda j: None):
    return Job(i, {"x": i}, None, cb)


def test_lane_scheduler_offer_lease_complete_close():
    sch = LaneScheduler()
    done = []
    jobs = [Job(i, {"x": i}, None, done.append) for i in range(4)]
    assert all(sch.offer(j) for j in jobs)
    jobs[1].fail("killed while buffered", status=JobStatus.KILLED)
    h0, c0 = sch.lease()
    h1, c1 = sch.lease()
    assert (c0["x"], c1["x"]) == (0, 2), "killed job is skipped at lease"
    assert jobs[0].status == JobStatus.RUNNING
    sch.complete(h0, 1.5, extra={"steps": 3})
    assert jobs[0].result.score == 1.5 and jobs[0].status == JobStatus.FINISHED
    sch.fail(h1, "lane diverged hard")
    assert jobs[2].status == JobStatus.FAILED
    leftovers, orphans = sch.close()
    assert [j.job_id for j in leftovers] == [3] and orphans == []
    assert not sch.offer(_job(9)), "closed scheduler refuses offers"
    assert sch.n_streamed == 1 and sch.n_leased == 2
    # double-complete of a finished handle is a no-op
    sch.complete(h0, 99.0)
    assert jobs[0].result.score == 1.5


def test_flush_race_stress_no_job_stranded_or_doubled():
    """Concurrent run()/release() hammering: every job settles exactly once
    with its own score — the atomic buffer claim means no double-flush and
    no stranded pending job."""
    n_jobs, n_slots = 24, 4
    rm = VectorizedResourceManager(n_parallel=n_slots)
    settled = []
    lock = threading.Lock()

    def on_done(job):
        with lock:
            settled.append(job.job_id)
        rm.release(job.resource_id)  # Algorithm 1 returns the slot

    def target(cfg):
        time.sleep(0.001)
        if cfg["x"] % 7 == 3:
            raise RuntimeError("boom")  # per-job blast radius
        return cfg["x"] * 2.0

    jobs = [Job(i, {"x": i}, None, on_done) for i in range(n_jobs)]
    queue = list(jobs)

    def producer():
        while True:
            with lock:
                if not queue:
                    return
                job = queue.pop(0)
            while True:
                res = rm.get_available()
                if res is not None:
                    break
                time.sleep(0.001)
            job.resource_id = res
            rm.run(job, target)

    threads = [threading.Thread(target=producer) for _ in range(3)]
    for t in threads:
        t.start()
    # the idle-release pump Algorithm 1 performs when the proposer is dry —
    # it is what flushes trailing partial batches
    deadline = time.time() + 30
    while time.time() < deadline and not all(j.done for j in jobs):
        res = rm.get_available()
        if res is not None:
            rm.release(res)
        time.sleep(0.002)
    for t in threads:
        t.join(timeout=10)
    assert all(j.done for j in jobs), "a job was stranded in the buffer"
    assert sorted(settled) == list(range(n_jobs)), "each job settles exactly once"
    for j in jobs:
        if j.job_id % 7 == 3:
            assert j.status == JobStatus.FAILED, "only the raising job fails"
        else:
            assert j.status == JobStatus.FINISHED
            assert j.result.score == j.job_id * 2.0


def test_streaming_flush_race_with_fake_engine():
    """run()/release() racing against a live streaming flight: offers splice
    into the flight, late offers seed a follow-up flight, all exactly-once."""

    class FakeStreamTarget:
        def run_population(self, configs, scheduler=None, mesh=None):
            assert configs == []
            idle = 0
            while idle < 40:
                lease = scheduler.lease()
                if lease is None:
                    if getattr(scheduler, "closed", False):
                        break
                    time.sleep(0.002)
                    idle += 1
                    continue
                idle = 0
                h, cfg = lease
                scheduler.complete(h, cfg["x"] * 3.0)
            return []

    n_jobs, n_slots = 30, 4
    rm = VectorizedResourceManager(n_parallel=n_slots, lane_refill=True)
    target = FakeStreamTarget()
    settled = []
    lock = threading.Lock()

    def on_done(job):
        with lock:
            settled.append(job.job_id)
        rm.release(job.resource_id)

    jobs = [Job(i, {"x": i}, None, on_done) for i in range(n_jobs)]
    queue = list(jobs)

    def producer():
        while True:
            with lock:
                if not queue:
                    return
                job = queue.pop(0)
            while True:
                res = rm.get_available()
                if res is not None:
                    break
                time.sleep(0.001)
            job.resource_id = res
            rm.run(job, target)

    threads = [threading.Thread(target=producer) for _ in range(3)]
    for t in threads:
        t.start()
    deadline = time.time() + 30
    while time.time() < deadline and not all(j.done for j in jobs):
        res = rm.get_available()
        if res is not None:
            rm.release(res)
        time.sleep(0.002)
    for t in threads:
        t.join(timeout=10)
    assert all(j.done for j in jobs)
    assert sorted(settled) == list(range(n_jobs))
    assert all(j.result.score == j.job_id * 3.0 for j in jobs)
    assert rm.n_streamed == n_jobs
    assert rm.n_refill_flights >= 1


def test_diverged_lane_reports_exact_applied_steps():
    """extra['steps'] is the device-side applied-step count, not the step at
    which the capped divergence poll happened to notice the freeze."""
    cfgs = [{"learning_rate": 1e6, "stream": 0, "n_iterations": 16}]
    trial = PopulationTrial(ARCH, steps=1, batch=BATCH, seq=SEQ, seed=0,
                            population=2, refill_idle_grace_s=0.0)
    sch = FeedScheduler(cfgs)
    trial.run_population([], scheduler=sch)
    assert sch.extras[0]["diverged"]
    assert sch.scores[0] <= -1e8
    # the lane exploded after 4 applied updates; the divergence *poll* only
    # fires at step 8 (DIVERGE_CHECK_EVERY) — reporting >= 8 would mean we
    # recorded poll time, not the device-side applied-step counter
    assert sch.extras[0]["steps"] < 8


def test_lane_refill_requires_streaming_capable_manager():
    with pytest.raises(ValueError, match="does not support streaming"):
        Experiment(
            {"proposer": "random", "parameter_config": [
                {"name": "x", "type": "float", "range": [0.0, 1.0]}],
             "n_samples": 1, "n_parallel": 1, "target": "max",
             "resource": "local", "lane_refill": True},
            lambda cfg: 0.0,
        )


def test_lane_refill_kwargs_target_falls_back_instead_of_livelocking():
    """A runner whose **kwargs swallow 'scheduler' without leasing must latch
    over to batch mode (zero-progress streaming flights must not loop)."""
    rm = VectorizedResourceManager(n_parallel=2, lane_refill=True)

    class KwargsBatchTarget:
        def run_population(self, configs, **kwargs):
            return [float(c["x"]) for c in configs]

    done = []
    jobs = [Job(i, {"x": i}, f"slot{i}", done.append) for i in range(2)]
    with pytest.warns(UserWarning, match="never leased"):
        for j in jobs:
            rm._busy[j.resource_id] = None
            rm.run(j, KwargsBatchTarget())
        for j in jobs:
            assert j.wait(10.0)
    assert all(j.status == JobStatus.FINISHED for j in jobs)
    assert [j.result.score for j in jobs] == [0.0, 1.0]
    assert rm._streaming_broken


def test_lane_refill_warns_on_batch_only_target():
    rm = VectorizedResourceManager(n_parallel=1, lane_refill=True)

    class BatchOnly:
        def run_population(self, configs):
            return [1.0] * len(configs)

    job = Job(0, {"x": 0}, "slot0", lambda j: None)
    rm._busy["slot0"] = None
    with pytest.warns(UserWarning, match="falling back"):
        rm.run(job, BatchOnly())
    assert job.wait(10.0) and job.result.score == 1.0


def test_streaming_flight_failure_blast_radius():
    """An engine that always dies is restarted under supervision; the lane
    leased across consecutive deaths is quarantined as the likely poison,
    and once the restart budget is exhausted the remaining leased/queued
    jobs fail with distinct reasons instead of hanging the experiment."""

    class DyingTarget:
        def run_population(self, configs, scheduler=None, mesh=None):
            scheduler.lease()  # takes one job, then the program explodes
            raise RuntimeError("XLA fell over")

    rm = VectorizedResourceManager(n_parallel=2, lane_refill=True,
                                   restart_backoff_s=0.001)
    done = []
    jobs = [Job(i, {"x": i}, f"slot{i}", done.append) for i in range(2)]
    for j in jobs:
        rm._busy[j.resource_id] = None  # claim as get_available would
        rm.run(j, DyingTarget())
    for j in jobs:
        assert j.wait(10.0)
    assert all(j.status == JobStatus.FAILED for j in jobs)
    # death 1: job0 leased -> requeued; death 2: job0 leased again ->
    # quarantined (2 consecutive deaths); death 3: job1 leased, restart
    # budget exhausted -> fails mid-lane
    assert "quarantined" in jobs[0].result.error
    assert jobs[0].quarantined
    assert "died mid-lane" in jobs[1].result.error
    assert rm.n_flight_deaths == 3
    assert rm.n_flight_restarts == 2
    assert rm.n_quarantined == 1


def test_streaming_flight_transient_death_recovers():
    """A flight that dies once is restarted and every job still completes."""

    class FlakyTarget:
        def __init__(self):
            self.calls = 0

        def run_population(self, configs, scheduler=None, mesh=None):
            self.calls += 1
            if self.calls == 1:
                scheduler.lease()
                raise RuntimeError("transient device loss")
            while True:
                leased = scheduler.lease()
                if leased is None:
                    break
                handle, cfg = leased
                scheduler.complete(handle, float(cfg["x"]))

    rm = VectorizedResourceManager(n_parallel=2, lane_refill=True,
                                   restart_backoff_s=0.001)
    done = []
    jobs = [Job(i, {"x": i}, f"slot{i}", done.append) for i in range(2)]
    tgt = FlakyTarget()
    for j in jobs:
        rm._busy[j.resource_id] = None
        rm.run(j, tgt)
    for j in jobs:
        assert j.wait(10.0)
    assert all(j.status == JobStatus.FINISHED for j in jobs)
    assert jobs[0].result.score == 0.0 and jobs[1].result.score == 1.0
    assert rm.n_flight_deaths == 1 and rm.n_flight_restarts == 1
    assert rm.n_quarantined == 0


# -- satellite bugfix regressions -------------------------------------------------

def test_streaming_anonymous_configs_get_distinct_streams():
    """Two anonymous configs refilled through the SAME lane must not share a
    data stream (the lane-index fallback would repeat across refills)."""
    trial = PopulationTrial(ARCH, steps=2, batch=BATCH, seq=SEQ, seed=0,
                            population=1, refill_idle_grace_s=0.0)
    sch = FeedScheduler([{"learning_rate": 1e-3}, {"learning_rate": 1e-3}])
    trial.run_population([], scheduler=sch)
    assert sch.scores[0] != sch.scores[1]


def test_serial_fallback_streams_are_distinct():
    trial = PopulationTrial(ARCH, steps=2, batch=BATCH, seq=SEQ, seed=0)
    a = trial({"learning_rate": 1e-3})
    b = trial({"learning_rate": 1e-3})
    assert a != b, "anonymous serial trials must not share stream 0"
    shared = PopulationTrial(ARCH, steps=2, batch=BATCH, seq=SEQ, seed=0,
                             per_trial_streams=False)
    assert shared({"learning_rate": 1e-3}) == shared({"learning_rate": 1e-3})


def test_negative_sentinel_streams_are_valid_and_distinct():
    d = SyntheticLM(64, SEQ, BATCH, seed=3)
    m0 = d.make_batch(1)
    m1 = d.make_batch(1, stream=-1)
    m2 = d.make_batch(1, stream=-2)
    assert not np.array_equal(m1["tokens"], m0["tokens"])
    assert not np.array_equal(m1["tokens"], m2["tokens"])
    np.testing.assert_array_equal(m1["tokens"], d.make_batch(1, stream=-1)["tokens"])
    # per-lane step cursors for refilled lanes
    pb = d.make_population_batch([0, 3], [5, 6])
    np.testing.assert_array_equal(pb["tokens"][0], d.make_batch(0, stream=5)["tokens"])
    np.testing.assert_array_equal(pb["tokens"][1], d.make_batch(3, stream=6)["tokens"])


def test_padding_lanes_do_not_disturb_scores():
    cfgs = _cfgs(2)
    wide = PopulationTrial(ARCH, steps=2, batch=BATCH, seq=SEQ, seed=0,
                           population=4)
    narrow = PopulationTrial(ARCH, steps=2, batch=BATCH, seq=SEQ, seed=0,
                             population=2)
    np.testing.assert_allclose(wide.run_population(cfgs),
                               narrow.run_population(cfgs),
                               rtol=1e-5, atol=1e-6)


def test_per_trial_init_serial_population_equivalence():
    cfgs = [{"learning_rate": 1e-3, "stream": 3}, {"learning_rate": 2e-3, "stream": 7}]
    t = PopulationTrial(ARCH, steps=3, batch=BATCH, seq=SEQ, seed=0,
                        population=2, per_trial_init=True)
    serial = [t(dict(c)) for c in cfgs]
    vec = t.run_population(cfgs)
    np.testing.assert_allclose(vec, serial, rtol=1e-5, atol=1e-6)
    shared_init = PopulationTrial(ARCH, steps=3, batch=BATCH, seq=SEQ, seed=0,
                                  population=2).run_population(cfgs)
    assert not np.allclose(shared_init, vec), \
        "per-trial init must start trials from different weights"


def test_scalar_batch_per_job_blast_radius():
    """On the scalar fallback path, one raising config fails only its job."""
    rm = VectorizedResourceManager(n_parallel=3)
    done = []

    def target(cfg):
        if cfg["x"] == 1:
            raise ValueError("bad config")
        return float(cfg["x"])

    jobs = [Job(i, {"x": i}, f"slot{i}", done.append) for i in range(3)]
    for j in jobs:
        rm._busy[j.resource_id] = None
        rm.run(j, target)
    for j in jobs:
        assert j.wait(10.0)
    assert jobs[1].status == JobStatus.FAILED
    assert jobs[0].status == JobStatus.FINISHED and jobs[0].result.score == 0.0
    assert jobs[2].status == JobStatus.FINISHED and jobs[2].result.score == 2.0
