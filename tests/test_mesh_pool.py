"""Geometry edges of the mesh-slice resource pool.

``tile_pod`` is the quantum allocator under both the TPU-native
``MeshPoolResourceManager`` and the elastic lane pool's width-annotated
leases — its row-major contiguity, label format and error contract are
load-bearing for resource ids that survive in journals and snapshots.
Covered here: non-power-of-two pods, 1-device slices, virtual pods, the
does-not-tile / not-enough-devices failure modes, and the two-level mesh
construction layered on top.
"""
import numpy as np
import pytest

import jax

from repro.core.resource.mesh_pool import MeshSlice, tile_pod

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a multi-device (virtual CPU) mesh"
)


# -- tile_pod geometry -----------------------------------------------------------


def test_tile_pod_non_power_of_two_row():
    """A (1, 6) pod tiles into width-3 slices: two row-major tiles, ids
    naming the exact grid window each occupies."""
    slices = tile_pod((1, 6), (1, 3), virtual=True)
    assert [s.slice_id for s in slices] == \
        ["slice[0:1,0:3]", "slice[0:1,3:6]"]
    assert [s.origin for s in slices] == [(0, 0), (0, 3)]
    assert all(s.shape == (1, 3) for s in slices)
    # contiguity: each tile holds consecutive columns of its row
    assert slices[1].devices == ("chip(0,3)", "chip(0,4)", "chip(0,5)")


def test_tile_pod_single_device_slices():
    """1x1 slices: every chip is its own resource, in row-major order."""
    slices = tile_pod((2, 3), (1, 1), virtual=True)
    assert len(slices) == 6
    assert slices[0].devices == ("chip(0,0)",)
    assert slices[-1].slice_id == "slice[1:2,2:3]"
    assert [s.origin for s in slices] == \
        [(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]


def test_tile_pod_2d_blocks_are_contiguous_rectangles():
    """A 4x4 pod in 2x2 blocks: each slice is a rectangle of the grid, not a
    scattered chip set (contiguity is the ICI locality contract)."""
    slices = tile_pod((4, 4), (2, 2), virtual=True)
    assert len(slices) == 4
    assert slices[1].devices == \
        ("chip(0,2)", "chip(0,3)", "chip(1,2)", "chip(1,3)")


def test_tile_pod_virtual_pods_scale_without_devices():
    """The paper's Fig. 3 regime: 256 virtual slices on a deviceless host."""
    slices = tile_pod((16, 16), (1, 1), virtual=True)
    assert len(slices) == 256
    assert all(s.virtual for s in slices)
    with pytest.raises(RuntimeError, match="virtual"):
        slices[0].mesh()


def test_tile_pod_rejects_untileable_slice():
    with pytest.raises(ValueError, match="does not tile"):
        tile_pod((1, 8), (1, 3), virtual=True)
    with pytest.raises(ValueError, match="does not tile"):
        tile_pod((2, 2), (3, 1), virtual=True)


def test_tile_pod_rejects_short_device_list():
    with pytest.raises(ValueError, match="need 4 devices"):
        tile_pod((2, 2), (1, 1), devices=jax.devices()[:1])


@multi_device
def test_real_slice_builds_named_mesh():
    n = jax.device_count()
    (sl,) = tile_pod((1, n), (1, n))
    assert not sl.virtual
    mesh = sl.mesh(axis_names=("pop", "model"))
    assert dict(mesh.shape) == {"pop": 1, "model": n}


# -- the two-level population mesh layered on tile_pod geometry ------------------


@multi_device
def test_population_mesh_two_level_width():
    from repro.distributed.sharding import population_mesh

    n = jax.device_count()
    flat = population_mesh()
    assert tuple(flat.axis_names) == ("pop",)
    assert flat.shape["pop"] == n

    two = population_mesh(width=n)
    assert tuple(two.axis_names) == ("pop", "model")
    assert two.shape["pop"] == 1 and two.shape["model"] == n

    with pytest.raises(ValueError, match="tile"):
        population_mesh(width=3 * n)


def test_population_specs_replicates_rank0_and_indivisible():
    """Rank-aware specs: scalar leaves and leading dims the mesh cannot
    divide fall back to replication instead of a lowering error."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import population_mesh, population_specs

    n = jax.device_count()
    mesh = population_mesh()
    tree = {"w": jnp.zeros((n, 3)), "s": jnp.zeros(()), "odd": jnp.zeros((n + 1,))}
    specs = population_specs(tree, mesh)
    assert specs["w"].spec == P("pop")
    assert specs["s"].spec == P()
    assert specs["odd"].spec == P()
